"""L2 correctness: the batched jax pipeline vs the per-pixel oracle."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.mosum import window_matrix


def wmat(cfg):
    return jnp.asarray(window_matrix(cfg.n_total, cfg.n_hist, cfg.h))


def make_cfg(N=60, n=40, h=20, k=2, m=8, use_pallas=True):
    return model.ModelConfig(
        n_total=N, n_hist=n, h=h, k=k, m_chunk=m, use_pallas=use_pallas
    )


def synth(rng, N, m, f=12.0, with_breaks=True):
    t = np.arange(1, N + 1, dtype=np.float64)
    Y = 0.05 * np.sin(2 * np.pi * t[:, None] / f) + 0.01 * rng.standard_normal(
        (N, m)
    )
    if with_breaks:
        Y[int(0.6 * N) :, ::2] += 0.5
    return t, Y


def test_gauss_jordan_inv_matches_numpy():
    rng = np.random.default_rng(0)
    for p in (2, 4, 8, 12):
        A = rng.standard_normal((p, p))
        G = A @ A.T + p * np.eye(p)  # SPD
        got = np.asarray(model.gauss_jordan_inv(jnp.asarray(G)))
        np.testing.assert_allclose(got, np.linalg.inv(G), rtol=1e-8, atol=1e-8)


def test_design_matrix_matches_ref():
    t = np.arange(1, 51, dtype=np.float64)
    for k in (1, 3, 5):
        got = np.asarray(
            model.design_matrix(jnp.asarray(t, jnp.float32), jnp.float32(23.0), k)
        )
        want = ref.design_matrix(t, 23.0, k)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
        assert got.shape == (2 + 2 * k, 50)


def test_fit_matches_per_pixel_ols():
    rng = np.random.default_rng(1)
    cfg = make_cfg()
    t, Y = synth(rng, cfg.n_total, cfg.m_chunk)
    X = ref.design_matrix(t, 12.0, cfg.k)
    want = np.stack(
        [ref.fit_history(X, Y[:, i], cfg.n_hist) for i in range(cfg.m_chunk)], axis=1
    )
    got = np.asarray(
        model.fit(
            jnp.asarray(t, jnp.float32),
            jnp.float32(12.0),
            jnp.asarray(Y[: cfg.n_hist], jnp.float32),
            cfg,
        )
    )
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_boundary_matches_ref():
    cfg = make_cfg(N=120, n=30)
    got = np.asarray(model.boundary(jnp.float32(2.5), cfg))
    want = ref.boundary_ref(120, 30, 2.5)
    np.testing.assert_allclose(got, want, rtol=1e-5)
    # log_+ kicks in at t/n > e: boundary constant before, growing after
    assert np.all(got[: int(np.e * 30) - 30] == got[0])


@pytest.mark.parametrize("use_pallas", [True, False])
def test_fused_pipeline_matches_oracle(use_pallas):
    rng = np.random.default_rng(2)
    cfg = make_cfg(N=80, n=50, h=25, k=2, m=16, use_pallas=use_pallas)
    t, Y = synth(rng, cfg.n_total, cfg.m_chunk)
    lam = 2.0
    breaks, first, momax, _ = ref.bfast_ref(
        Y, t, f=12.0, n=cfg.n_hist, h=cfg.h, k=cfg.k, lam=lam
    )
    got_b, got_f, got_m = [
        np.asarray(a)
        for a in model.bfast_fused(
            jnp.asarray(t, jnp.float32),
            jnp.float32(12.0),
            wmat(cfg),
            jnp.asarray(Y, jnp.float32),
            jnp.float32(lam),
            cfg,
        )
    ]
    np.testing.assert_array_equal(got_b, breaks)
    np.testing.assert_array_equal(got_f, first)
    np.testing.assert_allclose(got_m, momax, rtol=5e-3, atol=5e-3)


def test_phased_equals_fused():
    rng = np.random.default_rng(3)
    cfg = make_cfg(N=70, n=45, h=20, k=3, m=12)
    t, Y = synth(rng, cfg.n_total, cfg.m_chunk)
    tj = jnp.asarray(t, jnp.float32)
    fj = jnp.float32(12.0)
    yj = jnp.asarray(Y, jnp.float32)
    lam = jnp.float32(2.2)
    (beta,) = model.phase_fit(tj, fj, yj[: cfg.n_hist], cfg)
    (yhat,) = model.phase_predict(tj, fj, beta, cfg)
    (mo,) = model.phase_mosum(wmat(cfg), yj, yhat, cfg)
    pb, pf, pm = model.phase_detect(mo, lam, cfg)
    fb, ff, fm = model.bfast_fused(tj, fj, wmat(cfg), yj, lam, cfg)
    np.testing.assert_array_equal(np.asarray(pb), np.asarray(fb))
    np.testing.assert_array_equal(np.asarray(pf), np.asarray(ff))
    np.testing.assert_allclose(np.asarray(pm), np.asarray(fm), rtol=1e-6)


def test_irregular_day_of_year_axis():
    """§4.3: fractional-year time axis with uneven gaps must work."""
    rng = np.random.default_rng(4)
    N, n, h, k, f = 96, 64, 32, 3, 365.0
    cfg = make_cfg(N=N, n=n, h=h, k=k, m=6)
    # Landsat-like: ~16-day cadence with jitter and dropped scenes.
    gaps = rng.choice([8.0, 16.0, 16.0, 24.0, 32.0], size=N)
    t = np.cumsum(gaps)
    Y = 0.3 + 0.1 * np.sin(2 * np.pi * t[:, None] / f) + 0.01 * rng.standard_normal(
        (N, cfg.m_chunk)
    )
    Y[70:, :3] -= 0.4
    # lam well above the 5%-alpha value (~2.39) so that random noise
    # cannot flake the no-break pixels; the oracle-equality assertions
    # below are the real test.
    lam = 4.0
    breaks, first, momax, _ = ref.bfast_ref(Y, t, f=f, n=n, h=h, k=k, lam=lam)
    got_b, got_f, got_m = [
        np.asarray(a)
        for a in model.bfast_fused(
            jnp.asarray(t, jnp.float32),
            jnp.float32(f),
            wmat(cfg),
            jnp.asarray(Y, jnp.float32),
            jnp.float32(lam),
            cfg,
        )
    ]
    np.testing.assert_array_equal(got_b, breaks)
    assert got_b[:3].all() and not got_b[3:].any()
    np.testing.assert_array_equal(got_f, first)
    np.testing.assert_allclose(got_m, momax, rtol=5e-3, atol=5e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_hypothesis_break_detection_roundtrip(seed):
    """Injected level shifts must flag, and every pixel must agree with
    the per-pixel float64 oracle (breaks AND first-crossing index).

    No absolute "no false positive" claim is made for the flat pixels:
    under H0 the MOSUM drifts with the parameter-estimation error (the
    reason lambda comes from simulation in the first place).
    """
    rng = np.random.default_rng(seed)
    cfg = make_cfg(N=100, n=60, h=30, k=2, m=10)
    t, Y = synth(rng, cfg.n_total, cfg.m_chunk, with_breaks=False)
    Y[75:, :5] += 1.0  # strong break in pixels 0..4
    lam = 4.0
    breaks, first, _, _ = ref.bfast_ref(
        Y, t, f=12.0, n=cfg.n_hist, h=cfg.h, k=cfg.k, lam=lam
    )
    got_b, got_f, _ = model.bfast_fused(
        jnp.asarray(t, jnp.float32),
        jnp.float32(12.0),
        wmat(cfg),
        jnp.asarray(Y, jnp.float32),
        jnp.float32(lam),
        cfg,
    )
    got_b, got_f = np.asarray(got_b), np.asarray(got_f)
    assert got_b[:5].all()
    np.testing.assert_array_equal(got_b, breaks)
    np.testing.assert_array_equal(got_f, first)


def test_config_validation():
    with pytest.raises(ValueError):
        make_cfg(N=50, n=50).validate()
    with pytest.raises(ValueError):
        make_cfg(n=20, h=21).validate()
    with pytest.raises(ValueError):
        make_cfg(n=6, h=2, k=3).validate()  # n <= p
