"""L1 correctness: the Pallas MOSUM kernel vs the pure-numpy oracle.

Hypothesis sweeps shapes/bandwidths/dtypes; every case is also checked
against the plain-XLA variant so the two backends can never drift.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.mosum import mosum_pallas, mosum_xla


def oracle_mosum(Y, Yhat, n, h, k):
    N, m = Y.shape
    out = np.empty((N - n, m))
    for i in range(m):
        out[:, i] = ref.mosum_ref(Y[:, i] - Yhat[:, i], n, h, k)
    return out


def random_case(rng, N, m, n, h, k):
    t = np.arange(1, N + 1, dtype=np.float64)
    Y = 0.1 * np.sin(2 * np.pi * t[:, None] / 12.0) + 0.05 * rng.standard_normal(
        (N, m)
    )
    X = ref.design_matrix(t, 12.0, k)
    beta = np.stack([ref.fit_history(X, Y[:, i], n) for i in range(m)], axis=1)
    Yhat = X.T @ beta
    return Y, Yhat


@pytest.mark.parametrize("block_m", [1, 2, 7, 64, 256])
def test_block_shapes_match_oracle(block_m):
    rng = np.random.default_rng(0)
    N, m, n, h, k = 80, 64, 50, 25, 2
    Y, Yhat = random_case(rng, N, m, n, h, k)
    got = mosum_pallas(
        jnp.asarray(Y, jnp.float32),
        jnp.asarray(Yhat, jnp.float32),
        n=n,
        h=h,
        k=k,
        block_m=block_m,
    )
    want = oracle_mosum(Y, Yhat, n, h, k)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


@settings(max_examples=30, deadline=None)
@given(
    N=st.integers(24, 120),
    m=st.integers(1, 40),
    data=st.data(),
)
def test_hypothesis_shape_sweep(N, m, data):
    k = data.draw(st.integers(1, 3))
    n = data.draw(st.integers(2 + 2 * k + 2, N - 2))
    h = data.draw(st.integers(1, n))
    rng = np.random.default_rng(N * 1000 + m)
    Y, Yhat = random_case(rng, N, m, n, h, k)
    got = mosum_pallas(
        jnp.asarray(Y, jnp.float32), jnp.asarray(Yhat, jnp.float32), n=n, h=h, k=k
    )
    want = oracle_mosum(Y, Yhat, n, h, k)
    assert got.shape == (N - n, m)
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-3, atol=5e-3)


@settings(max_examples=10, deadline=None)
@given(dtype=st.sampled_from([jnp.float32, jnp.float64]))
def test_dtype_sweep(dtype):
    rng = np.random.default_rng(7)
    N, m, n, h, k = 60, 16, 40, 20, 2
    Y, Yhat = random_case(rng, N, m, n, h, k)
    got = mosum_pallas(
        jnp.asarray(Y, dtype), jnp.asarray(Yhat, dtype), n=n, h=h, k=k
    )
    want = oracle_mosum(Y, Yhat, n, h, k)
    tol = 2e-3 if dtype == jnp.float32 else 1e-9
    np.testing.assert_allclose(np.asarray(got), want, rtol=tol, atol=tol)
    assert got.dtype == dtype


def test_pallas_equals_xla_variant():
    rng = np.random.default_rng(3)
    N, m, n, h, k = 100, 128, 60, 30, 3
    Y, Yhat = random_case(rng, N, m, n, h, k)
    yj = jnp.asarray(Y, jnp.float32)
    yh = jnp.asarray(Yhat, jnp.float32)
    a = mosum_pallas(yj, yh, n=n, h=h, k=k)
    b = mosum_xla(yj, yh, n=n, h=h, k=k)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_window_is_h_terms_ending_at_t():
    # Deterministic: residual = 1 exactly at one time step; the MOSUM
    # must be nonzero exactly for the h monitor steps covering it.
    N, m, n, h, k = 40, 4, 24, 6, 1  # wait: dof = n - 4 > 0
    Y = np.zeros((N, m), dtype=np.float32)
    Yhat = np.zeros_like(Y)
    spike = n + 3  # 0-based time index in the monitor period
    Y[spike, :] = 1.0
    # history residuals must be nonzero for sigma > 0
    rng = np.random.default_rng(1)
    Y[:n, :] = rng.standard_normal((n, m)).astype(np.float32)
    mo = np.asarray(mosum_pallas(jnp.asarray(Y), jnp.asarray(Yhat), n=n, h=h, k=k))
    nz = np.abs(mo[:, 0]) > 1e-9
    # Windows ending at t cover the spike for t in [spike, spike+h-1].
    # Monitor indices < h-1 have windows reaching into the (noisy)
    # history, so only assert from h-1 onwards.
    lo = spike - n  # first monitor index whose window includes spike
    hi = min(lo + h, N - n)
    expect = np.zeros(N - n, dtype=bool)
    expect[lo:hi] = True
    np.testing.assert_array_equal(nz[h - 1 :], expect[h - 1 :])


def test_rejects_bad_params():
    y = jnp.zeros((10, 4), jnp.float32)
    with pytest.raises(ValueError):
        mosum_pallas(y, y, n=12, h=2, k=1)  # n >= N
    with pytest.raises(ValueError):
        mosum_pallas(y, y, n=8, h=9, k=1)  # h > n
    with pytest.raises(ValueError):
        mosum_pallas(y, y, n=4, h=2, k=1)  # dof <= 0
    with pytest.raises(ValueError):
        mosum_pallas(y, jnp.zeros((10, 5), jnp.float32), n=8, h=2, k=1)
