"""AOT layer: lowering produces loadable HLO text, manifests are
consistent, and golden vectors round-trip."""

import json
import os
import struct
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest

from compile import aot
from compile.model import ModelConfig


def small_cfg(m=64):
    return ModelConfig(n_total=50, n_hist=30, h=10, k=2, m_chunk=m)


@pytest.mark.parametrize("phase", ["fused", "fit", "predict", "mosum", "detect"])
def test_lower_phase_emits_hlo_text(phase):
    text, ins, outs = aot.lower_phase(small_cfg(), phase)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert len(ins) >= 2
    assert len(outs) >= 1
    # shapes in the descriptor must appear in the HLO entry layout
    assert all(isinstance(i["shape"], list) for i in ins)


def test_fused_io_descriptors_match_config():
    cfg = small_cfg(m=32)
    _, ins, outs = aot.lower_phase(cfg, "fused")
    names = [i["name"] for i in ins]
    assert names == ["t", "f", "w", "y", "lam"]
    y = next(i for i in ins if i["name"] == "y")
    assert y["shape"] == [cfg.n_total, cfg.m_chunk]
    assert [o["name"] for o in outs] == ["breaks", "first", "momax"]
    assert outs[0]["dtype"] == "i32"
    assert outs[2]["dtype"] == "f32"


def test_variants_cover_paper_sweeps():
    names = [name for name, _, _ in aot.variants(1024, quick=False)]
    for required in ["default", "k1", "k2", "k4", "k5", "h25", "h100", "chile", "default_xla"]:
        assert required in names, f"missing variant {required}"
    # chile variant must be shaped like §4.3
    chile = next(cfg for name, cfg, _ in aot.variants(1024, False) if name == "chile")
    assert (chile.n_total, chile.n_hist, chile.h, chile.k) == (288, 144, 72, 3)


def test_write_bten_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.bten")
        arr = np.arange(12, dtype=np.float32).reshape(3, 4)
        aot.write_bten(path, arr)
        with open(path, "rb") as fh:
            raw = fh.read()
        assert raw[:4] == b"BTEN"
        code, ndim = struct.unpack("<BB", raw[4:6])
        assert (code, ndim) == (0, 2)
        dims = struct.unpack("<II", raw[6:14])
        assert dims == (3, 4)
        back = np.frombuffer(raw[14:], dtype="<f4").reshape(3, 4)
        np.testing.assert_array_equal(back, arr)


def test_emit_golden_is_self_consistent():
    with tempfile.TemporaryDirectory() as d:
        aot.emit_golden(d)
        with open(os.path.join(d, "case0.json")) as fh:
            meta = json.load(fh)
        assert meta["N"] > meta["n"] > meta["h"]
        # the breaks vector must flag the even pixels (generator injects
        # a +0.5 shift there) and mo shape must match the monitor period
        def rd(name):
            with open(os.path.join(d, f"case0_{name}.bten"), "rb") as fh:
                raw = fh.read()
            code, ndim = struct.unpack("<BB", raw[4:6])
            dims = struct.unpack("<" + "I" * ndim, raw[6 : 6 + 4 * ndim])
            dt = {0: "<f4", 1: "<i4", 2: "<f8"}[code]
            return np.frombuffer(raw[6 + 4 * ndim :], dtype=dt).reshape(dims)

        breaks = rd("breaks")
        assert breaks[::2].all() and not breaks[1::2].any()
        mo = rd("mo")
        assert mo.shape == (meta["N"] - meta["n"], meta["m"])
        first = rd("first")
        assert (first[breaks == 1] >= 0).all()
        assert (first[breaks == 0] == -1).all()
