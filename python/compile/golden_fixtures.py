"""Generate the committed golden-vector fixtures for the rust suite.

``aot.py --golden`` exports oracle vectors into ``artifacts/golden/``,
which only exists after an artifact build — so offline CI used to skip
the golden tests entirely. This standalone script (numpy only, no jax)
derives small fixtures from the same float64 oracle (``kernels/ref.py``)
and writes them to ``rust/tests/data/golden/``, where they are
committed and always available:

* ``case0`` — breaking series (0.5 shift on the last 40% of even pixels)
* ``case1`` — stable series (no shift; the oracle must report 0 breaks)
* ``case2`` — gappy series: random cloud holes, one leading-gap pixel
  and one entirely-missing pixel. ``y`` is stored *raw* (NaNs included);
  the oracle runs on the forward/backward-filled series, mirroring the
  rust staging fill. The all-NaN pixel keeps the scan semantics every
  rust engine implements: breaks=0, first=-1, momax=0.

Inputs are quantised to float32 before the oracle runs so the rust
engines (which store scenes as f32) see bit-identical inputs.

Usage:  python3 python/compile/golden_fixtures.py
"""

from __future__ import annotations

import json
import os
import struct
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from kernels import ref  # noqa: E402

OUT = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "..", "rust", "tests", "data", "golden"
)


def write_bten(path: str, arr: np.ndarray) -> None:
    """b"BTEN" | u8 dtype (0=f32,1=i32,2=f64) | u8 ndim | dims u32 | LE data."""
    arr = np.ascontiguousarray(arr)
    code = {np.dtype("float32"): 0, np.dtype("int32"): 1, np.dtype("float64"): 2}[arr.dtype]
    with open(path, "wb") as fh:
        fh.write(b"BTEN")
        fh.write(struct.pack("<BB", code, arr.ndim))
        for d in arr.shape:
            fh.write(struct.pack("<I", d))
        fh.write(arr.tobytes())


def fill_series(y: np.ndarray) -> np.ndarray:
    """Forward fill then backward fill (rust ``fill::fill_series``).

    An entirely-NaN series is returned unchanged, as in rust.
    """
    y = y.copy()
    last = np.nan
    for i in range(len(y)):
        if np.isnan(y[i]):
            if not np.isnan(last):
                y[i] = last
        else:
            last = y[i]
    nxt = np.nan
    for i in range(len(y) - 1, -1, -1):
        if np.isnan(y[i]):
            if not np.isnan(nxt):
                y[i] = nxt
        else:
            nxt = y[i]
    return y


def emit_case(idx: int, name: str, Y_raw: np.ndarray, t, *, f, n, h, k, lam) -> None:
    N, m = Y_raw.shape
    Y_filled = np.stack([fill_series(Y_raw[:, i]) for i in range(m)], axis=1)
    breaks, first, momax, MO = ref.bfast_ref(Y_filled, t, f=f, n=n, h=h, k=k, lam=lam)
    # an all-NaN series scans to the defined no-break result in rust
    all_nan = np.isnan(Y_raw).all(axis=0)
    momax = np.where(all_nan, 0.0, momax)
    assert (breaks[all_nan] == 0).all() and (first[all_nan] == -1).all()
    X = ref.design_matrix(t, f, k)
    beta = np.stack([ref.fit_history(X, Y_filled[:, i], n) for i in range(m)], axis=1)
    meta = dict(name=name, N=N, n=n, h=h, k=k, f=f, lam=lam, m=m)
    with open(os.path.join(OUT, f"case{idx}.json"), "w") as fh:
        json.dump(meta, fh, indent=1)
    for tname, arr, dt in [
        ("t", t, "float64"),
        ("y", Y_raw, "float64"),  # raw: NaN gaps preserved
        ("beta", beta, "float64"),
        ("mo", MO, "float64"),
        ("momax", momax, "float64"),
        ("breaks", breaks, "int32"),
        ("first", first, "int32"),
    ]:
        write_bten(os.path.join(OUT, f"case{idx}_{tname}.bten"), np.asarray(arr, dtype=dt))
    nb = int(breaks.sum())
    print(f"case{idx} ({name}): m={m}, {nb} breaking pixels")


def main() -> None:
    os.makedirs(OUT, exist_ok=True)
    N, n, h, k, f = 60, 40, 20, 2, 12.0
    t = np.arange(1, N + 1, dtype=np.float64)

    def base(m: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        y = 0.05 * np.sin(2 * np.pi * t[:, None] / f) + 0.01 * rng.standard_normal((N, m))
        return y

    def quantise(y: np.ndarray) -> np.ndarray:
        return y.astype(np.float32).astype(np.float64)

    # case0: breaking — the aot.py --golden recipe
    y0 = base(6, 7)
    y0[int(N * 0.6):, ::2] += 0.5
    y0 = quantise(y0)
    emit_case(0, "breaking", y0, t, f=f, n=n, h=h, k=k, lam=2.5)

    # case1: stable — lambda above the finite-sample null quantile;
    # the oracle must report no breaks at all (asserted)
    y1 = quantise(base(4, 8))
    b1, *_ = ref.bfast_ref(y1, t, f=f, n=n, h=h, k=k, lam=6.0)
    assert b1.sum() == 0, "case1 must be break-free"
    emit_case(1, "stable", y1, t, f=f, n=n, h=h, k=k, lam=6.0)

    # case2: gappy — cloud holes + leading gap + one dead pixel
    m2 = 7
    y2 = base(m2, 9)
    y2[int(N * 0.6):, ::2] += 0.5
    rng = np.random.default_rng(10)
    holes = rng.random((N, 5)) < 0.08  # pixels 0..4: random dropouts
    y2[:, :5] = np.where(holes, np.nan, y2[:, :5])
    y2[:7, 5] = np.nan      # pixel 5: leading gap (backward fill)
    y2[:, 6] = np.nan       # pixel 6: never reports
    y2 = quantise(y2)
    emit_case(2, "gappy", y2, t, f=f, n=n, h=h, k=k, lam=2.5)


if __name__ == "__main__":
    main()
