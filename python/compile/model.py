"""L2 — the batched BFAST(monitor) compute graph in JAX.

Operates on a *chunk* of pixels ``Y ∈ R^{N×m}`` (time-major) at once,
exactly the fusion the paper performs in Section 3: the design matrix
and its pseudo-inverse are computed once per chunk, the per-pixel model
fits collapse into one matmul (Eq. 9), predictions into another
(Eq. 10), and the residual/MOSUM/detection tail runs in the L1 Pallas
kernel plus a handful of fused element-wise ops.

Two kinds of modules are exported by ``aot.py``:

* ``fused``  — the production path: (t, f, Y, lambda) → (breaks, first,
  momax). One executable, no intermediate round-trips.
* ``fit`` / ``predict`` / ``mosum`` / ``detect`` — the *phased* path
  used only by the instrumented benchmarks that reproduce the paper's
  per-phase figures (Figs. 3–6). Intermediates stay on device as PJRT
  buffers between phases.

Numerics: everything is float32 on the request path (as in the paper's
CUDA code); only the tiny (p×p, p = 2+2k ≤ 12) Gram inversion is done
in float64 and hand-rolled Gauss–Jordan, because the CPU PJRT plugin of
xla_extension 0.5.1 cannot run LAPACK custom-calls that
``jnp.linalg.*`` would lower to.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.mosum import mosum_pallas, mosum_xla

# float64 constants/ops below require the x64 flag; aot.py sets it
# before tracing. Harmless for the f32 request path.
E = 2.718281828459045


@dataclass(frozen=True)
class ModelConfig:
    """Static shape/hyper-parameters baked into one AOT artifact."""

    n_total: int  # N — length of each time series
    n_hist: int  # n — stable history period
    h: int  # MOSUM bandwidth
    k: int  # harmonic terms
    m_chunk: int  # pixels per chunk (the batched axis)
    # Pallas lane tile: the HBM↔VMEM schedule knob. On a real TPU this
    # is bounded by VMEM (~2048 lanes for N=200, see DESIGN.md §2); for
    # CPU-PJRT deployment aot.py sets block_m = m_chunk so the
    # interpret-mode grid collapses to one step (the while-loop +
    # dynamic-slice overhead of interpreted grids is pure loss on CPU).
    block_m: int = 2048
    use_pallas: bool = True  # False → plain-XLA ablation variant

    @property
    def p(self) -> int:
        return 2 + 2 * self.k

    def validate(self) -> None:
        if not 1 <= self.n_hist < self.n_total:
            raise ValueError(f"need 1 <= n < N: {self}")
        if not 1 <= self.h <= self.n_hist:
            raise ValueError(f"need 1 <= h <= n: {self}")
        if self.n_hist <= self.p:
            raise ValueError(f"history shorter than dof correction: {self}")
        if self.m_chunk < 1:
            raise ValueError(f"m_chunk must be positive: {self}")


def design_matrix(t: jax.Array, f: jax.Array, k: int) -> jax.Array:
    """X ∈ R^{(2+2k)×N} from a runtime time vector and frequency.

    ``t`` is a *runtime input* so the same artifact serves both the
    regular-index case (t = 1..N, f = 23) and the irregular Landsat
    day-of-year case of §4.3 (t = fractional days, f = 365) without
    re-lowering. Trend regressor is t/f — see ref.design_matrix.
    """
    ty = t / f
    rows = [jnp.ones_like(t), ty]
    for j in range(1, k + 1):
        w = (2.0 * jnp.pi * j) * ty
        rows.append(jnp.sin(w))
        rows.append(jnp.cos(w))
    return jnp.stack(rows)


def gauss_jordan_inv(G: jax.Array) -> jax.Array:
    """Inverse of a small SPD matrix via unrolled Gauss–Jordan.

    p ≤ 12, so the python loop unrolls into a handful of fused HLO ops;
    no pivoting is needed for an SPD Gram matrix. Runs in the dtype of
    ``G`` (float64 from the caller).
    """
    p = G.shape[0]
    A = jnp.concatenate([G, jnp.eye(p, dtype=G.dtype)], axis=1)  # (p, 2p)
    for i in range(p):
        row = A[i, :] / A[i, i]
        elim = A[:, i : i + 1] * row[None, :]
        mask = jnp.zeros((p, 1), dtype=G.dtype).at[i, 0].set(1.0)
        A = (A - elim) * (1.0 - mask) + row[None, :] * mask
    return A[:, p:]


def fit(t: jax.Array, f: jax.Array, y_hist: jax.Array, cfg: ModelConfig) -> jax.Array:
    """β̂_all = M · Y_hist (Eqs. 8–9) for all pixels of the chunk.

    The Gram solve runs in float64 (p×p — negligible), the big
    (p×n)·(n×m) matmul in float32 (MXU-friendly).
    """
    X = design_matrix(t, f, cfg.k)  # (p, N) f32
    Xh = X[:, : cfg.n_hist]
    Xh64 = Xh.astype(jnp.float64)
    G = Xh64 @ Xh64.T  # (p, p)
    M = (gauss_jordan_inv(G) @ Xh64).astype(jnp.float32)  # (p, n)
    return M @ y_hist  # (p, m)


def predict(t: jax.Array, f: jax.Array, beta: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Ŷ = Xᵀ β̂_all (Eq. 10)."""
    X = design_matrix(t, f, cfg.k)
    return X.T @ beta  # (N, m)


def mosum(y: jax.Array, yhat: jax.Array, w: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Normalised MOSUM process — dispatches to the L1 kernel.

    ``w`` is the banded window-sum operator (kernels.mosum.window_matrix)
    supplied as a *runtime input*: baking it as an HLO constant feeding
    the dot miscompiles to all-zeros on xla_extension 0.5.1 (the rust
    coordinator rebuilds the same band from the manifest shape).
    """
    if cfg.use_pallas:
        return mosum_pallas(
            y, yhat, n=cfg.n_hist, h=cfg.h, k=cfg.k, w=w, block_m=cfg.block_m
        )
    return mosum_xla(y, yhat, n=cfg.n_hist, h=cfg.h, k=cfg.k, w=w)


def boundary(lam: jax.Array, cfg: ModelConfig) -> jax.Array:
    """b_t = λ √(log₊ (t/n)) for the monitor period (Eq. 4)."""
    t = jnp.arange(cfg.n_hist + 1, cfg.n_total + 1, dtype=jnp.float32)
    x = t / jnp.float32(cfg.n_hist)
    logp = jnp.where(x <= E, 1.0, jnp.log(x))
    return lam * jnp.sqrt(logp)  # (N - n,)


def detect(mo: jax.Array, bound: jax.Array):
    """Boundary crossing per pixel.

    Returns (breaks i32[m], first i32[m], momax f32[m]); ``first`` is
    the 0-based monitor index of the first crossing or -1.
    """
    amo = jnp.abs(mo)  # (N-n, m)
    exceed = amo > bound[:, None]
    has = jnp.any(exceed, axis=0)
    idx = jnp.argmax(exceed, axis=0).astype(jnp.int32)
    first = jnp.where(has, idx, jnp.int32(-1))
    return has.astype(jnp.int32), first, jnp.max(amo, axis=0)


def bfast_fused(t, f, w, y, lam, cfg: ModelConfig):
    """The production module: whole pipeline, one executable.

    Inputs
    ------
    t   : f32[N]  — time axis (index or fractional day-of-year)
    f   : f32[]   — observations per period (23, 365, ...)
    w   : f32[N-n, N] — banded window operator (see ``mosum``)
    y   : f32[N, m_chunk] — one chunk of pixel series, time-major
    lam : f32[]   — critical value λ(α, h/n, N/n)

    Outputs: (breaks i32[m], first i32[m], momax f32[m]).
    """
    beta = fit(t, f, y[: cfg.n_hist, :], cfg)
    yhat = predict(t, f, beta, cfg)
    mo = mosum(y, yhat, w, cfg)
    return detect(mo, boundary(lam, cfg))


# --- phased entry points (instrumented benchmarks only) -----------------


def phase_fit(t, f, y_hist, cfg: ModelConfig):
    return (fit(t, f, y_hist, cfg),)


def phase_predict(t, f, beta, cfg: ModelConfig):
    return (predict(t, f, beta, cfg),)


def phase_mosum(w, y, yhat, cfg: ModelConfig):
    return (mosum(y, yhat, w, cfg),)


def phase_detect(mo, lam, cfg: ModelConfig):
    return detect(mo, boundary(lam, cfg))
