"""Pallas kernels (L1) and their pure-jnp/numpy oracles."""

from .mosum import mosum_pallas, mosum_xla  # noqa: F401
