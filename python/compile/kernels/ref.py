"""Pure-numpy correctness oracle for the BFAST(monitor) pipeline.

This is the slow-but-obviously-correct reference every other layer is
pinned against:

* ``python/tests`` asserts the Pallas kernel and the AOT model match it;
* the rust test-suite compares against golden vectors exported from it
  (``aot.py --golden``).

Everything here follows Algorithm 1 of the paper literally, one time
series at a time, in float64.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "design_matrix",
    "fit_history",
    "mosum_ref",
    "log_plus",
    "boundary_ref",
    "bfast_ref",
]


def design_matrix(t: np.ndarray, f: float, k: int) -> np.ndarray:
    """Season-trend design matrix X in R^{(2+2k) x N} (paper Alg. 1, step 1).

    Row layout: [1, t/f, sin(2*pi*1*t/f), cos(2*pi*1*t/f), ...,
    sin(2*pi*k*t/f), cos(2*pi*k*t/f)].

    The trend regressor is t/f (time in *years*) rather than the raw
    index t: an exact reparameterisation of Eq. (1) — predictions are
    identical — that keeps the Gram matrix well-conditioned in float32
    for N up to several hundred. All implementations (numpy, jax, rust)
    share this convention.
    """
    t = np.asarray(t, dtype=np.float64)
    rows = [np.ones_like(t), t / f]
    for j in range(1, k + 1):
        w = 2.0 * np.pi * j * t / f
        rows.append(np.sin(w))
        rows.append(np.cos(w))
    return np.stack(rows)  # (2 + 2k, N)


def fit_history(X: np.ndarray, y: np.ndarray, n: int) -> np.ndarray:
    """OLS coefficients from the stable history period (Eq. 6)."""
    Xh = X[:, :n]  # (p, n)
    G = Xh @ Xh.T
    return np.linalg.solve(G, Xh @ y[:n])


def mosum_ref(r: np.ndarray, n: int, h: int, k: int) -> np.ndarray:
    """Normalised MOSUM process MO_t for t = n+1..N (Eq. 3).

    ``r`` are residuals y - yhat for the full series. sigma_hat uses
    the history residuals with dof n - (2 + 2k), as in Algorithm 3.
    """
    N = r.shape[0]
    dof = n - (2 + 2 * k)
    sigma = np.sqrt(np.sum(r[:n] ** 2) / dof)
    mo = np.empty(N - n, dtype=np.float64)
    for t in range(n + 1, N + 1):  # 1-based t
        mo[t - n - 1] = r[t - h : t].sum()  # h terms ending at t
    return mo / (sigma * np.sqrt(n))


def log_plus(x: np.ndarray) -> np.ndarray:
    """log_+ from Eq. (4): 1 for x <= e, log(x) otherwise."""
    x = np.asarray(x, dtype=np.float64)
    return np.where(x <= np.e, 1.0, np.log(np.maximum(x, 1e-300)))


def boundary_ref(N: int, n: int, lam: float) -> np.ndarray:
    """Boundary b_t = lambda * sqrt(log_+ (t/n)) for t = n+1..N (Eq. 4)."""
    t = np.arange(n + 1, N + 1, dtype=np.float64)
    return lam * np.sqrt(log_plus(t / n))


def bfast_ref(
    Y: np.ndarray,
    t: np.ndarray,
    *,
    f: float,
    n: int,
    h: int,
    k: int,
    lam: float,
):
    """Full per-pixel BFAST(monitor) reference over Y in R^{N x m}.

    Returns (breaks int32[m], first int32[m], momax f64[m],
    MO f64[(N-n) x m]). ``first`` is the 0-based monitor index of the
    first boundary crossing, or -1 when the pixel has no break.
    """
    Y = np.asarray(Y, dtype=np.float64)
    N, m = Y.shape
    X = design_matrix(t, f, k)
    bound = boundary_ref(N, n, lam)
    breaks = np.zeros(m, dtype=np.int32)
    first = np.full(m, -1, dtype=np.int32)
    momax = np.zeros(m, dtype=np.float64)
    MO = np.zeros((N - n, m), dtype=np.float64)
    for i in range(m):
        y = Y[:, i]
        beta = fit_history(X, y, n)
        yhat = X.T @ beta
        r = y - yhat
        mo = mosum_ref(r, n, h, k)
        MO[:, i] = mo
        exceed = np.abs(mo) > bound
        momax[i] = np.abs(mo).max() if mo.size else 0.0
        if exceed.any():
            breaks[i] = 1
            first[i] = int(np.argmax(exceed))
    return breaks, first, momax, MO
