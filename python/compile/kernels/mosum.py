"""L1 — Pallas MOSUM kernel (the paper's Algorithm 3, re-thought for TPU).

The CUDA kernel in the paper spawns one thread per pixel (``gid``) and
walks the time axis sequentially, updating each moving sum from the
previous one. The arrays are stored pixel-major (``Y[gid + j*m]``) so a
warp's threads access consecutive addresses (coalescing).

The Pallas port transposes that schedule for a vector unit:

* the **pixel axis is the lane axis** — a BlockSpec tile of shape
  ``(N, block_m)`` keeps ``block_m`` pixels resident in VMEM and every
  jnp op inside the kernel vectorises over them (the analogue of the
  warp), while
* the **time axis is handled with a cumulative sum** instead of the
  loop-carried rolling update: ``MO_t = cs_t - cs_{t-h}`` where ``cs``
  is the inclusive cumsum of the residuals. Same O(N) work per pixel,
  but no sequential dependence that would serialise the VPU.
* residuals are **recomputed on the fly** from ``Y`` and ``Ŷ`` exactly
  as the paper does to save device memory — they never leave VMEM.

VMEM budget per grid step (f32): two ``(N, block_m)`` input slabs, one
``(N - n, block_m)`` output slab and ~3 temporaries of the input size,
i.e. roughly ``5.5 * N * block_m * 4`` bytes ≈ 0.29 MB/lane-group for
``N = 200, block_m = 256`` — far below the 16 MB VMEM ceiling, leaving
room for double buffering. ``block_m`` is a multiple of the 128-wide
lane dimension.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers the kernel to plain HLO so
the AOT artifact runs on any backend. Correctness is pinned against
the pure-jnp oracle in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Lane width of the TPU VPU; block_m should be a multiple of this.
LANE = 128
DEFAULT_BLOCK_M = 2048


def window_matrix(n_total: int, n: int, h: int, dtype=np.float32) -> np.ndarray:
    """Banded window-sum operator W ∈ R^{(N-n)×N}.

    Row i (monitor step t = n+1+i, 1-based) selects the h residuals of
    the Eq. (3) window: ``W[i, j] = 1`` for ``j ∈ [n+i-h+1, n+i]``
    (0-based columns), so ``W @ r`` yields every window sum at once.

    Why a matmul instead of a scan: this is the MXU-shaped formulation
    of the paper's rolling update — a (N−n)×N constant band contracted
    against the (N, block_m) residual slab feeds the systolic array on
    a real TPU, and lowers to the (multi-threaded) Eigen dot on the CPU
    PJRT backend. The scan/cumsum formulations lower to O(N²)
    reduce-windows or long slice+pad chains on xla_extension 0.5.1 and
    dominated the whole pipeline (EXPERIMENTS.md §Perf has the A/B).
    """
    nm = n_total - n
    w = np.zeros((nm, n_total), dtype=dtype)
    for i in range(nm):
        w[i, n + i - h + 1 : n + i + 1] = 1.0
    return w


def window_matrix_trunc(n_total: int, n: int, h: int, dtype=np.float32):
    """Toeplitz band restricted to the rows any window touches.

    The Eq. (3) windows only read residual rows ``n-h+1 .. N-1``
    (0-based), so the contraction shrinks from (N−n)×N to
    (N−n)×(N−n+h−1): ``W'[i, i:i+h] = 1`` and ``win = W' @ r[n-h+1:]``.
    ~25–75 % fewer MACs depending on h/N (EXPERIMENTS.md §Perf).
    Returns (W', first_row) where first_row = n-h+1.
    """
    nm = n_total - n
    cols = nm + h - 1
    w = np.zeros((nm, cols), dtype=dtype)
    for i in range(nm):
        w[i, i : i + h] = 1.0
    return w, n - h + 1


def _mosum_kernel(w_ref, y_ref, yh_ref, mo_ref, *, n: int, h: int, dof: int):
    """Fused residual -> banded-matmul window sums -> sigma-normalise.

    y_ref, yh_ref : (N, bm) observations and model predictions
    mo_ref        : (N - n, bm) normalised MOSUM process output

    Implements Eq. (3) of the paper:
        MO_t = 1/(sigma_hat * sqrt(n)) * sum_{s=t-h+1..t} r_s
    with sigma_hat^2 = sum_{i<=n} r_i^2 / (n - (2 + 2k))  (Alg. 3).
    """
    y = y_ref[...]
    yh = yh_ref[...]
    r = y - yh                                  # residuals, on the fly
    hist = r[:n, :]
    sigma = jnp.sqrt(jnp.sum(hist * hist, axis=0) / dof)     # (bm,)
    win = jnp.dot(w_ref[...], r)                # (N-n, bm) window sums
    denom = sigma * jnp.sqrt(jnp.asarray(n, dtype=y.dtype))
    mo_ref[...] = win / denom


def mosum_pallas(
    y: jax.Array,
    yhat: jax.Array,
    *,
    n: int,
    h: int,
    k: int,
    w: jax.Array | None = None,
    block_m: int = DEFAULT_BLOCK_M,
    interpret: bool = True,
) -> jax.Array:
    """Normalised MOSUM process for a chunk of pixels.

    Parameters
    ----------
    y, yhat : (N, m) float32 — observations / predictions, time-major.
    n       : length of the stable history period (1 <= n < N).
    h       : MOSUM bandwidth (1 <= h <= n).
    k       : number of harmonic terms (sigma dof correction 2 + 2k).
    block_m : pixels per VMEM tile; m must be divisible by it.

    Returns
    -------
    (N - n, m) float32 — MO_t for t = n+1 .. N.
    """
    N, m = y.shape
    if yhat.shape != (N, m):
        raise ValueError(f"y {y.shape} vs yhat {yhat.shape}")
    if not (1 <= n < N):
        raise ValueError(f"need 1 <= n < N, got n={n}, N={N}")
    if not (1 <= h <= n):
        raise ValueError(f"need 1 <= h <= n, got h={h}, n={n}")
    dof = n - (2 + 2 * k)
    if dof <= 0:
        raise ValueError(f"history too short: n={n} <= 2+2k={2 + 2 * k}")
    if m % block_m != 0:
        # Shrink the tile rather than fail: keeps small test shapes easy.
        block_m = m if m < block_m else _largest_divisor(m, block_m)
    grid = (m // block_m,)
    # The banded window operator rides along as a kernel input pinned
    # to block (0, 0) — resident in VMEM across all grid steps. For AOT
    # modules W arrives as a *runtime input* (the L3 coordinator builds
    # it): baking it as an HLO constant feeding the dot miscompiles to
    # all-zeros on xla_extension 0.5.1's CPU backend (EXPERIMENTS.md
    # §Perf documents the hunt).
    wmat = jnp.asarray(window_matrix(N, n, h), dtype=y.dtype) if w is None else w
    kernel = functools.partial(_mosum_kernel, n=n, h=h, dof=dof)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((N - n, N), lambda i: (0, 0)),
            pl.BlockSpec((N, block_m), lambda i: (0, i)),
            pl.BlockSpec((N, block_m), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((N - n, block_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((N - n, m), y.dtype),
        interpret=interpret,
    )(wmat, y, yhat)


def _largest_divisor(m: int, upto: int) -> int:
    for b in range(min(m, upto), 0, -1):
        if m % b == 0:
            return b
    return 1


def mosum_xla(
    y: jax.Array,
    yhat: jax.Array,
    *,
    n: int,
    h: int,
    k: int,
    w: jax.Array | None = None,
) -> jax.Array:
    """Plain-XLA variant of the same computation (ablation baseline).

    Identical math, no pallas_call — used to quantify what explicit
    tiling buys on top of XLA's own fusion (DESIGN.md ablations).
    """
    dof = n - (2 + 2 * k)
    r = y - yhat
    hist = r[:n, :]
    sigma = jnp.sqrt(jnp.sum(hist * hist, axis=0) / dof)
    wmat = jnp.asarray(window_matrix(y.shape[0], n, h), dtype=y.dtype) if w is None else w
    win = jnp.dot(wmat, r)
    return win / (sigma * jnp.sqrt(jnp.asarray(n, dtype=y.dtype)))
