"""AOT driver — lowers the L2/L1 graph to HLO *text* artifacts.

Run once at build time (``make artifacts``); Python never appears on
the request path. For every configuration variant this emits:

    artifacts/<name>__<phase>.hlo.txt   one HLO module per phase
    artifacts/manifest.json             shapes/dtypes for the rust runtime
    artifacts/golden/*.bten             oracle vectors for rust tests

HLO **text** (not ``.serialize()``) is the interchange format: jax
≥ 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser on the rust side reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import struct
import sys

import jax

jax.config.update("jax_enable_x64", True)  # Gram solve runs in f64

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from .model import (  # noqa: E402
    ModelConfig,
    bfast_fused,
    phase_detect,
    phase_fit,
    phase_predict,
    phase_mosum,
)
from .kernels import ref  # noqa: E402

F32 = "f32"
I32 = "i32"


def spec(shape, dtype=F32):
    return {"shape": list(shape), "dtype": dtype}


def _phase_tables(cfg: ModelConfig):
    """(fn, input-spec, output-spec) per phase for one config."""
    N, n, m, p = cfg.n_total, cfg.n_hist, cfg.m_chunk, cfg.p
    nm = N - n
    f32 = jnp.float32
    t_s = jax.ShapeDtypeStruct((N,), f32)
    f_s = jax.ShapeDtypeStruct((), f32)
    lam_s = jax.ShapeDtypeStruct((), f32)
    y_s = jax.ShapeDtypeStruct((N, m), f32)
    w_s = jax.ShapeDtypeStruct((nm, N), f32)
    yh_s = jax.ShapeDtypeStruct((n, m), f32)
    beta_s = jax.ShapeDtypeStruct((p, m), f32)
    yhat_s = jax.ShapeDtypeStruct((N, m), f32)
    mo_s = jax.ShapeDtypeStruct((nm, m), f32)

    out_detect = [
        ("breaks", spec((m,), I32)),
        ("first", spec((m,), I32)),
        ("momax", spec((m,))),
    ]
    return {
        "fused": (
            lambda t, f, w, y, lam: bfast_fused(t, f, w, y, lam, cfg),
            [("t", t_s), ("f", f_s), ("w", w_s), ("y", y_s), ("lam", lam_s)],
            out_detect,
        ),
        "fit": (
            lambda t, f, yh: phase_fit(t, f, yh, cfg),
            [("t", t_s), ("f", f_s), ("y_hist", yh_s)],
            [("beta", spec((p, m)))],
        ),
        "predict": (
            lambda t, f, b: phase_predict(t, f, b, cfg),
            [("t", t_s), ("f", f_s), ("beta", beta_s)],
            [("yhat", spec((N, m)))],
        ),
        "mosum": (
            lambda w, y, yh: phase_mosum(w, y, yh, cfg),
            [("w", w_s), ("y", y_s), ("yhat", yhat_s)],
            [("mo", spec((nm, m)))],
        ),
        "detect": (
            lambda mo, lam: phase_detect(mo, lam, cfg),
            [("mo", mo_s), ("lam", lam_s)],
            out_detect,
        ),
    }


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_phase(cfg: ModelConfig, phase: str) -> tuple[str, list, list]:
    fn, inputs, outputs = _phase_tables(cfg)[phase]
    in_specs = [s for (_, s) in inputs]
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    in_desc = [
        {"name": nm_, **spec(tuple(s.shape), F32)} for (nm_, s) in inputs
    ]
    return text, in_desc, [{"name": nm_, **s} for (nm_, s) in outputs]


# Variant table — see DESIGN.md §4 for which figure needs which.
BASE = dict(n_total=200, n_hist=100, h=50, k=3)
ALL_PHASES = ["fused", "fit", "predict", "mosum", "detect"]


def variants(m_chunk: int, quick: bool):
    out = [
        ("small", ModelConfig(**BASE, m_chunk=1024, block_m=256), ALL_PHASES),
    ]
    if quick:
        return out
    out += [
        ("default", ModelConfig(**BASE, m_chunk=m_chunk, block_m=m_chunk), ALL_PHASES),
        # Fig. 5 — influence of k on the phases.
        *[
            (
                f"k{k}",
                ModelConfig(n_total=200, n_hist=100, h=50, k=k, m_chunk=m_chunk, block_m=m_chunk),
                ALL_PHASES,
            )
            for k in (1, 2, 4, 5)
        ],
        # Fig. 6 — influence of h on the MOSUM phase.
        *[
            (
                f"h{h}",
                ModelConfig(n_total=200, n_hist=100, h=h, k=3, m_chunk=m_chunk, block_m=m_chunk),
                ALL_PHASES,
            )
            for h in (25, 100)
        ],
        # §4.3 — Chile Landsat configuration (irregular day-of-year axis).
        (
            "chile",
            ModelConfig(n_total=288, n_hist=144, h=72, k=3, m_chunk=m_chunk, block_m=m_chunk),
            ["fused"],
        ),
        # Ablation — same pipeline with the plain-XLA mosum instead of pallas.
        (
            "default_xla",
            ModelConfig(**BASE, m_chunk=m_chunk, use_pallas=False),
            ["fused"],
        ),
    ]
    return out


def write_bten(path: str, arr: np.ndarray) -> None:
    """Tiny tensor container for rust golden tests.

    Layout: b"BTEN" | u8 dtype (0=f32,1=i32,2=f64) | u8 ndim |
    ndim × u32 dims | raw little-endian data.
    """
    arr = np.ascontiguousarray(arr)
    code = {np.dtype("float32"): 0, np.dtype("int32"): 1, np.dtype("float64"): 2}[
        arr.dtype
    ]
    with open(path, "wb") as fh:
        fh.write(b"BTEN")
        fh.write(struct.pack("<BB", code, arr.ndim))
        for d in arr.shape:
            fh.write(struct.pack("<I", d))
        fh.write(arr.tobytes())


def emit_golden(outdir: str) -> None:
    """Oracle vectors the rust tests compare against (ref.py, float64)."""
    os.makedirs(outdir, exist_ok=True)
    rng = np.random.default_rng(42)
    N, n, h, k, f, lam, m = 60, 40, 20, 2, 12.0, 2.5, 7
    t = np.arange(1, N + 1, dtype=np.float64)
    Y = 0.05 * np.sin(2 * np.pi * t[:, None] / f) + 0.01 * rng.standard_normal(
        (N, m)
    )
    Y[int(N * 0.6) :, ::2] += 0.5  # breaks in even pixels
    breaks, first, momax, MO = ref.bfast_ref(Y, t, f=f, n=n, h=h, k=k, lam=lam)
    X = ref.design_matrix(t, f, k)
    beta = np.stack([ref.fit_history(X, Y[:, i], n) for i in range(m)], axis=1)
    meta = dict(N=N, n=n, h=h, k=k, f=f, lam=lam, m=m)
    with open(os.path.join(outdir, "case0.json"), "w") as fh:
        json.dump(meta, fh)
    for name, arr, dt in [
        ("t", t, "float64"),
        ("y", Y, "float64"),
        ("beta", beta, "float64"),
        ("mo", MO, "float64"),
        ("momax", momax, "float64"),
        ("breaks", breaks, "int32"),
        ("first", first, "int32"),
    ]:
        write_bten(os.path.join(outdir, f"case0_{name}.bten"), arr.astype(dt))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--m-chunk", type=int, default=16384)
    ap.add_argument("--quick", action="store_true", help="small config only")
    ap.add_argument(
        "--only", default=None, help="comma-separated variant names to (re)build"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"version": 1, "artifacts": []}
    only = set(args.only.split(",")) if args.only else None
    for name, cfg, phases in variants(args.m_chunk, args.quick):
        if only and name not in only:
            continue
        cfg.validate()
        for phase in phases:
            fname = f"{name}__{phase}.hlo.txt"
            path = os.path.join(args.out, fname)
            text, ins, outs = lower_phase(cfg, phase)
            with open(path, "w") as fh:
                fh.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "phase": phase,
                    "file": fname,
                    "n_total": cfg.n_total,
                    "n_hist": cfg.n_hist,
                    "h": cfg.h,
                    "k": cfg.k,
                    "p": cfg.p,
                    "m_chunk": cfg.m_chunk,
                    "use_pallas": cfg.use_pallas,
                    "inputs": ins,
                    "outputs": outs,
                }
            )
            print(f"lowered {fname:<28} ({len(text) / 1024:.0f} KiB)", flush=True)
    man_path = os.path.join(args.out, "manifest.json")
    # --only patches an existing manifest instead of clobbering it.
    if only and os.path.exists(man_path):
        with open(man_path) as fh:
            old = json.load(fh)
        keep = [a for a in old["artifacts"] if a["name"] not in only]
        manifest["artifacts"] = keep + manifest["artifacts"]
    with open(man_path, "w") as fh:
        json.dump(manifest, fh, indent=1)
    emit_golden(os.path.join(args.out, "golden"))
    print(f"manifest: {man_path} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
