/* kernel_replica.c — measured provenance for BENCH_PR6_BASELINE.json
 * and BENCH_PR6.json.
 *
 * The repo's CI runners are too noisy (and too varied) to pin absolute
 * numbers, so the committed perf-trajectory files are measured with
 * this standalone C replica of the two kernel formulations the PR
 * changes, compiled the way rustc compiles the Rust loops:
 *
 *     gcc -O3 -ffp-contract=off -o kernel_replica kernel_replica.c -lm
 *
 * (-ffp-contract=off because the Rust kernels never fuse mul+add; no
 * -ffast-math because the NaN/zero-skip semantics are load-bearing.)
 *
 * "seed" mirrors the pre-PR kernels line for line:
 *   - GEMM: per-row ikj, KC=128 k-blocking, zero-skip, 2048-col panels
 *     (par_sgemm with one thread);
 *   - MOSUM: two passes — phase 4 materialises the full n_mon × m f32
 *     MOSUM matrix (per 512-pixel block: sigma, initial window,
 *     rolling accumulator advance + row write), phase 5 re-reads that
 *     matrix to scan boundaries.
 *
 * "opt" mirrors the post-PR kernels:
 *   - GEMM: MR=4 register tile sharing each streamed B row across four
 *     C rows (fast path when all four A values are nonzero, per-row
 *     skip otherwise), scalar tail, same KC/panel blocking;
 *   - MOSUM: fused — each 512-pixel block rolls its statistics into a
 *     block-local n_mon × w strip and scans it for breaks while hot;
 *     the scene-wide MOSUM matrix never materialises.
 *
 * Before timing anything the program proves the two formulations are
 * bit-identical (memcmp on raw f32/i32 output, NaN / -0.0 / exact-zero
 * laden inputs included) — the same contract rust/tests/gemm_props.rs
 * and tests/cross_backend.rs enforce on the Rust side.
 *
 * Then it times the full five-phase fig2 (m=20000) and fig3 (m=50000)
 * fused-CPU pipelines for both variants: 1 warmup + 5 trials,
 * per-phase nanoseconds, single core. Output lines are parsed by
 * tools/make_bench_json.py into the committed reports.
 */

#include <math.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#define KC 128
#define MR 4
#define PANEL 2048 /* par_sgemm column panel */
#define BLOCK 512  /* MOSUM pixel-block width */

static uint64_t now_ns(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (uint64_t)ts.tv_sec * 1000000000ull + (uint64_t)ts.tv_nsec;
}

/* --- deterministic data (LCG; fixed seeds, like the Rust Pcg32 use) -- */

static uint64_t rng_state = 42;
static uint32_t rnd32(void) {
    rng_state = rng_state * 6364136223846793005ull + 1442695040888963407ull;
    return (uint32_t)(rng_state >> 33);
}
static float frand(float lo, float hi) {
    return lo + (hi - lo) * ((float)(rnd32() & 0xffffff) / 16777216.0f);
}

/* ------------------------- GEMM: seed kernel ------------------------ */
/* per-row ikj with KC blocking and the av == 0.0f skip; one column
 * panel [j0, j0+nb) of C. */
static void gemm_cols_seed(int m, int k, int n, const float *a, const float *b,
                           float *c, int j0, int nb) {
    for (int i = 0; i < m; i++) {
        float *crow = &c[(size_t)i * n + j0];
        for (int j = 0; j < nb; j++) crow[j] = 0.0f;
        for (int pc = 0; pc < k; pc += KC) {
            int kb = k - pc < KC ? k - pc : KC;
            const float *arow = &a[(size_t)i * k + pc];
            for (int p = 0; p < kb; p++) {
                float av = arow[p];
                if (av == 0.0f) continue;
                const float *brow = &b[(size_t)(pc + p) * n + j0];
                for (int j = 0; j < nb; j++) crow[j] += av * brow[j];
            }
        }
    }
}

/* ---------------------- GEMM: optimised kernel ---------------------- */
/* MR=4 micro-tile: four C rows share every streamed B row; fast path
 * when all four A values are nonzero, per-row zero-skip otherwise;
 * scalar tail identical to the seed row loop. */
static void gemm_cols_opt(int m, int k, int n, const float *a, const float *b,
                          float *c, int j0, int nb) {
    int i = 0;
    while (i < m) {
        if (i + MR > m) {
            for (int r = i; r < m; r++) {
                float *crow = &c[(size_t)r * n + j0];
                for (int j = 0; j < nb; j++) crow[j] = 0.0f;
                for (int pc = 0; pc < k; pc += KC) {
                    int kb = k - pc < KC ? k - pc : KC;
                    const float *arow = &a[(size_t)r * k + pc];
                    for (int p = 0; p < kb; p++) {
                        float av = arow[p];
                        if (av == 0.0f) continue;
                        const float *brow = &b[(size_t)(pc + p) * n + j0];
                        for (int j = 0; j < nb; j++) crow[j] += av * brow[j];
                    }
                }
            }
            break;
        }
        float *c0 = &c[(size_t)(i + 0) * n + j0];
        float *c1 = &c[(size_t)(i + 1) * n + j0];
        float *c2 = &c[(size_t)(i + 2) * n + j0];
        float *c3 = &c[(size_t)(i + 3) * n + j0];
        for (int j = 0; j < nb; j++) c0[j] = c1[j] = c2[j] = c3[j] = 0.0f;
        for (int pc = 0; pc < k; pc += KC) {
            int kb = k - pc < KC ? k - pc : KC;
            const float *a0 = &a[(size_t)(i + 0) * k + pc];
            const float *a1 = &a[(size_t)(i + 1) * k + pc];
            const float *a2 = &a[(size_t)(i + 2) * k + pc];
            const float *a3 = &a[(size_t)(i + 3) * k + pc];
            for (int p = 0; p < kb; p++) {
                float v0 = a0[p], v1 = a1[p], v2 = a2[p], v3 = a3[p];
                const float *brow = &b[(size_t)(pc + p) * n + j0];
                if (v0 != 0.0f && v1 != 0.0f && v2 != 0.0f && v3 != 0.0f) {
                    for (int j = 0; j < nb; j++) {
                        float bv = brow[j];
                        c0[j] += v0 * bv;
                        c1[j] += v1 * bv;
                        c2[j] += v2 * bv;
                        c3[j] += v3 * bv;
                    }
                } else {
                    float *cr[MR] = {c0, c1, c2, c3};
                    float vv[MR] = {v0, v1, v2, v3};
                    for (int r = 0; r < MR; r++) {
                        float v = vv[r];
                        if (v == 0.0f) continue;
                        float *crow = cr[r];
                        for (int j = 0; j < nb; j++) crow[j] += v * brow[j];
                    }
                }
            }
        }
        i += MR;
    }
}

typedef void (*gemm_cols_fn)(int, int, int, const float *, const float *,
                             float *, int, int);

/* par_sgemm with one thread: sequential 2048-column panels. */
static void gemm(gemm_cols_fn f, int m, int k, int n, const float *a,
                 const float *b, float *c) {
    for (int j0 = 0; j0 < n; j0 += PANEL) {
        int nb = n - j0 < PANEL ? n - j0 : PANEL;
        f(m, k, n, a, b, c, j0, nb);
    }
}

/* --------------------------- MOSUM + detect ------------------------- */

typedef struct {
    int m, n_total, n_hist, h, n_mon, p;
    double *bound; /* n_mon boundary values */
} Scene;

/* seed: phase 4 writes the full n_mon × m MOSUM matrix, phase 5
 * re-reads it.  Returns per-phase ns via out params. */
static void mosum_detect_seed(const Scene *sc, const float *resid, float *mo,
                              float *momax, int *first, int *breaks,
                              uint64_t *mosum_ns, uint64_t *detect_ns) {
    int m = sc->m, n = sc->n_hist, h = sc->h, n_mon = sc->n_mon;
    double dof = (double)(n - sc->p);
    uint64_t t0 = now_ns();
    for (int s = 0; s < m; s += BLOCK) {
        int e = s + BLOCK < m ? s + BLOCK : m;
        int w = e - s;
        double sigma[BLOCK], acc[BLOCK];
        for (int j = 0; j < w; j++) sigma[j] = 0.0;
        for (int t = 0; t < n; t++) {
            const float *row = &resid[(size_t)t * m + s];
            for (int j = 0; j < w; j++)
                sigma[j] += (double)row[j] * (double)row[j];
        }
        double sqrt_n = sqrt((double)n);
        for (int j = 0; j < w; j++) sigma[j] = sqrt(sigma[j] / dof) * sqrt_n;
        for (int j = 0; j < w; j++) acc[j] = 0.0;
        for (int t = n + 1 - h; t <= n; t++) {
            const float *row = &resid[(size_t)t * m + s];
            for (int j = 0; j < w; j++) acc[j] += (double)row[j];
        }
        for (int j = 0; j < w; j++)
            mo[(size_t)0 * m + s + j] = (float)(acc[j] / sigma[j]);
        for (int ti = 1; ti < n_mon; ti++) {
            const float *add = &resid[(size_t)(n + ti) * m + s];
            const float *sub = &resid[(size_t)(n + ti - h) * m + s];
            for (int j = 0; j < w; j++)
                acc[j] += (double)add[j] - (double)sub[j];
            for (int j = 0; j < w; j++)
                mo[(size_t)ti * m + s + j] = (float)(acc[j] / sigma[j]);
        }
    }
    uint64_t t1 = now_ns();
    for (int s = 0; s < m; s += BLOCK) {
        int e = s + BLOCK < m ? s + BLOCK : m;
        int w = e - s;
        float mx[BLOCK];
        int fs[BLOCK];
        for (int j = 0; j < w; j++) mx[j] = 0.0f;
        for (int j = 0; j < w; j++) fs[j] = -1;
        for (int ti = 0; ti < n_mon; ti++) {
            float bnd = (float)sc->bound[ti];
            const float *row = &mo[(size_t)ti * m + s];
            for (int j = 0; j < w; j++) {
                float a = fabsf(row[j]);
                if (a > mx[j]) mx[j] = a;
                if (fs[j] < 0 && a > bnd) fs[j] = ti;
            }
        }
        for (int j = 0; j < w; j++) {
            breaks[s + j] = fs[j] >= 0 ? 1 : 0;
            first[s + j] = fs[j];
            momax[s + j] = mx[j];
        }
    }
    *mosum_ns = t1 - t0;
    *detect_ns = now_ns() - t1;
}

/* opt: fused — block-local strip, detect scans it while cache-hot. */
static void mosum_detect_opt(const Scene *sc, const float *resid, float *strip,
                             float *momax, int *first, int *breaks,
                             uint64_t *mosum_ns, uint64_t *detect_ns) {
    int m = sc->m, n = sc->n_hist, h = sc->h, n_mon = sc->n_mon;
    double dof = (double)(n - sc->p);
    uint64_t mns = 0, dns = 0;
    for (int s = 0; s < m; s += BLOCK) {
        uint64_t t0 = now_ns();
        int e = s + BLOCK < m ? s + BLOCK : m;
        int w = e - s;
        double sigma[BLOCK], acc[BLOCK];
        for (int j = 0; j < w; j++) sigma[j] = 0.0;
        for (int t = 0; t < n; t++) {
            const float *row = &resid[(size_t)t * m + s];
            for (int j = 0; j < w; j++)
                sigma[j] += (double)row[j] * (double)row[j];
        }
        double sqrt_n = sqrt((double)n);
        for (int j = 0; j < w; j++) sigma[j] = sqrt(sigma[j] / dof) * sqrt_n;
        for (int j = 0; j < w; j++) acc[j] = 0.0;
        for (int t = n + 1 - h; t <= n; t++) {
            const float *row = &resid[(size_t)t * m + s];
            for (int j = 0; j < w; j++) acc[j] += (double)row[j];
        }
        for (int j = 0; j < w; j++)
            strip[(size_t)0 * w + j] = (float)(acc[j] / sigma[j]);
        for (int ti = 1; ti < n_mon; ti++) {
            const float *add = &resid[(size_t)(n + ti) * m + s];
            const float *sub = &resid[(size_t)(n + ti - h) * m + s];
            float *out = &strip[(size_t)ti * w];
            for (int j = 0; j < w; j++) {
                acc[j] += (double)add[j] - (double)sub[j];
                out[j] = (float)(acc[j] / sigma[j]);
            }
        }
        uint64_t t1 = now_ns();
        float mx[BLOCK];
        int fs[BLOCK];
        for (int j = 0; j < w; j++) mx[j] = 0.0f;
        for (int j = 0; j < w; j++) fs[j] = -1;
        for (int ti = 0; ti < n_mon; ti++) {
            float bnd = (float)sc->bound[ti];
            const float *row = &strip[(size_t)ti * w];
            for (int j = 0; j < w; j++) {
                float a = fabsf(row[j]);
                if (a > mx[j]) mx[j] = a;
                if (fs[j] < 0 && a > bnd) fs[j] = ti;
            }
        }
        for (int j = 0; j < w; j++) {
            breaks[s + j] = fs[j] >= 0 ? 1 : 0;
            first[s + j] = fs[j];
            momax[s + j] = mx[j];
        }
        uint64_t t2 = now_ns();
        mns += t1 - t0;
        dns += t2 - t1;
    }
    *mosum_ns = mns;
    *detect_ns = dns;
}

/* ------------------------ bitwise validation ------------------------ */

/* special-value-laden fill: exact zeros, -0.0, NaN, ±inf among finite */
static void fill_special(float *v, size_t len) {
    for (size_t i = 0; i < len; i++) {
        uint32_t r = rnd32() % 16;
        if (r <= 2)
            v[i] = 0.0f;
        else if (r == 3)
            v[i] = -0.0f;
        else if (r == 4)
            v[i] = NAN;
        else if (r == 5)
            v[i] = INFINITY;
        else
            v[i] = frand(-2.0f, 2.0f);
    }
}

static int validate_gemm(void) {
    int shapes[][3] = {{1, 1, 1},    {3, 5, 7},     {4, 128, 31},
                       {5, 129, 33}, {7, 127, 40},  {8, 100, 2049},
                       {13, 260, 70}, {6, 5, 2047},  {200, 8, 1031}};
    int bad = 0;
    for (size_t s = 0; s < sizeof(shapes) / sizeof(shapes[0]); s++) {
        int m = shapes[s][0], k = shapes[s][1], n = shapes[s][2];
        float *a = malloc((size_t)m * k * sizeof(float));
        float *b = malloc((size_t)k * n * sizeof(float));
        float *c1 = malloc((size_t)m * n * sizeof(float));
        float *c2 = malloc((size_t)m * n * sizeof(float));
        fill_special(a, (size_t)m * k);
        fill_special(b, (size_t)k * n);
        gemm(gemm_cols_seed, m, k, n, a, b, c1);
        gemm(gemm_cols_opt, m, k, n, a, b, c2);
        if (memcmp(c1, c2, (size_t)m * n * sizeof(float)) != 0) {
            printf("VALIDATE gemm m=%d k=%d n=%d MISMATCH\n", m, k, n);
            bad = 1;
        }
        free(a); free(b); free(c1); free(c2);
    }
    if (!bad) printf("VALIDATE gemm seed==opt bitwise over %zu shapes ok\n",
                     sizeof(shapes) / sizeof(shapes[0]));
    return bad;
}

static double log_plus(double x) { return x <= M_E ? 1.0 : log(x); }

static Scene make_scene(int m, int n_total, int n_hist, int h, int p,
                        double lambda) {
    Scene sc = {m, n_total, n_hist, h, n_total - n_hist, p, NULL};
    sc.bound = malloc((size_t)sc.n_mon * sizeof(double));
    for (int ti = 0; ti < sc.n_mon; ti++) {
        double t = (double)(n_hist + ti + 1);
        sc.bound[ti] = lambda * sqrt(log_plus(t / (double)n_hist));
    }
    return sc;
}

static int validate_mosum(void) {
    Scene sc = make_scene(1337, 200, 100, 50, 8, 2.5);
    size_t rm = (size_t)sc.n_total * sc.m;
    float *resid = malloc(rm * sizeof(float));
    for (size_t i = 0; i < rm; i++) resid[i] = frand(-1.5f, 1.5f);
    /* NaN gaps: a few all-NaN pixels and scattered single-layer gaps */
    for (int t = 0; t < sc.n_total; t++) resid[(size_t)t * sc.m + 7] = NAN;
    for (int g = 0; g < 500; g++)
        resid[((size_t)(rnd32() % sc.n_total)) * sc.m + rnd32() % sc.m] = NAN;

    float *mo = malloc((size_t)sc.n_mon * sc.m * sizeof(float));
    float *strip = malloc((size_t)sc.n_mon * BLOCK * sizeof(float));
    float *mx1 = malloc(sc.m * sizeof(float)), *mx2 = malloc(sc.m * sizeof(float));
    int *f1 = malloc(sc.m * sizeof(int)), *f2 = malloc(sc.m * sizeof(int));
    int *b1 = malloc(sc.m * sizeof(int)), *b2 = malloc(sc.m * sizeof(int));
    uint64_t x, y;
    mosum_detect_seed(&sc, resid, mo, mx1, f1, b1, &x, &y);
    mosum_detect_opt(&sc, resid, strip, mx2, f2, b2, &x, &y);
    int bad = memcmp(mx1, mx2, sc.m * sizeof(float)) ||
              memcmp(f1, f2, sc.m * sizeof(int)) ||
              memcmp(b1, b2, sc.m * sizeof(int));
    printf(bad ? "VALIDATE mosum seed vs opt MISMATCH\n"
               : "VALIDATE mosum seed==opt bitwise (momax/first/breaks, NaN-laden) ok\n");
    free(resid); free(mo); free(strip);
    free(mx1); free(mx2); free(f1); free(f2); free(b1); free(b2);
    free(sc.bound);
    return bad;
}

/* ------------------------- pipeline timing -------------------------- */

typedef struct {
    uint64_t model, predict, resid, mosum, detect;
} PhaseNs;

static void run_pipeline(int variant_opt, const Scene *sc, const float *y,
                         const float *mmat, const float *xt, PhaseNs *ph) {
    int m = sc->m, N = sc->n_total, n = sc->n_hist, p = sc->p;
    gemm_cols_fn f = variant_opt ? gemm_cols_opt : gemm_cols_seed;

    float *beta = malloc((size_t)p * m * sizeof(float));
    float *yhat = malloc((size_t)N * m * sizeof(float));

    uint64_t t0 = now_ns();
    gemm(f, p, n, m, mmat, y, beta); /* create model: uses Y[:n] rows */
    uint64_t t1 = now_ns();
    gemm(f, N, p, m, xt, beta, yhat); /* predictions */
    uint64_t t2 = now_ns();
    float *resid = yhat; /* reuse, like the Rust engine */
    for (size_t i = 0; i < (size_t)N * m; i++) resid[i] = y[i] - resid[i];
    uint64_t t3 = now_ns();

    float *momax = malloc(m * sizeof(float));
    int *first = malloc(m * sizeof(int));
    int *breaks = malloc(m * sizeof(int));
    uint64_t mns, dns;
    if (variant_opt) {
        float *strip = malloc((size_t)sc->n_mon * BLOCK * sizeof(float));
        mosum_detect_opt(sc, resid, strip, momax, first, breaks, &mns, &dns);
        free(strip);
    } else {
        float *mo = malloc((size_t)sc->n_mon * m * sizeof(float));
        mosum_detect_seed(sc, resid, mo, momax, first, breaks, &mns, &dns);
        free(mo);
    }
    ph->model = t1 - t0;
    ph->predict = t2 - t1;
    ph->resid = t3 - t2;
    ph->mosum = mns;
    ph->detect = dns;
    free(beta); free(yhat); free(momax); free(first); free(breaks);
}

static void time_scenario(const char *name, int m) {
    /* paper_synthetic: N=200 n=100 h=50 k=3 → p = 2 + 2k = 8 */
    int N = 200, n = 100, h = 50, k = 3, p = 2 + 2 * k;
    Scene sc = make_scene(m, N, n, h, p, 2.5);

    /* seasonal scene + noise + NaN gaps, like ArtificialDataset */
    rng_state = 42;
    float *y = malloc((size_t)N * m * sizeof(float));
    for (int t = 0; t < N; t++) {
        float tv = (float)(t + 1);
        for (int j = 0; j < m; j++) {
            float s = sinf(2.0f * (float)M_PI * tv / 23.0f + (float)(j % 7));
            y[(size_t)t * m + j] = s + frand(-0.3f, 0.3f);
        }
    }
    for (int g = 0; g < m / 20; g++) /* ~5% of pixels get one gap */
        y[((size_t)(rnd32() % N)) * m + rnd32() % m] = NAN;

    /* design-shaped operands: M (p × n), Xᵀ (N × p) with intercept 1 */
    float *mmat = malloc((size_t)p * n * sizeof(float));
    for (size_t i = 0; i < (size_t)p * n; i++) mmat[i] = frand(-0.1f, 0.1f);
    float *xt = malloc((size_t)N * p * sizeof(float));
    for (int t = 0; t < N; t++) {
        float tv = (float)(t + 1);
        xt[(size_t)t * p + 0] = 1.0f;
        xt[(size_t)t * p + 1] = tv;
        for (int q = 1; q <= k; q++) {
            float ang = 2.0f * (float)M_PI * (float)q * tv / 23.0f;
            xt[(size_t)t * p + 2 * q] = sinf(ang);
            xt[(size_t)t * p + 2 * q + 1] = cosf(ang);
        }
    }

    for (int variant = 0; variant < 2; variant++) {
        const char *vn = variant ? "opt" : "seed";
        PhaseNs ph;
        run_pipeline(variant, &sc, y, mmat, xt, &ph); /* warmup */
        for (int trial = 0; trial < 5; trial++) {
            run_pipeline(variant, &sc, y, mmat, xt, &ph);
            uint64_t total =
                ph.model + ph.predict + ph.resid + ph.mosum + ph.detect;
            printf("RESULT variant=%s scenario=%s m=%d trial=%d "
                   "model=%llu predict=%llu resid=%llu mosum=%llu "
                   "detect=%llu total=%llu\n",
                   vn, name, m, trial, (unsigned long long)ph.model,
                   (unsigned long long)ph.predict,
                   (unsigned long long)ph.resid,
                   (unsigned long long)ph.mosum,
                   (unsigned long long)ph.detect,
                   (unsigned long long)total);
            fflush(stdout);
        }
    }
    free(y); free(mmat); free(xt); free(sc.bound);
}

int main(void) {
    if (validate_gemm() || validate_mosum()) {
        fprintf(stderr, "bitwise validation FAILED — refusing to time\n");
        return 1;
    }
    time_scenario("fig2", 20000);
    time_scenario("fig3", 50000);
    return 0;
}
