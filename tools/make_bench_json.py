#!/usr/bin/env python3
"""Turn `kernel_replica` RESULT lines into the committed trajectory
reports (BENCH_PR6_BASELINE.json from the seed variant, BENCH_PR6.json
from the optimised one).

The output is byte-identical to the Rust `BenchReport::save` canonical
form: `json.dumps(indent=1)` matches the in-tree pretty writer (newline
+ one space per nesting level, `"key": value`), and whole-number floats
are emitted as ints the way `write_num` does.

Usage: kernel_replica | python3 tools/make_bench_json.py <git_rev> <outdir>
"""

import json
import sys

SCENARIOS = {
    "fig2": {
        "about": "paper-shaped synthetic scene, implementation comparison",
        "n_total": 200, "n_hist": 100, "h": 50, "k": 3, "seed": 42,
    },
    "fig3": {
        "about": "per-phase breakdown through the coordinated pipeline",
        "n_total": 200, "n_hist": 100, "h": 50, "k": 3, "seed": 42,
    },
}
PHASES = [
    ("model", "create model"),
    ("predict", "predictions"),
    ("resid", "residuals"),
    ("mosum", "mosum"),
    ("detect", "detect breaks"),
]


def median(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2] if len(xs) % 2 else (xs[len(xs) // 2 - 1] + xs[len(xs) // 2]) // 2


def main():
    git_rev, outdir = sys.argv[1], sys.argv[2]
    # runs[variant][scenario] = {"m": int, "trials": [dict per trial]}
    runs = {}
    for line in sys.stdin:
        if not line.startswith("RESULT "):
            continue
        kv = dict(f.split("=", 1) for f in line.split()[1:])
        sc = runs.setdefault(kv["variant"], {}).setdefault(
            kv["scenario"], {"m": int(kv["m"]), "trials": []})
        sc["trials"].append({k: int(kv[k]) for k, _ in PHASES} | {"total": int(kv["total"])})

    out_names = {"seed": "BENCH_PR6_BASELINE.json", "opt": "BENCH_PR6.json"}
    for variant, fname in out_names.items():
        scenarios = []
        for name, meta in SCENARIOS.items():
            sc = runs[variant][name]
            totals = [t["total"] for t in sc["trials"]]
            scenarios.append({
                "scenario": name,
                "about": meta["about"],
                "m": sc["m"],
                "n_total": meta["n_total"],
                "n_hist": meta["n_hist"],
                "h": meta["h"],
                "k": meta["k"],
                "seed": meta["seed"],
                "engines": [{
                    "engine": "fused-cpu",
                    "trials_ns": totals,
                    "median_ns": median(totals),
                    "min_ns": min(totals),
                    "phases_ns": {
                        label: median([t[key] for t in sc["trials"]])
                        for key, label in PHASES
                    },
                }],
            })
        report = {
            "version": 1,
            "fingerprint": {
                "host_threads": 1,
                "cargo_profile": "release",
                "git_rev": git_rev,
                "scale": 1,
                "warmup": 1,
                "trials": 5,
                "source": "kernel-replica-c",
            },
            "scenarios": scenarios,
        }
        path = f"{outdir}/{fname}"
        with open(path, "w") as f:
            f.write(json.dumps(report, indent=1) + "\n")
        fig2 = scenarios[0]["engines"][0]
        print(f"{path}: fig2 fused-cpu median {fig2['median_ns']} ns")


if __name__ == "__main__":
    main()
