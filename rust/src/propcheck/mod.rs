//! Property-based testing substrate (replaces `proptest` for the
//! offline build).
//!
//! A property runs many times against randomly generated inputs drawn
//! from a [`Gen`]; on failure the failing case and its reproduction
//! seed are reported. Used by the coordinator/raster/linalg test
//! suites for invariants (routing, chunk coverage, state machines).
//!
//! ```no_run
//! # // no_run: doctest binaries miss the xla_extension rpath
//! use bfast::propcheck::{property, Gen};
//! property("reverse twice is identity", 64, |g| {
//!     let xs = g.vec_u32(0..=100, 0..=32);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     if ys == xs { Ok(()) } else { Err(format!("{xs:?}")) }
//! });
//! ```

use crate::prng::Pcg32;
use std::ops::RangeInclusive;

/// Random input source handed to each property run.
pub struct Gen {
    rng: Pcg32,
    /// Size hint grows with the run index so early runs are small
    /// (cheap smoke) and later runs stress larger inputs.
    pub size: usize,
}

impl Gen {
    pub fn u32(&mut self, range: RangeInclusive<u32>) -> u32 {
        let (lo, hi) = (*range.start(), *range.end());
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn usize(&mut self, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        lo + self.rng.below((hi - lo + 1) as u32) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn vec_u32(&mut self, elem: RangeInclusive<u32>, len: RangeInclusive<usize>) -> Vec<u32> {
        let n = self.usize(len);
        (0..n).map(|_| self.u32(elem.clone())).collect()
    }

    pub fn vec_f32(&mut self, lo: f64, hi: f64, len: RangeInclusive<usize>) -> Vec<f32> {
        let n = self.usize(len);
        (0..n).map(|_| self.f64(lo, hi) as f32).collect()
    }

    /// Access the raw generator for custom draws.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `prop` against `runs` random inputs. Panics (test failure) on
/// the first counter-example, printing the case description returned
/// by the property and the seed that reproduces it.
///
/// Seed override: set `BFAST_PROP_SEED` to replay a failure.
pub fn property<F>(name: &str, runs: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = std::env::var("BFAST_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xB0F5_7A57_u64);
    for run in 0..runs {
        let seed = base_seed.wrapping_add(run as u64);
        let mut g = Gen { rng: Pcg32::new(seed), size: 4 + run * 4 };
        if let Err(case) = prop(&mut g) {
            panic!(
                "property {name:?} failed on run {run}/{runs}\n  case: {case}\n  \
                 reproduce with BFAST_PROP_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        property("u32 in range", 100, |g| {
            let x = g.u32(3..=9);
            if (3..=9).contains(&x) { Ok(()) } else { Err(format!("{x}")) }
        });
    }

    #[test]
    fn vec_len_respected() {
        property("vec len", 50, |g| {
            let v = g.vec_u32(0..=10, 2..=5);
            if (2..=5).contains(&v.len()) { Ok(()) } else { Err(format!("{v:?}")) }
        });
    }

    #[test]
    #[should_panic(expected = "reproduce with BFAST_PROP_SEED=")]
    fn failing_property_reports_seed() {
        property("always fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn size_grows() {
        let mut sizes = Vec::new();
        property("size", 5, |g| {
            sizes.push(g.size);
            Ok(())
        });
        assert!(sizes.windows(2).all(|w| w[0] < w[1]));
    }
}
