//! # bfast — massively-parallel break detection for satellite data
//!
//! A production reproduction of *"Massively-Parallel Break Detection
//! for Satellite Data"* (von Mehren et al., 2018): the BFAST(monitor)
//! structural-change procedure of Verbesselt et al. applied to every
//! pixel of a satellite image time-series stack, executed through an
//! AOT-compiled JAX/Pallas pipeline on an XLA/PJRT device, coordinated
//! from rust.
//!
//! ## Layers
//!
//! * **L3 (this crate)** — the streaming coordinator ([`coordinator`]):
//!   scene source → gap-fill → chunking → staged device transfer →
//!   executor → break-map assembly, plus all CPU baselines
//!   ([`pixel`], [`cpu`]) the paper evaluates against.
//! * **L2/L1 (python/compile)** — the batched BFAST compute graph and
//!   its Pallas MOSUM kernel, lowered once to `artifacts/*.hlo.txt`.
//! * **runtime** ([`runtime`]) — loads those artifacts through the
//!   `xla` crate's PJRT client and executes them from the request path
//!   (no python anywhere near it).
//!
//! ## Quick start
//!
//! ```no_run
//! use bfast::params::BfastParams;
//! use bfast::synth::artificial::ArtificialDataset;
//! use bfast::coordinator::{BfastRunner, RunnerConfig};
//!
//! let params = BfastParams::new(200, 100, 50, 3, 23.0, 0.05).unwrap();
//! let data = ArtificialDataset::new(params.clone(), 10_000, 42).generate();
//! let mut runner = BfastRunner::from_manifest_dir("artifacts", RunnerConfig::default()).unwrap();
//! let result = runner.run(&data.stack, &params).unwrap();
//! println!("{} of {} pixels broke", result.break_count(), result.len());
//! ```
//!
//! Substrate modules ([`prng`], [`linalg`], [`json`], [`threadpool`],
//! [`cli`], [`propcheck`], [`bench_support`]) exist because the build
//! environment is fully offline — see DESIGN.md §3.

pub mod bench_support;
pub mod cli;
pub mod coordinator;
pub mod cpu;
pub mod design;
pub mod fill;
pub mod history;
pub mod json;
pub mod lambda;
pub mod linalg;
pub mod metrics;
pub mod mosum;
pub mod params;
pub mod pixel;
pub mod prng;
pub mod propcheck;
pub mod raster;
pub mod report;
pub mod runtime;
pub mod synth;
pub mod threadpool;

/// Crate-wide result type (anyhow is the only error dependency).
pub type Result<T> = anyhow::Result<T>;
