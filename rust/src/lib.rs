//! # bfast — massively-parallel break detection for satellite data
//!
//! A production reproduction of *"Massively-Parallel Break Detection
//! for Satellite Data"* (von Mehren et al., 2018): the BFAST(monitor)
//! structural-change procedure of Verbesselt et al. applied to every
//! pixel of a satellite image time-series stack, coordinated from
//! rust against a pluggable executor backend.
//!
//! ## Layers
//!
//! * **Front door ([`api`])** — the typed request/response facade
//!   every entry point speaks: [`api::AnalysisRequest`] (scene source
//!   + params + engine + chunking + outputs, with a canonical JSON
//!   wire form) and [`api::SessionRequest`] for monitor init/ingest,
//!   executed under an [`api::JobHandle`] (progress observation +
//!   cooperative cancellation). The CLI parses flags into it, the
//!   server queues it, `bfast client` posts it, the library executes
//!   it — one vocabulary, so a request can be logged, forwarded,
//!   replayed, or split by pixel range across shards. The **back
//!   door matches**: every run returns an [`api::AnalysisResult`]
//!   with its own canonical v1 envelope (lossless `.bten` map
//!   payload, served by `GET /v1/runs/{id}/result`), and per-shard
//!   [`api::PartialResult`]s merge associatively back into the
//!   full-scene bits.
//! * **L6 ([`gateway`])** — the resident fleet coordinator:
//!   `bfast gateway` keeps the `/v1` facade up as a long-lived
//!   process in front of N workers. Workers register and heartbeat
//!   (`POST /v1/workers`; `bfast serve --gateway` self-registers),
//!   placement weights follow each worker's observed chunks/sec
//!   (scraped from its `/metrics`), and a shard whose worker dies
//!   mid-run is re-split across the survivors — still bit-identical
//!   to a single-process run (`tests/gateway.rs`, `tests/chaos.rs`,
//!   with deterministic fault injection via [`gateway::chaos`]).
//! * **Store ([`store`])** — the content-addressed layer under the
//!   serving stack: an in-tree SHA-256 ([`store::hash`], with a
//!   streaming [`store::HashingReader`]) gives every scene a canonical
//!   `scene_digest` and every request a derived `request_digest`
//!   (engine-irrelevant fields excluded); [`store::ResultCache`]
//!   (LRU by bytes) answers repeated requests at the front door of
//!   both serve and gateway with the bit-identical cached envelope —
//!   gateway hits place zero worker traffic; and [`store::compress`]
//!   is the zero-dep DEFLATE/gzip/zlib wire ([`store::AnyDecoder`]
//!   sniffs scene uploads, `Content-Encoding: gzip` request bodies
//!   decode centrally, results compress on `Accept-Encoding: gzip`).
//! * **L5 ([`shard`])** — the fleet layer: `bfast shard` splits one
//!   request by pixel range, fans the slices out across N serve
//!   workers over keep-alive sockets, streams per-shard progress
//!   into one aggregate `JobHandle`, propagates cancellation as a
//!   `DELETE` fan-out, retries failed shards on surviving workers,
//!   and merges the partial results **bit-identically** to a direct
//!   single-process run (`tests/shard.rs`).
//! * **L4 ([`serve`])** — the break-detection service: a
//!   zero-dependency keep-alive HTTP/1.1 front-end (`bfast serve`)
//!   with a bounded job scheduler ([`serve::queue`], cancellation via
//!   `DELETE /v1/runs/{id}`, finished-record eviction policy) and a
//!   persistent registry of live monitor sessions
//!   ([`serve::registry`]), sharing one runner across its worker
//!   threads. Break maps served over the wire are bit-identical to
//!   direct runs (`tests/serve.rs`, `tests/api.rs`).
//! * **Command streams ([`cmd`])** — the chunk contract as data:
//!   [`cmd::Recorder`] captures the per-chunk op sequence (gather →
//!   fill → batched fit → MOSUM → detect → readback) into a versioned
//!   [`cmd::CmdStream`] with a canonical binary form (`.bcmd`, plus a
//!   JSON dump), and [`cmd::ReplayExecutor`] re-executes it through a
//!   translation cache **bit-identically** to the fused CPU engine —
//!   `bfast run --record`, `bfast replay`, `--engine cmd`, and
//!   `GET /v1/runs/{id}/cmdstream` on serve. Multi-job streams are the
//!   scheduler's batching seam: compatible queued requests execute
//!   through one stream on one prepared engine (`tests/cmdstream.rs`).
//! * **L3 ([`coordinator`])** — the streaming coordinator:
//!   scene source → gap-fill → chunking → staged transfer → executor →
//!   break-map assembly, plus all CPU baselines ([`pixel`], [`cpu`])
//!   the paper evaluates against, and the incremental [`monitor`]
//!   subsystem that keeps per-pixel rolling state between satellite
//!   revisits instead of recomputing whole scenes.
//! * **Backends** ([`runtime`]) — the chunk contract is the
//!   [`runtime::ExecutorBackend`] trait. Implementations:
//!   - [`runtime::EmulatedDevice`] (**default**): a pure-rust device
//!     emulator executing the batched BFAST pipeline (history OLS fit
//!     → predictions → MOSUM → break scan) on the [`threadpool`] +
//!     [`linalg`] substrate. No artifacts, no network, no C deps.
//!   - `runtime::pjrt::DeviceRuntime` (**feature `pjrt`**): loads the
//!     AOT HLO artifacts emitted by `python/compile/aot.py` and
//!     executes them through the `xla` crate's PJRT client.
//! * **L2/L1 (python/compile)** — the batched BFAST compute graph and
//!   its Pallas MOSUM kernel, lowered once to `artifacts/*.hlo.txt`
//!   (only consumed by the `pjrt` backend).
//! * **Observability ([`trace`])** — the flight recorder cutting
//!   across every layer above: each run carries a request id (minted
//!   at the front door, propagated as `X-Request-Id` through gateway →
//!   worker), records a span tree **run → shard → chunk → phase**
//!   into a bounded per-run ring, and exports it as Chrome
//!   trace-event JSON (`GET /v1/runs/{id}/trace`, merged across the
//!   fleet by the gateway; Perfetto-loadable). [`trace::log!`] is the
//!   leveled structured logger behind `--log-level`/`--log-format`,
//!   and [`metrics`] renders Prometheus expositions with fixed-bucket
//!   latency histograms (`tests/metrics.rs`, `tests/trace.rs`).
//!
//! ## Backend feature matrix
//!
//! | build                      | backend            | needs artifacts | needs network |
//! |----------------------------|--------------------|-----------------|---------------|
//! | `cargo build` (default)    | `EmulatedDevice`   | no              | no            |
//! | `cargo build -F pjrt`      | `DeviceRuntime`    | yes (`make artifacts`) | no (in-tree `xla` stub; link the real crate for hardware) |
//!
//! Tier-1 verification: `cargo build --release && cargo test -q`.
//!
//! ## Quick start
//!
//! Describe the analysis once, as an [`api::AnalysisRequest`], and
//! execute it — the same request could be posted verbatim to a
//! `bfast serve` instance (`POST /v1/runs`, `Content-Type:
//! application/json`) and would produce the same bits:
//!
//! ```
//! use bfast::api::{AnalysisRequest, EngineSpec, JobHandle, SceneSource};
//! use bfast::params::BfastParams;
//! use bfast::synth::artificial::ArtificialDataset;
//!
//! let params = BfastParams::new(60, 40, 20, 2, 12.0, 0.05).unwrap();
//! let data = ArtificialDataset::new(params.clone(), 500, 42).generate();
//!
//! let mut req = AnalysisRequest::new(SceneSource::Inline(data.stack));
//! req.params = bfast::api::ParamSpec::from_params(&params);
//! req.engine = EngineSpec::Emulated;
//!
//! let handle = JobHandle::new(); // progress + cancellation
//! let result = req.execute(&handle).unwrap();
//! println!("{} of {} pixels broke", result.map.break_count(), result.map.len());
//! assert_eq!(handle.progress().0, handle.progress().1); // all chunks ran
//!
//! // the request itself is the wire/job description:
//! let wire = req.to_json_string();
//! let replay = AnalysisRequest::from_json_str(&wire).unwrap();
//! # let _ = replay;
//! ```
//!
//! The long-form coordinator API ([`coordinator::BfastRunner`])
//! remains available underneath for callers that manage their own
//! backends and stacks.
//!
//! ## Monitoring workflow (near-real-time ingest)
//!
//! A fresh `run` refits every pixel from scratch; operationally a new
//! layer arrives every 8–16 days and only the monitor period grows.
//! [`monitor::MonitorSession`] runs the history pass once, then
//! absorbs one layer at a time in O(m·p) — bit-identical to a fresh
//! run over the grown archive at every step:
//!
//! ```
//! use bfast::params::BfastParams;
//! use bfast::synth::artificial::ArtificialDataset;
//! use bfast::coordinator::{BfastRunner, RunnerConfig};
//!
//! let full = BfastParams::new(60, 40, 20, 2, 12.0, 0.05).unwrap();
//! let gen = ArtificialDataset::new(full.clone(), 200, 42);
//! let data = gen.generate();
//!
//! // 1. one-time history pass over the archive as of layer 41
//! let init = data.stack.prefix(41).unwrap();
//! let mut p0 = full.clone();
//! p0.n_total = 41;
//! let runner = BfastRunner::emulated(RunnerConfig::default()).unwrap();
//! let mut session = runner.start_monitor(&init, &p0).unwrap();
//!
//! // 2. ingest each new acquisition as it arrives (here: streamed)
//! for (t, layer) in gen.stream().skip(41) {
//!     let delta = session.ingest(t, &layer).unwrap();
//!     if !delta.new_breaks.is_empty() {
//!         println!("t={t}: {} new breaks", delta.new_breaks.len());
//!     }
//! }
//! assert_eq!(session.n_seen(), 60);
//!
//! // 3. persist / resume across process restarts
//! let dir = std::env::temp_dir().join("bfast-doc-session");
//! session.save(&dir).unwrap();
//! let resumed = bfast::monitor::MonitorSession::load(&dir, 4).unwrap();
//! assert_eq!(resumed.break_count(), session.break_count());
//! # std::fs::remove_dir_all(dir).ok();
//! ```
//!
//! The state directory holds `session.json` plus `state_*.bten`
//! tensors (β̂, σ̂√n, the last-h residual ring, MOSUM accumulator,
//! break scan, forward-fill values); the CLI front-end is
//! `bfast monitor --state dir/` (see README).
//!
//! Substrate modules ([`prng`], [`linalg`], [`json`], [`threadpool`],
//! [`cli`], [`propcheck`], [`bench_support`], [`error`]) exist because
//! the build environment is fully offline — see DESIGN.md §3.

pub mod api;
pub mod b64;
pub mod bench;
pub mod bench_support;
pub mod cli;
pub mod cmd;
pub mod coordinator;
pub mod cpu;
pub mod design;
pub mod error;
pub mod fill;
pub mod gateway;
pub mod history;
pub mod json;
pub mod lambda;
pub mod linalg;
pub mod metrics;
pub mod monitor;
pub mod mosum;
pub mod params;
pub mod pixel;
pub mod prng;
pub mod propcheck;
pub mod raster;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod store;
pub mod synth;
pub mod threadpool;
pub mod trace;

pub use error::{BfastError, Context, Result};
