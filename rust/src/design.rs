//! Season-trend design matrix (paper Eq. 1–2 / Alg. 1 step 1).
//!
//! X ∈ R^{(2+2k)×N} with row layout
//! `[1, t/f, sin(2π·1·t/f), cos(2π·1·t/f), …, sin(2π·k·t/f), cos(2π·k·t/f)]`.
//!
//! The trend regressor is t/f (time in *periods*, e.g. years) rather
//! than the raw index t — an exact reparameterisation of Eq. (1) that
//! keeps the Gram matrix well-conditioned in f32. Identical convention
//! in `python/compile/model.py` and `ref.py`.

use crate::linalg::Mat;
use crate::params::BfastParams;

/// Regular time axis 1..=N (the §4.2 artificial-data setting).
pub fn regular_time_axis(n_total: usize) -> Vec<f64> {
    (1..=n_total).map(|t| t as f64).collect()
}

/// Build X from an arbitrary time axis (supports the §4.3 irregular
/// Landsat day-of-year axis).
pub fn design_matrix(t: &[f64], freq: f64, k: usize) -> Mat {
    let n = t.len();
    let p = 2 + 2 * k;
    Mat::from_fn(p, n, |row, col| {
        let ty = t[col] / freq;
        match row {
            0 => 1.0,
            1 => ty,
            _ => {
                let j = (row - 2) / 2 + 1;
                let w = 2.0 * std::f64::consts::PI * j as f64 * ty;
                if row % 2 == 0 {
                    w.sin()
                } else {
                    w.cos()
                }
            }
        }
    })
}

/// Design matrix for [`BfastParams`] on the regular axis.
pub fn design_for(params: &BfastParams) -> Mat {
    design_matrix(&regular_time_axis(params.n_total), params.freq, params.k)
}

/// The paper's fused precomputation (Eq. 8):
/// `M = (X_h X_hᵀ)⁻¹ X_h ∈ R^{p×n}` with X_h the history columns.
/// Shared by every pixel of a scene — computed once per analysis.
pub fn history_pinv(x: &Mat, n_hist: usize) -> crate::error::Result<Mat> {
    let p = x.rows();
    let xh = Mat::from_fn(p, n_hist, |i, j| x[(i, j)]);
    xh.pinv_wide()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_matches_paper() {
        let t = regular_time_axis(46);
        let x = design_matrix(&t, 23.0, 2);
        assert_eq!(x.rows(), 6);
        assert_eq!(x.cols(), 46);
        // row 0: intercept
        assert!(x.row(0).iter().all(|&v| v == 1.0));
        // row 1: trend t/f
        assert!((x[(1, 0)] - 1.0 / 23.0).abs() < 1e-12);
        assert!((x[(1, 45)] - 2.0).abs() < 1e-12);
        // rows 2,3: first harmonic
        let w = 2.0 * std::f64::consts::PI * 5.0 / 23.0;
        assert!((x[(2, 4)] - w.sin()).abs() < 1e-12);
        assert!((x[(3, 4)] - w.cos()).abs() < 1e-12);
        // rows 4,5: second harmonic (j = 2)
        let w2 = 2.0 * w;
        assert!((x[(4, 4)] - w2.sin()).abs() < 1e-12);
        assert!((x[(5, 4)] - w2.cos()).abs() < 1e-12);
    }

    #[test]
    fn harmonics_period_exactly_f() {
        // sin/cos rows must repeat with period f on the regular axis
        let t = regular_time_axis(92);
        let x = design_matrix(&t, 23.0, 3);
        for row in 2..8 {
            for col in 0..(92 - 23) {
                assert!(
                    (x[(row, col)] - x[(row, col + 23)]).abs() < 1e-9,
                    "row {row} col {col}"
                );
            }
        }
    }

    #[test]
    fn pinv_identity_on_design_rows() {
        let p = BfastParams::paper_synthetic();
        let x = design_for(&p);
        let m = history_pinv(&x, p.n_hist).unwrap();
        assert_eq!((m.rows(), m.cols()), (p.p(), p.n_hist));
        // M · X_hᵀ = I_p
        let xh = Mat::from_fn(p.p(), p.n_hist, |i, j| x[(i, j)]);
        let id = m.matmul(&xh.transpose()).unwrap();
        assert!(id.dist(&Mat::eye(p.p())) < 1e-8);
    }

    #[test]
    fn irregular_axis_supported() {
        let t = vec![1.5, 18.0, 33.2, 49.9, 65.0, 81.7, 97.4, 113.0, 130.1, 145.8];
        let x = design_matrix(&t, 365.0, 1);
        assert_eq!((x.rows(), x.cols()), (4, 10));
        assert!((x[(1, 2)] - 33.2 / 365.0).abs() < 1e-12);
    }
}
