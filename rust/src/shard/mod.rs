//! Sharded fan-out: one analysis, many `bfast serve` workers.
//!
//! The paper's thesis is that break detection scales by partitioning
//! the scene across parallel compute; PR 4 made every
//! [`AnalysisRequest`] pixel-range-partitionable for exactly this
//! moment. This module is the coordinator that turns one process into
//! a fleet:
//!
//! ```text
//!            ┌─ slice [0, m/2)   ──POST──▶ worker A ──▶ PartialResult ─┐
//!  request ──┤                                                         ├─ merge ─▶ AnalysisResult
//!            └─ slice [m/2, m)   ──POST──▶ worker B ──▶ PartialResult ─┘
//! ```
//!
//! * [`split`] partitions a request by pixel range — the shards differ
//!   **only** in `chunking.pixel_range`, so
//!   `merge(split(req, k))` is bit-identical to the unsharded run
//!   (property-pinned in `tests/shard.rs` for k ∈ {1, 2, 3, 7}).
//! * [`run_sharded`] drives the fan-out over real sockets on the
//!   keep-alive [`http::Client`]: submit each slice (backing off on
//!   429 `Retry-After`), stream per-shard chunk progress into **one
//!   aggregate [`JobHandle`]**, propagate cancellation as a
//!   `DELETE /v1/runs/{id}` fan-out to every in-flight shard, retry a
//!   failed shard on a surviving worker, fetch each worker's
//!   `GET /v1/runs/{id}/result`, and fold the [`PartialResult`]s back
//!   into the full-scene [`AnalysisResult`] — bit-identical to a
//!   direct `BfastRunner::run` of the same scene.
//!
//! The CLI front-end is `bfast shard --workers a:port,b:port --input
//! scene.bsq` (see the README's "Sharded serving" walkthrough).

use crate::api::{
    self, AnalysisRequest, AnalysisResult, ChunkSpec, EngineSpec, JobHandle, ParamSpec,
    PartialResult, SceneSource,
};
use crate::cli::{Command, Matches};
use crate::error::{bail, ensure, err, BfastError, Context, Result};
use crate::json;
use crate::raster::TimeStack;
use crate::serve::http::{self, Client};
use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Fan-out knobs (`bfast shard` flags).
#[derive(Clone, Debug)]
pub struct ShardOptions {
    /// Shard count; 0 = one shard per worker.
    pub shards: usize,
    /// Per-shard job status poll interval.
    pub poll: Duration,
    /// Placement attempts per shard across workers (0 = one per
    /// worker): attempt `n` for shard `i` starts from slot
    /// `(i + n) % workers` and then skips forward past any worker
    /// already found dead this run, so a retry always lands on a
    /// *surviving* worker when there is one.
    pub attempts: usize,
    /// Bounded 429-backoff tries per placement.
    pub submit_attempts: usize,
    /// Per-I/O timeout on worker sockets (connect, read, write): a
    /// black-holed worker surfaces as a transport error after this
    /// long instead of pinning a shard thread.
    pub io_timeout: Duration,
}

impl Default for ShardOptions {
    fn default() -> Self {
        Self {
            shards: 0,
            poll: Duration::from_millis(50),
            attempts: 0,
            submit_attempts: 8,
            io_timeout: Duration::from_secs(30),
        }
    }
}

/// The single-placement subset of [`ShardOptions`] —
/// what [`place_on_worker`] needs to drive one shard on one worker.
#[derive(Clone)]
pub struct PlaceOptions {
    /// Job status poll interval.
    pub poll: Duration,
    /// Bounded 429-backoff tries for the submit.
    pub submit_attempts: usize,
    /// Per-I/O timeout on the worker socket.
    pub io_timeout: Duration,
    /// Sent as the `X-Request-Id` header on the shard submit, so the
    /// worker's flight recorder keys its trace to the coordinator's
    /// request id.
    pub request_id: Option<String>,
    /// Observer invoked with the worker-side job id as soon as the
    /// submit is accepted — *before* polling begins — so a resident
    /// coordinator can record the placement (and later fetch its
    /// worker trace) even when the placement subsequently fails.
    pub on_submit: Option<std::sync::Arc<dyn Fn(u64) + Send + Sync>>,
}

impl std::fmt::Debug for PlaceOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlaceOptions")
            .field("poll", &self.poll)
            .field("submit_attempts", &self.submit_attempts)
            .field("io_timeout", &self.io_timeout)
            .field("request_id", &self.request_id)
            .field("on_submit", &self.on_submit.as_ref().map(|_| "<fn>"))
            .finish()
    }
}

impl From<&ShardOptions> for PlaceOptions {
    fn from(o: &ShardOptions) -> Self {
        Self {
            poll: o.poll,
            submit_attempts: o.submit_attempts,
            io_timeout: o.io_timeout,
            request_id: None,
            on_submit: None,
        }
    }
}

/// Why a placement failed — the classification a coordinator's
/// recovery policy turns on:
///
/// * [`PlaceError::WorkerDown`] — the *worker* is the problem
///   (connect/transport failure, 5xx, a poll that found the job gone):
///   re-placing the same shard on a different worker can succeed, and
///   the worker should be skipped for the rest of the run.
/// * [`PlaceError::Job`] — the *job* is the problem (4xx, the analysis
///   failed, the caller cancelled): the same placement would fail on
///   any worker, so don't burn the fleet retrying it.
#[derive(Debug)]
pub enum PlaceError {
    WorkerDown(BfastError),
    Job(BfastError),
}

impl PlaceError {
    pub fn inner(&self) -> &BfastError {
        match self {
            PlaceError::WorkerDown(e) | PlaceError::Job(e) => e,
        }
    }

    pub fn into_inner(self) -> BfastError {
        match self {
            PlaceError::WorkerDown(e) | PlaceError::Job(e) => e,
        }
    }

    /// The caller's own [`JobHandle`] was cancelled (always a
    /// [`PlaceError::Job`]).
    pub fn is_cancelled(&self) -> bool {
        api::is_cancelled(self.inner())
    }
}

/// What one successful placement produced.
#[derive(Debug)]
pub struct Placement {
    pub partial: PartialResult,
    /// Chunks the worker executed for this shard.
    pub chunks: usize,
    /// The worker-side wall time of the shard run.
    pub wall: Duration,
    /// The worker-side job id (for follow-up queries against the
    /// worker — e.g. `GET /v1/runs/{job}/trace`).
    pub job: u64,
}

/// How one shard fared (the `bfast shard` report table).
#[derive(Clone, Debug)]
pub struct ShardReport {
    pub shard: usize,
    /// Full-scene pixel range this shard covered.
    pub pixel_range: (usize, usize),
    /// The worker that completed it.
    pub worker: String,
    /// Placements tried (1 = the first worker succeeded).
    pub attempts: usize,
    pub chunks: usize,
    pub wall: Duration,
}

/// What [`run_sharded`] returns: the merged full-scene result plus the
/// per-shard placement report.
#[derive(Debug)]
pub struct ShardedRun {
    pub result: AnalysisResult,
    pub shards: Vec<ShardReport>,
}

/// Partition `[0, pixels)` into at most `k` contiguous ranges, sized
/// within one pixel of each other. Shards that would be empty (k >
/// pixels) are omitted — every returned range is non-empty.
pub fn split_ranges(pixels: usize, k: usize) -> Vec<(usize, usize)> {
    if pixels == 0 {
        return Vec::new();
    }
    let k = k.clamp(1, pixels);
    let base = pixels / k;
    let extra = pixels % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let width = base + usize::from(i < extra);
        out.push((start, start + width));
        start += width;
    }
    debug_assert_eq!(start, pixels);
    out
}

/// Partition `[0, pixels)` into exactly `weights.len()` contiguous
/// ranges with widths ∝ the weights (largest-remainder apportionment,
/// index-order tiebreak — fully deterministic). Ranges align
/// positionally with `weights`, so the caller can zip them back to
/// whatever the weights describe (per-worker throughput, say); a range
/// may be **empty** when its weight rounds to zero pixels — skip
/// `(a, b)` with `a == b` when placing.
///
/// Non-finite or non-positive weights are replaced by the mean of the
/// usable (finite, positive) weights — or 1.0 when none are — so a
/// worker with no throughput observation yet gets an average-sized
/// shard rather than none.
pub fn split_weighted(pixels: usize, weights: &[f64]) -> Vec<(usize, usize)> {
    if weights.is_empty() {
        return Vec::new();
    }
    let usable: Vec<f64> =
        weights.iter().copied().filter(|w| w.is_finite() && *w > 0.0).collect();
    let fallback = if usable.is_empty() {
        1.0
    } else {
        usable.iter().sum::<f64>() / usable.len() as f64
    };
    let w: Vec<f64> = weights
        .iter()
        .map(|&x| if x.is_finite() && x > 0.0 { x } else { fallback })
        .collect();
    let total: f64 = w.iter().sum();
    let mut widths = Vec::with_capacity(w.len());
    let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(w.len());
    let mut assigned = 0usize;
    for (i, wi) in w.iter().enumerate() {
        let quota = pixels as f64 * wi / total;
        let floor = quota.floor() as usize;
        widths.push(floor);
        assigned += floor;
        fracs.push((quota - floor as f64, i));
    }
    // hand the remainder out by descending fractional part (cycling if
    // float error left more remainder than weights — harmless)
    fracs.sort_by(|a, b| {
        b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
    });
    let mut remainder = pixels.saturating_sub(assigned);
    let mut i = 0;
    while remainder > 0 {
        widths[fracs[i % fracs.len()].1] += 1;
        remainder -= 1;
        i += 1;
    }
    // float-error insurance in the other direction: trim overshoot
    while assigned > pixels {
        let imax = (0..widths.len()).max_by_key(|&j| widths[j]).unwrap();
        widths[imax] -= 1;
        assigned -= 1;
    }
    let mut out = Vec::with_capacity(widths.len());
    let mut start = 0;
    for width in widths {
        out.push((start, start + width));
        start += width;
    }
    debug_assert_eq!(start, pixels);
    out
}

/// Split one request into at most `k` requests that differ **only** in
/// `chunking.pixel_range` — the partition contract from the request
/// schema. The shards cover the request's own effective range (its
/// existing `pixel_range`, or the whole scene), in order, without gaps
/// or overlap; would-be-empty shards are omitted. Executing every
/// shard and [`PartialResult::assemble`]-ing the outputs reproduces
/// the unsharded run bit-for-bit (`tests/shard.rs`).
pub fn split(req: &AnalysisRequest, k: usize) -> Result<Vec<AnalysisRequest>> {
    ensure!(k >= 1, "cannot split a request into 0 shards");
    let scene = req.source.load()?;
    let (base_start, base_end) = match req.chunking.pixel_range {
        Some((a, b)) => {
            ensure!(
                a < b && b <= scene.n_pixels(),
                "pixel_range [{a}, {b}) out of bounds for {} pixels",
                scene.n_pixels()
            );
            (a, b)
        }
        None => (0, scene.n_pixels()),
    };
    Ok(split_ranges(base_end - base_start, k)
        .into_iter()
        .map(|(a, b)| {
            let mut sub = req.clone();
            sub.chunking.pixel_range = Some((base_start + a, base_start + b));
            sub
        })
        .collect())
}

/// Fan one request out across `workers` (serve addresses) and merge
/// the shard results into the full-scene [`AnalysisResult`] —
/// bit-identical to a direct run of the same request. `handle` is the
/// one aggregate [`JobHandle`]: per-shard chunk progress streams into
/// it, and cancelling it DELETEs every in-flight shard job and returns
/// [`api::cancelled`].
///
/// As with any wire submit, each worker executes under its *own*
/// runner configuration (`AnalysisRequest::execute_on` semantics) —
/// the request's chunking travels for the record, but a worker started
/// with non-default streaming knobs is that operator's choice. The
/// bit-identity contract is pinned against workers running the stock
/// configuration.
pub fn run_sharded(
    req: &AnalysisRequest,
    workers: &[String],
    opts: &ShardOptions,
    handle: &JobHandle,
) -> Result<ShardedRun> {
    ensure!(!workers.is_empty(), "no workers to shard across");
    let (stack, params) = req.resolve()?;
    let pixels = stack.n_pixels();
    ensure!(pixels > 0, "scene has no pixels");
    // pin every parameter (λ included) coordinator-side, so all shards
    // — and any retried placement — analyse under identical numbers
    let pinned = ParamSpec::from_params(&params);
    // one request id for the whole fan-out: minted here when the
    // caller didn't bring one, propagated to every shard submit
    let request_id = req
        .request_id
        .clone()
        .unwrap_or_else(crate::trace::new_request_id);
    let k = if opts.shards == 0 { workers.len() } else { opts.shards };
    let ranges = split_ranges(pixels, k);
    let attempts = if opts.attempts == 0 { workers.len() } else { opts.attempts };

    // (chunks_done, chunks_total) per shard, summed into the handle
    let cells: Vec<(AtomicUsize, AtomicUsize)> =
        ranges.iter().map(|_| Default::default()).collect();
    // worker indices that failed as WorkerDown this run: every shard
    // thread publishes its corpses here, so nobody's *retry* cycles
    // back onto a worker another shard already found dead
    let dead = Mutex::new(HashSet::new());
    let stack = &*stack;
    let cells = &cells;
    let dead = &dead;
    let outcomes: Vec<Result<(PartialResult, ShardReport)>> = std::thread::scope(|scope| {
        let threads: Vec<_> = ranges
            .iter()
            .enumerate()
            .map(|(idx, &range)| {
                let pinned = pinned.clone();
                let engine = &req.engine;
                let chunking = &req.chunking;
                let request_id = request_id.as_str();
                scope.spawn(move || {
                    run_one_shard(
                        idx,
                        range,
                        stack,
                        pinned,
                        engine,
                        chunking,
                        request_id,
                        workers,
                        attempts,
                        opts,
                        handle,
                        cells,
                        dead,
                    )
                })
            })
            .collect();
        threads
            .into_iter()
            .map(|t| {
                t.join()
                    .unwrap_or_else(|_| Err(err!("shard worker thread panicked")))
            })
            .collect()
    });

    let mut parts = Vec::with_capacity(outcomes.len());
    let mut reports = Vec::with_capacity(outcomes.len());
    let mut cancelled = handle.is_cancelled();
    let mut first_err = None;
    for (idx, outcome) in outcomes.into_iter().enumerate() {
        match outcome {
            Ok((partial, report)) => {
                parts.push(partial);
                reports.push(report);
            }
            Err(e) if api::is_cancelled(&e) => cancelled = true,
            Err(e) => {
                first_err.get_or_insert(e.push_context(format!("shard {idx}")));
            }
        }
    }
    if cancelled {
        return Err(api::cancelled());
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    let result = PartialResult::assemble(parts)?.into_full(pixels, stack.width, stack.height)?;
    Ok(ShardedRun { result, shards: reports })
}

/// Publish the sum of all shards' progress cells into the aggregate
/// handle. Racy across shard threads, but each racer writes a
/// self-consistent (done, total) snapshot — good enough for a
/// progress bar, and the final write (all shards done) is exact.
fn publish_progress(handle: &JobHandle, cells: &[(AtomicUsize, AtomicUsize)]) {
    let done = cells.iter().map(|c| c.0.load(Ordering::Relaxed)).sum();
    let total = cells.iter().map(|c| c.1.load(Ordering::Relaxed)).sum();
    handle.set_progress(done, total);
}

#[allow(clippy::too_many_arguments)] // internal plumbing of run_sharded
fn run_one_shard(
    idx: usize,
    range: (usize, usize),
    stack: &TimeStack,
    params: ParamSpec,
    engine: &EngineSpec,
    chunking: &ChunkSpec,
    request_id: &str,
    workers: &[String],
    attempts: usize,
    opts: &ShardOptions,
    handle: &JobHandle,
    cells: &[(AtomicUsize, AtomicUsize)],
    dead: &Mutex<HashSet<usize>>,
) -> Result<(PartialResult, ShardReport)> {
    // The wire form ships only this shard's pixel strip (bandwidth and
    // worker memory ∝ m/k). Slicing here instead of forwarding the
    // full scene + pixel_range is bit-equivalent — pinned by the
    // `pixel_range` / `slice_pixels` test in tests/api.rs. The
    // request's chunking travels with pixel_range cleared (the slice
    // already applied it); like any wire submit, the worker's own
    // runner config governs the streaming knobs at execution. The body
    // is encoded straight from the scene buffer — no intermediate
    // sliced stack — so a fan-out holds one copy per shard, not ~4.
    let body =
        api::slice_request_body(stack, range, &params, engine, chunking, Some(request_id));
    let mut popts = PlaceOptions::from(opts);
    popts.request_id = Some(request_id.to_string());
    let progress = |done: usize, total: usize| {
        cells[idx].0.store(done, Ordering::Relaxed);
        cells[idx].1.store(total, Ordering::Relaxed);
        publish_progress(handle, cells);
    };
    let mut errors: Vec<String> = Vec::new();
    for attempt in 0..attempts.max(1) {
        if handle.is_cancelled() {
            return Err(api::cancelled());
        }
        // rotate from the static home slot, but skip every worker some
        // shard has already found dead this run — a retry must land on
        // a *live* candidate, not cycle blindly onto a known corpse
        let pick = {
            let dead = dead.lock().unwrap();
            (0..workers.len())
                .map(|o| (idx + attempt + o) % workers.len())
                .find(|wi| !dead.contains(wi))
        };
        let Some(wi) = pick else {
            errors.push("every worker is known dead this run".into());
            break;
        };
        let worker = &workers[wi];
        match place_on_worker(worker, &body, range, &popts, handle, &progress) {
            Ok(p) => {
                return Ok((
                    p.partial,
                    ShardReport {
                        shard: idx,
                        pixel_range: range,
                        worker: worker.clone(),
                        attempts: attempt + 1,
                        chunks: p.chunks,
                        wall: p.wall,
                    },
                ));
            }
            Err(e) if e.is_cancelled() => return Err(e.into_inner()),
            Err(e) => {
                if matches!(e, PlaceError::WorkerDown(_)) {
                    dead.lock().unwrap().insert(wi);
                }
                errors.push(format!("{worker}: {:#}", e.inner()));
                // a fresh placement starts from zero chunks
                progress(0, 0);
            }
        }
    }
    bail!(
        "pixels [{}, {}) failed on every worker tried — {}",
        range.0,
        range.1,
        errors.join("; ")
    )
}

/// One placement: submit the pre-serialized request `body` to
/// `worker`, poll the job to completion (streaming `(done, total)`
/// chunk progress through `progress`, honouring cancellation of
/// `handle` as a `DELETE` on the worker), and fetch the typed result
/// as a [`PartialResult`] covering `range`. Failures come back
/// classified as [`PlaceError`] so the caller's recovery policy can
/// distinguish a dead worker (re-place elsewhere) from a doomed job
/// (fail fast). On any non-cancellation failure after submit, the
/// worker-side job is best-effort `DELETE`d so a re-placed shard
/// doesn't leave an orphan computing the same pixels.
///
/// This is the placement primitive shared by the one-shot
/// [`run_sharded`] coordinator and the resident
/// [`crate::gateway`] (which re-splits `range` onto survivors when
/// this returns [`PlaceError::WorkerDown`] mid-run).
pub fn place_on_worker(
    worker: &str,
    body: &str,
    range: (usize, usize),
    opts: &PlaceOptions,
    handle: &JobHandle,
    progress: &(dyn Fn(usize, usize) + Sync),
) -> std::result::Result<Placement, PlaceError> {
    let mut client =
        Client::connect_timeout(worker, opts.io_timeout).map_err(PlaceError::WorkerDown)?;

    // submit, backing off politely while the worker's queue is full
    let mut submit_attempt = 0;
    let job = loop {
        if handle.is_cancelled() {
            return Err(PlaceError::Job(api::cancelled()));
        }
        let mut extra: Vec<(&str, &str)> = Vec::new();
        if let Some(rid) = &opts.request_id {
            extra.push(("X-Request-Id", rid.as_str()));
        }
        let (status, headers, resp) = client
            .request_with_headers("POST", "/v1/runs", "application/json", &extra, body.as_bytes())
            .map_err(PlaceError::WorkerDown)?;
        match status {
            202 => {
                let job = parse_json(&resp)
                    .and_then(|v| Ok(v.get("job")?.as_usize()? as u64))
                    .map_err(PlaceError::Job)?;
                if let Some(observe) = &opts.on_submit {
                    observe(job);
                }
                break job;
            }
            429 if submit_attempt + 1 < opts.submit_attempts.max(1) => {
                std::thread::sleep(http::backoff_delay(
                    submit_attempt,
                    http::retry_after(&headers),
                ));
                submit_attempt += 1;
            }
            s if s >= 500 => {
                return Err(PlaceError::WorkerDown(err!(
                    "submit: HTTP {s}: {}",
                    http::error_message(&resp)
                )));
            }
            _ => {
                return Err(PlaceError::Job(err!(
                    "submit: HTTP {status}: {}",
                    http::error_message(&resp)
                )));
            }
        }
    };

    // The job is live on the worker from here on: any failure below
    // best-effort-DELETEs it before the shard goes elsewhere, so a
    // re-placed shard doesn't leave an orphan computing the same
    // pixels (and squatting on the old worker's queue).
    let out = poll_and_fetch(&mut client, worker, job, range, opts, handle, progress);
    if out.as_ref().is_err_and(|e| !e.is_cancelled()) {
        // the old socket may be dead
        if let Ok(mut c) = Client::connect_timeout(worker, opts.io_timeout) {
            let _ = c.request("DELETE", &format!("/v1/runs/{job}"), "", &[]);
        }
    }
    out
}

fn parse_json(resp: &[u8]) -> Result<json::Value> {
    json::parse(std::str::from_utf8(resp).context("non-UTF-8 response body")?.trim())
}

/// Poll one submitted job to completion and fetch its typed result.
/// Split from [`place_on_worker`] so its caller can reap the job on
/// any failure path.
fn poll_and_fetch(
    client: &mut Client,
    worker: &str,
    job: u64,
    range: (usize, usize),
    opts: &PlaceOptions,
    handle: &JobHandle,
    progress: &(dyn Fn(usize, usize) + Sync),
) -> std::result::Result<Placement, PlaceError> {
    // reconnect once per round if the keep-alive socket dies under us
    // (per-connection request caps, worker restarts mid-poll)
    let get = |client: &mut Client, path: &str| -> Result<(u16, Vec<u8>)> {
        match client.request("GET", path, "", &[]) {
            Ok(out) => Ok(out),
            Err(_) => {
                *client = Client::connect_timeout(worker, opts.io_timeout)?;
                client.request("GET", path, "", &[])
            }
        }
    };
    let status_path = format!("/v1/runs/{job}");
    loop {
        if handle.is_cancelled() {
            // DELETE fan-out: stop this shard's job on the worker
            // (best-effort — the job may have just finished)
            let _ = client.request("DELETE", &status_path, "", &[]);
            return Err(PlaceError::Job(api::cancelled()));
        }
        let (status, resp) = get(client, &status_path).map_err(PlaceError::WorkerDown)?;
        if status != 200 {
            // non-200 on a poll means the worker lost the job (restart,
            // eviction) or is erroring — either way this placement is
            // unrecoverable *here* but fine elsewhere
            return Err(PlaceError::WorkerDown(err!(
                "polling job {job}: HTTP {status}: {}",
                http::error_message(&resp)
            )));
        }
        let v = parse_json(&resp).map_err(PlaceError::Job)?;
        let label =
            v.get("status").and_then(|s| Ok(s.as_str()?.to_string())).map_err(PlaceError::Job)?;
        match label.as_str() {
            "done" => break,
            "failed" => {
                return Err(PlaceError::Job(err!(
                    "job {job} failed: {}",
                    v.try_get("error").and_then(|e| e.as_str().ok()).unwrap_or("(no error)")
                )));
            }
            "cancelled" => {
                return Err(PlaceError::Job(err!("job {job} was cancelled on the worker")));
            }
            _ => {
                if let (Some(done), Some(total)) =
                    (v.try_get("chunks_done"), v.try_get("chunks_total"))
                {
                    let parsed = done
                        .as_usize()
                        .and_then(|d| Ok((d, total.as_usize()?)))
                        .map_err(PlaceError::Job)?;
                    progress(parsed.0, parsed.1);
                }
                std::thread::sleep(opts.poll);
            }
        }
    }

    // the typed back door: the canonical v1 result envelope
    let (status, resp) =
        get(client, &format!("/v1/runs/{job}/result")).map_err(PlaceError::WorkerDown)?;
    if status != 200 {
        return Err(PlaceError::WorkerDown(err!(
            "fetching result of job {job}: HTTP {status}: {}",
            http::error_message(&resp)
        )));
    }
    let result = std::str::from_utf8(&resp)
        .context("non-UTF-8 result body")
        .and_then(|s| AnalysisResult::from_json_str(s.trim()))
        .map_err(PlaceError::Job)?;
    progress(result.chunks, result.chunks);
    let (chunks, wall) = (result.chunks, result.wall);
    let partial = PartialResult::new(range, result).map_err(PlaceError::Job)?;
    Ok(Placement { partial, chunks, wall, job })
}

// -- the CLI front door --------------------------------------------------

/// The `bfast shard` flag surface (mirrors `bfast run`, plus the
/// worker fleet).
pub fn shard_command() -> Command {
    api::param_flags(
        Command::new("shard", "fan one analysis out across serve workers and merge")
            .req("input", "input .bsq stack")
            .req("workers", "comma-separated worker addresses (host:port,...)")
            .opt("shards", "0", "shard count (0 = one per worker)")
            .opt("pixels", "", "analyse only the pixel range START:END")
            .opt("poll-ms", "50", "per-shard job status poll interval (ms)")
            .opt("attempts", "0", "placement attempts per shard (0 = one per worker)")
            .opt("momax-pgm", "", "write max|MOSUM| heatmap PGM here")
            .opt("result-json", "", "write the merged v1 result envelope JSON here")
            .switch("timings", "print the merged phase breakdown"),
    )
}

/// Parse `bfast shard` flags into (request, workers, options).
pub fn shard_args_from_matches(
    m: &Matches,
) -> Result<(AnalysisRequest, Vec<String>, ShardOptions)> {
    let workers: Vec<String> = m
        .str("workers")?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    ensure!(!workers.is_empty(), "--workers needs at least one host:port address");
    let mut req = AnalysisRequest::new(SceneSource::Path(m.str("input")?.to_string()));
    req.params = api::param_spec_from_matches(m)?;
    req.chunking.pixel_range = api::parse_pixel_range(m.str("pixels")?)?;
    req.outputs = api::outputs_from_matches(m)?;
    let opts = ShardOptions {
        shards: m.usize("shards")?,
        poll: Duration::from_millis(m.u64("poll-ms")?),
        attempts: m.usize("attempts")?,
        ..ShardOptions::default()
    };
    Ok((req, workers, opts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::BfastParams;
    use crate::synth::ArtificialDataset;

    #[test]
    fn split_ranges_balances_and_skips_empties() {
        assert_eq!(split_ranges(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
        assert_eq!(split_ranges(6, 3), vec![(0, 2), (2, 4), (4, 6)]);
        // k > pixels: one single-pixel shard each, empties omitted
        assert_eq!(split_ranges(2, 7), vec![(0, 1), (1, 2)]);
        assert_eq!(split_ranges(1, 3), vec![(0, 1)]);
        assert_eq!(split_ranges(0, 4), Vec::<(usize, usize)>::new());
        assert_eq!(split_ranges(5, 1), vec![(0, 5)]);
        // exhaustive contiguity/coverage over a small grid
        for pixels in 1..40usize {
            for k in 1..10usize {
                let r = split_ranges(pixels, k);
                assert_eq!(r.first().unwrap().0, 0);
                assert_eq!(r.last().unwrap().1, pixels);
                assert_eq!(r.len(), k.min(pixels));
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "gap at {w:?}");
                }
                assert!(r.iter().all(|(a, b)| a < b), "empty shard in {r:?}");
                let widths: Vec<usize> = r.iter().map(|(a, b)| b - a).collect();
                let (lo, hi) =
                    (widths.iter().min().unwrap(), widths.iter().max().unwrap());
                assert!(hi - lo <= 1, "unbalanced split {widths:?}");
            }
        }
    }

    #[test]
    fn split_weighted_apportions_by_weight() {
        // 3:1 throughput → 75/25 of the scene
        assert_eq!(split_weighted(100, &[3.0, 1.0]), vec![(0, 75), (75, 100)]);
        assert_eq!(split_weighted(10, &[1.0]), vec![(0, 10)]);
        assert_eq!(split_weighted(7, &[]), Vec::<(usize, usize)>::new());
        // equal weights reproduce the even split
        assert_eq!(split_weighted(10, &[1.0, 1.0, 1.0]), split_ranges(10, 3));
        // a zero/NaN weight gets the mean of the usable ones (here 2.0,
        // so thirds), not a zero-width shard
        assert_eq!(
            split_weighted(9, &[2.0, f64::NAN, 2.0]),
            vec![(0, 3), (3, 6), (6, 9)]
        );
        // no usable weight at all → uniform
        assert_eq!(split_weighted(4, &[0.0, -1.0]), vec![(0, 2), (2, 4)]);
        // an extreme ratio may round a shard down to empty — the range
        // list still covers the scene positionally
        assert_eq!(split_weighted(2, &[1000.0, 0.001]), vec![(0, 2), (2, 2)]);
        // coverage + contiguity + determinism over a small grid
        for pixels in [0usize, 1, 5, 17, 100] {
            for weights in
                [&[1.0, 2.0, 3.0][..], &[0.5, 0.5], &[10.0, 0.1, 5.0, 2.2], &[1.0]]
            {
                let r = split_weighted(pixels, weights);
                assert_eq!(r.len(), weights.len());
                assert_eq!(r.first().unwrap().0, 0);
                assert_eq!(r.last().unwrap().1, pixels);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "gap at {w:?}");
                }
                assert_eq!(r, split_weighted(pixels, weights), "non-deterministic");
            }
        }
    }

    #[test]
    fn split_requests_differ_only_in_pixel_range() {
        let params = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap();
        let stack = ArtificialDataset::new(params.clone(), 11, 3).generate().stack;
        let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
        req.params = ParamSpec::from_params(&params);
        let shards = split(&req, 4).unwrap();
        assert_eq!(shards.len(), 4);
        let ranges: Vec<_> = shards.iter().map(|s| s.chunking.pixel_range.unwrap()).collect();
        assert_eq!(ranges, vec![(0, 3), (3, 6), (6, 9), (9, 11)]);
        for s in &shards {
            assert_eq!(s.params, req.params);
            assert_eq!(s.engine, req.engine);
            assert_eq!(s.chunking.queue_depth, req.chunking.queue_depth);
        }
        // an existing pixel_range is what gets partitioned
        req.chunking.pixel_range = Some((2, 7));
        let ranges: Vec<_> = split(&req, 2)
            .unwrap()
            .iter()
            .map(|s| s.chunking.pixel_range.unwrap())
            .collect();
        assert_eq!(ranges, vec![(2, 5), (5, 7)]);
        // out-of-bounds base ranges are rejected
        req.chunking.pixel_range = Some((7, 20));
        assert!(split(&req, 2).is_err());
    }

    #[test]
    fn shard_flags_parse() {
        let args: Vec<String> = [
            "--input", "scene.bsq", "--workers", "127.0.0.1:7901, 127.0.0.1:7902",
            "--n-total", "48", "--n-hist", "36", "--h", "12", "--k", "1", "--freq", "12",
            "--shards", "5", "--pixels", "3:9", "--poll-ms", "10",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let m = shard_command().parse(&args).unwrap();
        let (req, workers, opts) = shard_args_from_matches(&m).unwrap();
        assert_eq!(workers, vec!["127.0.0.1:7901", "127.0.0.1:7902"]);
        assert_eq!(opts.shards, 5);
        assert_eq!(opts.poll, Duration::from_millis(10));
        assert_eq!(req.params.n_total, Some(48));
        assert_eq!(req.chunking.pixel_range, Some((3, 9)));
        let empty: Vec<String> =
            ["--input", "s.bsq", "--workers", " , "].iter().map(|s| s.to_string()).collect();
        let m = shard_command().parse(&empty).unwrap();
        assert!(shard_args_from_matches(&m).is_err());
    }
}
