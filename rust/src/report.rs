//! Experiment report emitters — CSV + markdown tables written under
//! `results/`, consumed by EXPERIMENTS.md — plus the monitoring
//! session's per-layer delta summary.

use crate::error::{Context, Result};
use crate::monitor::IngestDelta;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// A simple column-oriented results table.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Format a f64 cell compactly.
    pub fn num(v: f64) -> String {
        if v == 0.0 {
            "0".into()
        } else if v.abs() >= 100.0 {
            format!("{v:.1}")
        } else if v.abs() >= 0.01 {
            format!("{v:.4}")
        } else {
            format!("{v:.3e}")
        }
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.columns.join(","));
        for r in &self.rows {
            let _ = writeln!(s, "{}", r.join(","));
        }
        s
    }

    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "### {}\n", self.title);
        let _ = writeln!(s, "| {} |", self.columns.join(" | "));
        let seps = self.columns.iter().map(|_| "---").collect::<Vec<_>>().join("|");
        let _ = writeln!(s, "|{seps}|");
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }

    /// Plain console rendering.
    pub fn to_console(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "== {} ==", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(s, "{}", fmt_row(&self.columns, &widths));
        for r in &self.rows {
            let _ = writeln!(s, "{}", fmt_row(r, &widths));
        }
        s
    }

    /// Write CSV + markdown into `results/` under the given stem.
    pub fn save(&self, results_dir: impl AsRef<Path>, stem: &str) -> Result<PathBuf> {
        let dir = results_dir.as_ref();
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let csv = dir.join(format!("{stem}.csv"));
        std::fs::write(&csv, self.to_csv())?;
        std::fs::write(dir.join(format!("{stem}.md")), self.to_markdown())?;
        Ok(csv)
    }
}

/// Summarise a monitoring session's ingests as a table: one row per
/// layer with its acquisition time, monitor index, newly-broken pixel
/// count and the running break total/fraction.
pub fn monitor_delta_table(deltas: &[IngestDelta], n_pixels: usize) -> Table {
    let mut t = Table::new(
        "monitor ingest deltas",
        &["layer", "t", "monitor_idx", "new_breaks", "total_breaks", "break_pct"],
    );
    for d in deltas {
        let pct = if n_pixels > 0 {
            100.0 * d.total_breaks as f64 / n_pixels as f64
        } else {
            0.0
        };
        t.row(vec![
            d.layer.to_string(),
            Table::num(d.t),
            d.monitor_index.to_string(),
            d.new_breaks.len().to_string(),
            d.total_breaks.to_string(),
            format!("{pct:.2}"),
        ]);
    }
    t
}

/// Render `bfast shard` output: one row per shard with the pixel
/// range it covered, the worker that completed it, how many
/// placements it took (>1 = a retry rescued it), and the shard's
/// chunk count and wall time.
pub fn shard_table(shards: &[crate::shard::ShardReport]) -> Table {
    let mut t = Table::new(
        "shard placements",
        &["shard", "pixels", "worker", "attempts", "chunks", "wall_s"],
    );
    for s in shards {
        t.row(vec![
            s.shard.to_string(),
            format!("[{}, {})", s.pixel_range.0, s.pixel_range.1),
            s.worker.clone(),
            s.attempts.to_string(),
            s.chunks.to_string(),
            format!("{:.3}", s.wall.as_secs_f64()),
        ]);
    }
    t
}

/// Render `bfast client jobs` output: one row per job with its
/// status and progress, as returned by `GET /v1/runs`.
pub fn jobs_table(jobs: &[(u64, String, f64)]) -> Table {
    let mut t = Table::new("analysis jobs", &["job", "status", "progress_pct"]);
    for (id, status, progress) in jobs {
        t.row(vec![
            id.to_string(),
            status.clone(),
            format!("{:.1}", 100.0 * progress),
        ]);
    }
    t
}

/// Render the gateway's fleet view (`bfast client workers` /
/// `GET /v1/workers`): one row per registered worker with its health,
/// placement weight and observed throughput.
pub fn workers_table(workers: &[crate::gateway::WorkerInfo]) -> Table {
    let mut t = Table::new(
        "fleet workers",
        &["worker", "status", "weight", "chunks_per_s", "beats", "last_beat_s"],
    );
    for w in workers {
        t.row(vec![
            w.addr.clone(),
            w.status().to_string(),
            format!("{:.2}", w.weight),
            format!("{:.2}", w.rate),
            w.beats.to_string(),
            format!("{:.1}", w.last_beat.as_secs_f64()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Table {
        let mut t = Table::new("speedups", &["m", "cpu_s", "device_s"]);
        t.row(vec!["1000".into(), Table::num(0.5), Table::num(0.0123)]);
        t
    }

    #[test]
    fn csv_and_markdown() {
        let t = t();
        assert_eq!(t.to_csv(), "m,cpu_s,device_s\n1000,0.5000,0.0123\n");
        let md = t.to_markdown();
        assert!(md.contains("| m | cpu_s | device_s |"));
        assert!(md.contains("### speedups"));
        let con = t.to_console();
        assert!(con.contains("speedups"));
    }

    #[test]
    fn num_formats() {
        assert_eq!(Table::num(0.0), "0");
        assert_eq!(Table::num(123.456), "123.5");
        assert_eq!(Table::num(0.5), "0.5000");
        assert_eq!(Table::num(0.0001234), "1.234e-4");
    }

    #[test]
    fn save_writes_files() {
        let dir = std::env::temp_dir().join(format!("bfast_rep_{}", std::process::id()));
        let p = t().save(&dir, "fig2").unwrap();
        assert!(p.exists());
        assert!(dir.join("fig2.md").exists());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = t();
        t.row(vec!["x".into()]);
    }

    #[test]
    fn shard_table_renders_placements() {
        let shards = vec![
            crate::shard::ShardReport {
                shard: 0,
                pixel_range: (0, 50),
                worker: "127.0.0.1:7901".into(),
                attempts: 1,
                chunks: 4,
                wall: std::time::Duration::from_millis(1500),
            },
            crate::shard::ShardReport {
                shard: 1,
                pixel_range: (50, 101),
                worker: "127.0.0.1:7902".into(),
                attempts: 2,
                chunks: 5,
                wall: std::time::Duration::from_millis(900),
            },
        ];
        let t = shard_table(&shards);
        assert_eq!(t.rows.len(), 2);
        let con = t.to_console();
        assert!(con.contains("shard placements"));
        assert!(con.contains("[50, 101)"), "{con}");
        assert!(con.contains("127.0.0.1:7902"), "{con}");
        assert!(con.contains("1.500"), "{con}");
    }

    #[test]
    fn workers_table_renders_fleet() {
        use std::time::Duration;
        let workers = vec![
            crate::gateway::WorkerInfo {
                addr: "127.0.0.1:7901".into(),
                alive: true,
                down: false,
                is_static: false,
                weight: 3.0,
                rate: 12.5,
                beats: 42,
                last_beat: Duration::from_millis(400),
            },
            crate::gateway::WorkerInfo {
                addr: "127.0.0.1:7902".into(),
                alive: false,
                down: true,
                is_static: true,
                weight: 1.0,
                rate: 0.0,
                beats: 7,
                last_beat: Duration::from_secs(9),
            },
        ];
        let t = workers_table(&workers);
        assert_eq!(t.rows.len(), 2);
        let con = t.to_console();
        assert!(con.contains("fleet workers"));
        assert!(con.contains("alive"), "{con}");
        assert!(con.contains("down"), "{con}");
        assert!(con.contains("12.50"), "{con}");
        assert!(con.contains("9.0"), "{con}");
    }

    #[test]
    fn jobs_table_renders_progress() {
        let t = jobs_table(&[(1, "done".into(), 1.0), (2, "running".into(), 0.25)]);
        let con = t.to_console();
        assert!(con.contains("analysis jobs"));
        assert!(con.contains("100.0"));
        assert!(con.contains("25.0"));
    }

    #[test]
    fn delta_table_renders_rows() {
        let deltas = vec![
            IngestDelta {
                layer: 40,
                t: 41.0,
                monitor_index: 4,
                new_breaks: vec![1, 5, 9],
                total_breaks: 3,
            },
            IngestDelta {
                layer: 41,
                t: 42.0,
                monitor_index: 5,
                new_breaks: vec![],
                total_breaks: 3,
            },
        ];
        let t = monitor_delta_table(&deltas, 100);
        assert_eq!(t.rows.len(), 2);
        let con = t.to_console();
        assert!(con.contains("monitor ingest deltas"));
        assert!(con.contains("3.00"), "{con}");
        let csv = t.to_csv();
        assert!(csv.starts_with("layer,t,monitor_idx,new_breaks,total_breaks,break_pct"));
    }
}
