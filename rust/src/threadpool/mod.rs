//! Data-parallel execution substrate (replaces rayon/OpenMP for the
//! offline build).
//!
//! The paper's multi-core baseline parallelises the per-pixel tail of
//! the pipeline "over the m time series using, e.g., OpenMP". This
//! module provides exactly that shape of parallelism on std scoped
//! threads: a static chunk grid pulled from an atomic counter, so load
//! imbalance self-corrects without work-stealing machinery.

use crate::error::{err, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// Number of worker threads to use: `BFAST_THREADS` env override or
/// the machine's available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("BFAST_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `body(start, end)` over `[0, len)` split into `grain`-sized
/// ranges, on `threads` workers. `body` must be `Sync` (it is shared);
/// per-range state should live inside the closure call.
pub fn parallel_ranges<F>(len: usize, grain: usize, threads: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let grain = grain.max(1);
    let n_chunks = len.div_ceil(grain);
    let threads = threads.max(1).min(n_chunks.max(1));
    if threads <= 1 || n_chunks <= 1 {
        let mut s = 0;
        while s < len {
            body(s, (s + grain).min(len));
            s += grain;
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let c = next.fetch_add(1, Ordering::Relaxed);
                if c >= n_chunks {
                    break;
                }
                let s = c * grain;
                body(s, (s + grain).min(len));
            });
        }
    });
}

/// Map over `[0, len)` in parallel producing a `Vec<T>`; `f(i)` runs
/// once per index, results land in order.
pub fn parallel_map<T, F>(len: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); len];
    let slots = SyncSlice::new(&mut out);
    let grain = (len / (threads.max(1) * 8)).max(1);
    parallel_ranges(len, grain, threads, |s, e| {
        for i in s..e {
            // SAFETY: each index is written by exactly one worker.
            unsafe { slots.write(i, f(i)) };
        }
    });
    out
}

type PoolJob = Box<dyn FnOnce() + Send + 'static>;

/// A persistent FIFO worker pool for task fan-out (the `serve`
/// front-end hands every accepted connection to one). Unlike
/// [`parallel_ranges`] — scoped, data-parallel, borrows its input —
/// jobs here are `'static` closures queued through a channel, and
/// [`WorkerPool::shutdown`] is **graceful**: it closes the queue,
/// lets the workers drain every job already submitted, and joins
/// them before returning.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<PoolJob>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (at least one) sharing one FIFO queue.
    pub fn new(threads: usize) -> Self {
        let (tx, rx) = mpsc::channel::<PoolJob>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    // take the next job while holding the lock, run it
                    // after releasing (a panicking job must not poison
                    // the queue for its siblings)
                    let job = match rx.lock() {
                        Ok(guard) => guard.recv(),
                        Err(_) => break,
                    };
                    match job {
                        // contain panics: a panicking job must not
                        // shrink the pool (the serve front-end would
                        // otherwise bleed workers until the accept
                        // loop dies)
                        Ok(job) => {
                            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                        }
                        Err(_) => break, // queue closed: drained + done
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Queue one job. Fails only after [`WorkerPool::shutdown`].
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) -> Result<()> {
        let tx = self.tx.as_ref().ok_or_else(|| err!("worker pool is shut down"))?;
        tx.send(Box::new(job)).map_err(|_| err!("worker pool workers have exited"))
    }

    /// Graceful shutdown: close the queue, drain what was already
    /// submitted, join every worker. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.tx.take(); // closing the sender ends every recv loop
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Split a mutable slice into disjoint per-index cells that different
/// threads may write. Sound as long as every index is written by at
/// most one thread (guaranteed by the chunk grid above).
pub struct SyncSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Sync for SyncSlice<'_, T> {}
unsafe impl<T: Send> Send for SyncSlice<'_, T> {}

impl<'a, T> SyncSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: std::marker::PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write one cell. Caller contract: no two threads write the same
    /// index, and no one reads it concurrently.
    ///
    /// # Safety
    /// `i < len` and exclusive access to index `i`.
    pub unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) = value };
    }

    /// Read one cell. Caller contract: no concurrent writer for `i`.
    ///
    /// # Safety
    /// `i < len` and no data race on index `i`.
    pub unsafe fn read(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        unsafe { *self.ptr.add(i) }
    }

    /// Get a mutable sub-slice `[start, end)`. Caller contract: ranges
    /// handed to different threads are disjoint.
    ///
    /// # Safety
    /// `start <= end <= len` and ranges are disjoint across threads.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, end: usize) -> &mut [T] {
        debug_assert!(start <= end && end <= self.len);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), end - start) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        let len = 10_003;
        let hits: Vec<AtomicU64> = (0..len).map(|_| AtomicU64::new(0)).collect();
        parallel_ranges(len, 17, 8, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn handles_edge_sizes() {
        for len in [0, 1, 2, 7] {
            let count = AtomicUsize::new(0);
            parallel_ranges(len, 3, 4, |s, e| {
                count.fetch_add(e - s, Ordering::Relaxed);
            });
            assert_eq!(count.load(Ordering::Relaxed), len);
        }
    }

    #[test]
    fn single_thread_fallback() {
        let sum = AtomicUsize::new(0);
        parallel_ranges(100, 10, 1, |s, e| {
            sum.fetch_add((s..e).sum::<usize>(), Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let v = parallel_map(1000, 4, |i| i * i);
        assert!(v.iter().enumerate().all(|(i, &x)| x == i * i));
    }

    #[test]
    fn sync_slice_disjoint_ranges() {
        let mut data = vec![0u32; 256];
        let ss = SyncSlice::new(&mut data);
        parallel_ranges(256, 32, 4, |s, e| {
            let part = unsafe { ss.slice_mut(s, e) };
            for (off, v) in part.iter_mut().enumerate() {
                *v = (s + off) as u32;
            }
        });
        assert!(data.iter().enumerate().all(|(i, &x)| x == i as u32));
    }

    #[test]
    fn worker_pool_drains_all_jobs_on_shutdown() {
        let mut pool = WorkerPool::new(4);
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let hits = Arc::clone(&hits);
            pool.execute(move || {
                hits.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        pool.shutdown(); // graceful: every queued job runs first
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        assert!(pool.execute(|| {}).is_err(), "execute after shutdown must fail");
    }

    #[test]
    fn worker_pool_survives_panicking_jobs_at_full_strength() {
        // a single-worker pool proves the panicking job did not kill
        // its worker: the follow-up jobs must still run on it
        let mut pool = WorkerPool::new(1);
        let hits = Arc::new(AtomicUsize::new(0));
        for _ in 0..3 {
            let _ = pool.execute(|| panic!("job panic must not kill the pool"));
        }
        for _ in 0..10 {
            let hits = Arc::clone(&hits);
            pool.execute(move || {
                hits.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        pool.shutdown();
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn default_threads_env_override() {
        // run serially: env is process-global
        std::env::set_var("BFAST_THREADS", "3");
        assert_eq!(default_threads(), 3);
        std::env::set_var("BFAST_THREADS", "bogus");
        assert!(default_threads() >= 1);
        std::env::remove_var("BFAST_THREADS");
    }
}
