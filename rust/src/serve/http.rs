//! Minimal HTTP/1.1 substrate (replaces hyper/axum for the offline
//! build): request parsing, response writing, a one-shot client for
//! `bfast client`/tests, percent decoding and base64 — everything the
//! serving layer needs on plain `std::net` sockets.
//!
//! Deliberately small: `Content-Length` bodies only (no chunked
//! encoding), ASCII headers. Connections are **kept alive** by
//! default per HTTP/1.1 — the serving layer loops over
//! [`read_request`]/[`write_response`] on one socket until the client
//! sends `Connection: close` (or an HTTP/1.0 request without
//! `keep-alive`), which is all the break-detection API requires while
//! keeping the parser easy to audit.

use crate::error::{bail, ensure, err, Context, Result};
use crate::json::Value;
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Largest accepted request head (request line + headers).
pub const MAX_HEADER: usize = 64 * 1024;

/// One parsed response: (status, lowercased headers, body).
pub type ResponseParts = (u16, Vec<(String, String)>, Vec<u8>);

/// A parsed HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    /// Percent-decoded path (`/v1/runs/7/map`).
    pub path: String,
    /// Percent-decoded query pairs in order of appearance.
    pub query: Vec<(String, String)>,
    /// Header (name, value) pairs; names lowercased.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// False for `HTTP/1.0` requests (whose default is no keep-alive).
    pub http11: bool,
}

impl Request {
    /// First query value for `key`, if present.
    pub fn query_get(&self, key: &str) -> Option<&str> {
        self.query.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Header value by case-insensitive name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// The Content-Type header ("" when absent).
    pub fn content_type(&self) -> &str {
        self.header("content-type").unwrap_or("")
    }

    /// Does the body claim to be JSON? (`application/json`, any case,
    /// with or without parameters like `; charset=utf-8`.)
    pub fn is_json(&self) -> bool {
        self.content_type().to_ascii_lowercase().starts_with("application/json")
    }

    /// May the connection serve another request after this one?
    /// HTTP/1.1 semantics: keep-alive unless `Connection: close`;
    /// HTTP/1.0 closes unless `Connection: keep-alive`.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection").map(|c| c.to_ascii_lowercase()) {
            Some(c) if c.split(',').any(|t| t.trim() == "close") => false,
            Some(c) if c.split(',').any(|t| t.trim() == "keep-alive") => true,
            _ => self.http11,
        }
    }
}

/// An HTTP response ready to serialise.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    /// Extra headers beyond the always-present Content-Type /
    /// Content-Length / Connection trio (e.g. `Retry-After` on 429).
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, value: &Value) -> Response {
        let mut body = value.to_string_compact().into_bytes();
        body.push(b'\n');
        Response {
            status,
            content_type: "application/json".into(),
            headers: Vec::new(),
            body,
        }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8".into(),
            headers: Vec::new(),
            body: body.into(),
        }
    }

    pub fn bytes(status: u16, content_type: &str, body: Vec<u8>) -> Response {
        Response { status, content_type: content_type.into(), headers: Vec::new(), body }
    }

    /// Attach an extra response header (builder-style).
    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }

    /// The uniform JSON error envelope **every** non-2xx wire response
    /// carries: `{"error": {"status": N, "message": "..."}}`. Clients
    /// can always parse `.error.message` regardless of which handler
    /// refused them. Use [`error_envelope`] directly to add extra
    /// fields (e.g. `retry_after_s` on a 429).
    pub fn json_error(status: u16, message: &str) -> Response {
        Response::json(status, &error_envelope(status, message, &[]))
    }

    /// Gzip the body (marking it with `Content-Encoding: gzip`) when
    /// the request's `Accept-Encoding` allows it and compression
    /// actually pays: opt-in per call site, only on 200s, never on
    /// bodies too small to matter, and dropped when the deflated form
    /// is no smaller. Callers that never send `Accept-Encoding` are
    /// untouched.
    pub fn gzip_if_accepted(mut self, req: &Request) -> Response {
        if self.status == 200 && accepts_gzip(req) && self.body.len() >= 512 {
            let packed = crate::store::compress::gzip_compress(&self.body);
            if packed.len() < self.body.len() {
                self.body = packed;
                self.headers.push(("Content-Encoding".into(), "gzip".into()));
            }
        }
        self
    }
}

/// Does the request's `Accept-Encoding` admit a gzip response body?
pub fn accepts_gzip(req: &Request) -> bool {
    req.header("accept-encoding").is_some_and(|v| {
        v.split(',').any(|t| {
            let t = t.trim();
            t == "gzip" || t.starts_with("gzip;")
        })
    })
}

/// Extract the human-readable message from an error-envelope body;
/// falls back to the raw (lossy-UTF-8) body for anything that is not
/// the `{"error": {...}}` shape — so callers can surface *any*
/// server's refusal in one line.
pub fn error_message(body: &[u8]) -> String {
    let text = String::from_utf8_lossy(body).trim().to_string();
    if let Ok(v) = crate::json::parse(&text) {
        if let Ok(msg) = v
            .get("error")
            .and_then(|e| e.get("message"))
            .and_then(|m| m.as_str())
        {
            return msg.to_string();
        }
    }
    text
}

/// Build the `{"error": {...}}` envelope body, with optional extra
/// fields inside the `error` object.
pub fn error_envelope(status: u16, message: &str, extra: &[(&str, Value)]) -> Value {
    let mut fields = vec![
        ("status", Value::Num(status as f64)),
        ("message", Value::Str(message.into())),
    ];
    for (k, v) in extra {
        fields.push((*k, v.clone()));
    }
    Value::obj(vec![("error", Value::obj(fields))])
}

/// Reason phrases for the statuses the API uses.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        304 => "Not Modified",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        _ => "",
    }
}

/// Read and parse one request. Bodies are bounded by `max_body`
/// (413-worthy errors surface as `Err`). Returns `Ok(None)` when the
/// peer closed the connection cleanly — or a read timeout expired —
/// before sending any bytes: the normal end of a keep-alive exchange,
/// not an error.
///
/// The head is consumed **byte-precisely** up to its `\r\n\r\n` and
/// the body by its `Content-Length`, so nothing belonging to the
/// *next* request on a kept-alive connection is ever swallowed — a
/// client that pipelines two requests in one write gets two answers.
/// (Hand a `BufReader` reused across calls to avoid per-byte reads on
/// a raw socket; the serving layer does.)
pub fn read_request(stream: &mut impl Read, max_body: usize) -> Result<Option<Request>> {
    let Some(head_bytes) = read_head(stream, "request")? else {
        return Ok(None); // clean close / idle keep-alive wait expired
    };
    let head = std::str::from_utf8(&head_bytes).context("non-UTF-8 request head")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().ok_or_else(|| err!("empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| err!("malformed request line {request_line:?}"))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| err!("malformed request line {request_line:?}"))?;
    let version = parts.next().unwrap_or("HTTP/1.1");
    ensure!(version.starts_with("HTTP/1."), "unsupported protocol {version:?}");
    let http11 = version != "HTTP/1.0";

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| err!("malformed header line {line:?}"))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let content_length = match headers.iter().find(|(k, _)| k == "content-length") {
        None => 0,
        Some((_, v)) => v
            .parse::<usize>()
            .map_err(|_| err!("bad Content-Length {v:?}"))?,
    };
    ensure!(
        content_length <= max_body,
        "request body of {content_length} bytes exceeds the {max_body}-byte limit"
    );
    let mut body = vec![0u8; content_length];
    stream
        .read_exact(&mut body)
        .context("connection closed mid-body")?;
    // Content-Encoding: gzip request bodies decode centrally here, so
    // every handler sees plain bytes; the decoded size is bounded by
    // the same max_body the raw form honours (zip-bomb guard).
    if let Some((_, enc)) = headers.iter().find(|(k, _)| k == "content-encoding") {
        match enc.to_ascii_lowercase().as_str() {
            "gzip" | "x-gzip" => {
                body = crate::store::compress::gzip_decompress(&body, max_body)
                    .context("decoding gzip request body")?;
            }
            "identity" | "" => {}
            other => bail!("unsupported Content-Encoding {other:?} (gzip|identity)"),
        }
    }

    let (path, query) = parse_target(target)?;
    Ok(Some(Request { method, path, query, headers, body, http11 }))
}

/// Serialise one response. `keep_alive` selects the `Connection`
/// header: the serving layer keeps the socket open between requests
/// unless the client asked to close (or the server is shutting down).
pub fn write_response(stream: &mut impl Write, resp: &Response, keep_alive: bool) -> Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
        resp.status,
        status_text(resp.status),
        resp.content_type,
        resp.body.len(),
    );
    for (k, v) in &resp.headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(if keep_alive {
        "Connection: keep-alive\r\n\r\n"
    } else {
        "Connection: close\r\n\r\n"
    });
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

/// Byte-precise head reader shared by request and response parsing:
/// consumes the stream up to and including `\r\n\r\n` and returns the
/// head without the terminator, so nothing belonging to the next
/// message on a kept-alive socket is swallowed. `Ok(None)` = clean
/// close (EOF, or an expired read timeout) before the first byte;
/// EOF mid-head is an error labelled with `what`.
fn read_head(stream: &mut impl Read, what: &str) -> Result<Option<Vec<u8>>> {
    let mut buf: Vec<u8> = Vec::with_capacity(256);
    let mut byte = [0u8; 1];
    loop {
        if buf.len() >= 4 && &buf[buf.len() - 4..] == b"\r\n\r\n" {
            buf.truncate(buf.len() - 4);
            return Ok(Some(buf));
        }
        ensure!(buf.len() <= MAX_HEADER, "{what} head exceeds {MAX_HEADER} bytes");
        let n = match stream.read(&mut byte) {
            Ok(n) => n,
            Err(e)
                if buf.is_empty()
                    && matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
            {
                return Ok(None);
            }
            Err(e) => return Err(e.into()),
        };
        if n == 0 {
            ensure!(buf.is_empty(), "connection closed mid-{what}");
            return Ok(None);
        }
        buf.push(byte[0]);
    }
}

/// Status code from the first line of a response head.
fn parse_status_line(head: &str) -> Result<u16> {
    let status_line = head.lines().next().ok_or_else(|| err!("empty response"))?;
    status_line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| err!("malformed status line {status_line:?}"))?
        .parse()
        .map_err(|_| err!("bad status in {status_line:?}"))
}

/// Header (name, value) pairs from a head's continuation lines, names
/// lowercased — shared by response parsing wherever the caller needs
/// more than the status (e.g. `Retry-After` on a 429).
fn head_headers(head: &str) -> Vec<(String, String)> {
    head.lines()
        .skip(1)
        .filter_map(|line| line.split_once(':'))
        .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
        .collect()
}

/// Content-Length among parsed headers (0 when absent).
fn headers_content_length(headers: &[(String, String)]) -> Result<usize> {
    match headers.iter().find(|(k, _)| k == "content-length") {
        None => Ok(0),
        Some((_, v)) => v.parse().map_err(|_| err!("bad Content-Length {v:?}")),
    }
}

/// The `Retry-After` header as a duration, when present and parseable
/// (integer seconds form only — all this API ever sends).
pub fn retry_after(headers: &[(String, String)]) -> Option<Duration> {
    headers
        .iter()
        .find(|(k, _)| k == "retry-after")
        .and_then(|(_, v)| v.parse::<u64>().ok())
        .map(Duration::from_secs)
}

/// Read exactly one response off a keep-alive connection — the head
/// byte-precisely, the body by its `Content-Length` — leaving the
/// socket usable for the next round-trip. (Wrap the stream in a
/// `BufReader` — reused across calls — to avoid per-byte reads on a
/// raw socket.) Returns `(status, body)`.
pub fn read_response(stream: &mut impl Read) -> Result<(u16, Vec<u8>)> {
    read_response_parts(stream).map(|(status, _, body)| (status, body))
}

/// [`read_response`], plus the parsed response headers (names
/// lowercased) for callers that need e.g. `Retry-After`.
pub fn read_response_parts(stream: &mut impl Read) -> Result<ResponseParts> {
    let head_bytes = read_head(stream, "response")?
        .ok_or_else(|| err!("connection closed before a response arrived"))?;
    let head = std::str::from_utf8(&head_bytes).context("non-UTF-8 response head")?;
    let status = parse_status_line(head)?;
    let headers = head_headers(head);
    let mut body = vec![0u8; headers_content_length(&headers)?];
    stream
        .read_exact(&mut body)
        .context("connection closed mid-body")?;
    Ok((status, headers, body))
}

/// One client round-trip (the `bfast client` subcommand, the tests
/// and the CI smoke step): connect, send `method path` with the given
/// body, return `(status, response body)`.
pub fn roundtrip(
    addr: &str,
    method: &str,
    path: &str,
    content_type: &str,
    body: &[u8],
) -> Result<(u16, Vec<u8>)> {
    parse_response(&roundtrip_raw(addr, method, path, content_type, &[], body)?)
}

/// The raw bytes of a one-shot `Connection: close` exchange.
fn roundtrip_raw(
    addr: &str,
    method: &str,
    path: &str,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
) -> Result<Vec<u8>> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let mut head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\n",
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("Connection: close\r\n\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?; // server closes after one response
    Ok(raw)
}

/// A **keep-alive** HTTP/1.1 client connection: one socket, many
/// request/response exchanges. This is the transport the shard
/// coordinator drives per worker (submit → poll → poll → … → result
/// without re-handshaking), and what long-lived operator tooling
/// should prefer over one-shot [`roundtrip`]s.
pub struct Client {
    addr: String,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
        Ok(Client { addr: addr.to_string(), reader: BufReader::new(stream) })
    }

    /// [`Client::connect`] with an explicit I/O bound: the TCP connect
    /// and every subsequent read/write give up after `io` instead of
    /// the default 30 s. This is what caps a caller's exposure to a
    /// black-holed peer — a probe or poll that never answers surfaces
    /// as a transport error after `io`, not a stuck thread. (The
    /// gateway's health sweep and placement engine run on this.)
    pub fn connect_timeout(addr: &str, io: Duration) -> Result<Client> {
        use std::net::ToSocketAddrs;
        let sock = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .next()
            .ok_or_else(|| err!("{addr} resolved to no address"))?;
        let stream = TcpStream::connect_timeout(&sock, io)
            .with_context(|| format!("connecting to {addr}"))?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(io));
        let _ = stream.set_write_timeout(Some(io));
        Ok(Client { addr: addr.to_string(), reader: BufReader::new(stream) })
    }

    /// The address this connection was opened against.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One exchange on the kept-alive socket; errors leave the
    /// connection unusable (reconnect to retry).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        body: &[u8],
    ) -> Result<(u16, Vec<u8>)> {
        self.request_parts(method, path, content_type, body)
            .map(|(status, _, body)| (status, body))
    }

    /// [`Client::request`], plus the response headers (names
    /// lowercased) — e.g. for `Retry-After` on a 429.
    pub fn request_parts(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        body: &[u8],
    ) -> Result<ResponseParts> {
        self.request_with_headers(method, path, content_type, &[], body)
    }

    /// [`Client::request_parts`] with extra request headers beyond the
    /// always-present Host / Content-Type / Content-Length /
    /// Connection — e.g. `X-Request-Id` on a shard submit, so a
    /// worker's trace stitches into the coordinator's distributed
    /// trace.
    pub fn request_with_headers(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        extra_headers: &[(&str, &str)],
        body: &[u8],
    ) -> Result<ResponseParts> {
        let mut head = format!(
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: {content_type}\r\n\
             Content-Length: {}\r\n",
            self.addr,
            body.len()
        );
        for (k, v) in extra_headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str("Connection: keep-alive\r\n\r\n");
        let stream = self.reader.get_mut();
        stream.write_all(head.as_bytes())?;
        stream.write_all(body)?;
        stream.flush()?;
        read_response_parts(&mut self.reader)
    }
}

/// [`roundtrip`] with polite 429 handling: when the server answers
/// `429 Too Many Requests`, sleep — honouring its `Retry-After` header
/// — and try again, with **bounded exponential backoff** (at most
/// `attempts` tries, delays capped at [`BACKOFF_CAP`]). Any other
/// status (and the final 429) is returned to the caller as-is;
/// transport errors are not retried.
pub fn roundtrip_retry(
    addr: &str,
    method: &str,
    path: &str,
    content_type: &str,
    body: &[u8],
    attempts: usize,
) -> Result<(u16, Vec<u8>)> {
    roundtrip_retry_with(addr, method, path, content_type, &[], body, attempts)
}

/// [`roundtrip_retry`] with extra request headers — e.g.
/// `Content-Encoding: gzip` on a compressed `client submit`.
pub fn roundtrip_retry_with(
    addr: &str,
    method: &str,
    path: &str,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    attempts: usize,
) -> Result<(u16, Vec<u8>)> {
    let attempts = attempts.max(1);
    let mut attempt = 0;
    loop {
        let raw = roundtrip_raw(addr, method, path, content_type, extra_headers, body)?;
        let (status, headers, resp_body) = parse_response_parts(&raw)?;
        if status != 429 || attempt + 1 >= attempts {
            return Ok((status, resp_body));
        }
        std::thread::sleep(backoff_delay(attempt, retry_after(&headers)));
        attempt += 1;
    }
}

/// Delay before retry number `attempt` (0-based): exponential from
/// 100 ms, raised to the server's `Retry-After` hint when that is
/// longer, and never above [`BACKOFF_CAP`].
pub fn backoff_delay(attempt: usize, retry_after: Option<Duration>) -> Duration {
    let exp = Duration::from_millis(100u64.saturating_mul(1 << attempt.min(10)));
    retry_after.map_or(exp, |hint| hint.max(exp)).min(BACKOFF_CAP)
}

/// Longest single backoff sleep [`roundtrip_retry`] will take.
pub const BACKOFF_CAP: Duration = Duration::from_secs(5);

/// Split a raw HTTP response into (status, body).
pub fn parse_response(raw: &[u8]) -> Result<(u16, Vec<u8>)> {
    parse_response_parts(raw).map(|(status, _, body)| (status, body))
}

/// Split a raw HTTP response into (status, headers, body) — header
/// names lowercased.
pub fn parse_response_parts(raw: &[u8]) -> Result<ResponseParts> {
    let pos = find_subslice(raw, b"\r\n\r\n").ok_or_else(|| err!("malformed HTTP response"))?;
    let head = std::str::from_utf8(&raw[..pos]).context("non-UTF-8 response head")?;
    Ok((parse_status_line(head)?, head_headers(head), raw[pos + 4..].to_vec()))
}

fn find_subslice(hay: &[u8], needle: &[u8]) -> Option<usize> {
    if hay.len() < needle.len() {
        return None;
    }
    hay.windows(needle.len()).position(|w| w == needle)
}

fn parse_target(target: &str) -> Result<(String, Vec<(String, String)>)> {
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let mut pairs = Vec::new();
    for part in query.split('&') {
        if part.is_empty() {
            continue;
        }
        let (k, v) = part.split_once('=').unwrap_or((part, ""));
        pairs.push((percent_decode(k)?, percent_decode(v)?));
    }
    Ok((percent_decode(path)?, pairs))
}

/// Decode `%XX` escapes (and `+` as space) — enough for curl-built
/// query strings.
pub fn percent_decode(s: &str) -> Result<String> {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' => {
                let hex = bytes
                    .get(i + 1..i + 3)
                    .ok_or_else(|| err!("truncated %-escape in {s:?}"))?;
                let v = u8::from_str_radix(std::str::from_utf8(hex)?, 16)
                    .map_err(|_| err!("bad %-escape in {s:?}"))?;
                out.push(v);
                i += 3;
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8(out).map_err(|_| err!("%-escapes in {s:?} are not UTF-8"))
}

// base64 moved to the neutral `crate::b64` module (the api front door
// needs it without depending on the HTTP substrate); re-exported here
// for the wire-facing callers that always imported it from http.
pub use crate::b64::{base64_decode, base64_encode};

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_query_and_body() {
        let raw = b"POST /v1/sessions/alpha/ingest?t=41.5&format=json HTTP/1.1\r\n\
                    Host: x\r\nContent-Type: application/json\r\nContent-Length: 9\r\n\r\n\
                    {\"t\": 1}!extra";
        let req = read_request(&mut Cursor::new(&raw[..]), 1 << 20).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/sessions/alpha/ingest");
        assert_eq!(req.query_get("t"), Some("41.5"));
        assert_eq!(req.query_get("format"), Some("json"));
        assert_eq!(req.query_get("missing"), None);
        assert_eq!(req.content_type(), "application/json");
        assert_eq!(req.body, b"{\"t\": 1}!"); // trailing bytes stay in the stream
        assert!(req.http11);
        assert!(req.keep_alive()); // HTTP/1.1 default
    }

    #[test]
    fn pipelined_requests_are_read_back_to_back() {
        // two requests in one buffer: byte-precise reads must leave the
        // second intact for the next call (keep-alive pipelining)
        let raw = b"POST /a HTTP/1.1\r\nContent-Length: 3\r\n\r\nxyzGET /b HTTP/1.1\r\n\r\n";
        let mut cur = Cursor::new(&raw[..]);
        let first = read_request(&mut cur, 1 << 10).unwrap().unwrap();
        assert_eq!((first.method.as_str(), first.path.as_str()), ("POST", "/a"));
        assert_eq!(first.body, b"xyz");
        let second = read_request(&mut cur, 1 << 10).unwrap().unwrap();
        assert_eq!((second.method.as_str(), second.path.as_str()), ("GET", "/b"));
        assert!(second.body.is_empty());
        assert!(read_request(&mut cur, 1 << 10).unwrap().is_none()); // clean EOF
    }

    #[test]
    fn keep_alive_semantics() {
        let parse = |head: &str| {
            read_request(&mut Cursor::new(head.as_bytes()), 1 << 10)
                .unwrap()
                .unwrap()
        };
        assert!(parse("GET /x HTTP/1.1\r\n\r\n").keep_alive());
        assert!(!parse("GET /x HTTP/1.1\r\nConnection: close\r\n\r\n").keep_alive());
        assert!(!parse("GET /x HTTP/1.1\r\nConnection: Close\r\n\r\n").keep_alive());
        assert!(!parse("GET /x HTTP/1.0\r\n\r\n").keep_alive());
        assert!(parse("GET /x HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").keep_alive());
        // clean EOF between requests is not an error
        assert!(read_request(&mut Cursor::new(&b""[..]), 1 << 10).unwrap().is_none());
    }

    #[test]
    fn rejects_oversized_body_and_garbage() {
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 100\r\n\r\n";
        assert!(read_request(&mut Cursor::new(&raw[..]), 10).is_err());
        assert!(read_request(&mut Cursor::new(&b"garbage"[..]), 10).is_err());
        let raw = b"GET /x SPDY/9\r\n\r\n";
        assert!(read_request(&mut Cursor::new(&raw[..]), 10).is_err());
    }

    #[test]
    fn response_roundtrips_through_parse_response() {
        let resp = Response::json_error(429, "queue full").with_header("Retry-After", "2");
        let mut wire = Vec::new();
        write_response(&mut wire, &resp, false).unwrap();
        let (status, headers, body) = parse_response_parts(&wire).unwrap();
        assert_eq!(status, 429);
        // the uniform envelope: {"error": {"status": ..., "message": ...}}
        let v = crate::json::parse(std::str::from_utf8(&body).unwrap().trim()).unwrap();
        let env = v.get("error").unwrap();
        assert_eq!(env.get("status").unwrap().as_usize().unwrap(), 429);
        assert_eq!(env.get("message").unwrap().as_str().unwrap(), "queue full");
        // extra headers travel, and retry_after() finds them
        assert_eq!(retry_after(&headers), Some(Duration::from_secs(2)));
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Retry-After: 2\r\n"), "{text}");
        assert!(text.contains("Connection: close"));
    }

    #[test]
    fn error_envelope_takes_extra_fields() {
        let v = error_envelope(429, "full", &[("retry_after_s", Value::Num(1.0))]);
        let env = v.get("error").unwrap();
        assert_eq!(env.get("retry_after_s").unwrap().as_usize().unwrap(), 1);
        assert_eq!(env.get("message").unwrap().as_str().unwrap(), "full");
    }

    #[test]
    fn backoff_delay_honours_hint_and_caps() {
        // pure exponential when the server gave no hint
        assert_eq!(backoff_delay(0, None), Duration::from_millis(100));
        assert_eq!(backoff_delay(2, None), Duration::from_millis(400));
        // the hint is a floor, not a ceiling...
        assert_eq!(backoff_delay(0, Some(Duration::from_secs(1))), Duration::from_secs(1));
        assert_eq!(
            backoff_delay(5, Some(Duration::from_secs(1))),
            Duration::from_millis(3200)
        );
        // ...and everything stays under the cap
        assert_eq!(backoff_delay(9, Some(Duration::from_secs(60))), BACKOFF_CAP);
        assert_eq!(backoff_delay(usize::MAX, None), BACKOFF_CAP);
    }

    #[test]
    fn retry_after_parses_only_integer_seconds() {
        let hdrs = |v: &str| vec![("retry-after".to_string(), v.to_string())];
        assert_eq!(retry_after(&hdrs("3")), Some(Duration::from_secs(3)));
        assert_eq!(retry_after(&hdrs("soon")), None);
        assert_eq!(retry_after(&[]), None);
    }

    #[test]
    fn read_response_consumes_exactly_one_reply() {
        // two back-to-back responses on one "socket": read_response
        // must stop at the first Content-Length boundary
        let mut wire = Vec::new();
        write_response(&mut wire, &Response::text(200, "first"), true).unwrap();
        write_response(&mut wire, &Response::text(200, "second"), false).unwrap();
        assert!(String::from_utf8_lossy(&wire).contains("Connection: keep-alive"));
        let mut cur = Cursor::new(&wire[..]);
        let (status, body) = read_response(&mut cur).unwrap();
        assert_eq!((status, body.as_slice()), (200, &b"first"[..]));
        let (status, body) = read_response(&mut cur).unwrap();
        assert_eq!((status, body.as_slice()), (200, &b"second"[..]));
        assert!(read_response(&mut cur).is_err()); // nothing left
    }

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("a%20b+c%2Fd").unwrap(), "a b c/d");
        assert_eq!(percent_decode("plain").unwrap(), "plain");
        assert!(percent_decode("bad%2").is_err());
        assert!(percent_decode("bad%zz").is_err());
    }

    #[test]
    fn gzip_request_bodies_decode_centrally() {
        use crate::store::compress::gzip_compress;
        let payload = b"{\"scene\": \"compressed on the wire\"}".repeat(20);
        let packed = gzip_compress(&payload);
        let mut raw = format!(
            "POST /v1/runs HTTP/1.1\r\nContent-Type: application/json\r\n\
             Content-Encoding: gzip\r\nContent-Length: {}\r\n\r\n",
            packed.len()
        )
        .into_bytes();
        raw.extend_from_slice(&packed);
        let req = read_request(&mut Cursor::new(&raw[..]), 1 << 20).unwrap().unwrap();
        assert_eq!(req.body, payload, "handlers must see the plain bytes");
        // the decoded size is bounded by max_body, not the wire size
        let mut small = Cursor::new(&raw[..]);
        assert!(read_request(&mut small, 64).is_err(), "zip-bomb guard");
        // unknown encodings are refused outright
        let raw = b"POST /x HTTP/1.1\r\nContent-Encoding: br\r\nContent-Length: 1\r\n\r\nx";
        assert!(read_request(&mut Cursor::new(&raw[..]), 1 << 10).is_err());
    }

    #[test]
    fn responses_compress_only_when_accepted_and_worthwhile() {
        use crate::store::compress::gzip_decompress;
        let parse = |head: &str| {
            read_request(&mut Cursor::new(head.as_bytes()), 1 << 10)
                .unwrap()
                .unwrap()
        };
        let plain = parse("GET /x HTTP/1.1\r\n\r\n");
        let gz = parse("GET /x HTTP/1.1\r\nAccept-Encoding: gzip, deflate\r\n\r\n");
        let gzq = parse("GET /x HTTP/1.1\r\nAccept-Encoding: gzip;q=0.8\r\n\r\n");
        let other = parse("GET /x HTTP/1.1\r\nAccept-Encoding: br\r\n\r\n");
        assert!(!accepts_gzip(&plain));
        assert!(accepts_gzip(&gz) && accepts_gzip(&gzq));
        assert!(!accepts_gzip(&other));

        let big = "x".repeat(4096);
        let resp = Response::text(200, &big).gzip_if_accepted(&gz);
        assert!(resp.headers.iter().any(|(k, v)| k == "Content-Encoding" && v == "gzip"));
        assert!(resp.body.len() < big.len());
        assert_eq!(gzip_decompress(&resp.body, 1 << 20).unwrap(), big.as_bytes());
        // no opt-in → no compression; tiny bodies stay plain either way
        assert!(Response::text(200, &big).gzip_if_accepted(&plain).headers.is_empty());
        assert!(Response::text(200, "tiny").gzip_if_accepted(&gz).headers.is_empty());
        assert!(Response::text(404, &big).gzip_if_accepted(&gz).headers.is_empty());
    }
}
