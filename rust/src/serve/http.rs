//! Minimal HTTP/1.1 substrate (replaces hyper/axum for the offline
//! build): request parsing, response writing, a one-shot client for
//! `bfast client`/tests, percent decoding and base64 — everything the
//! serving layer needs on plain `std::net` sockets.
//!
//! Deliberately small: one request per connection (`Connection:
//! close`), `Content-Length` bodies only (no chunked encoding), ASCII
//! headers. That is all the break-detection API requires, and it
//! keeps the parser easy to audit.

use crate::error::{bail, ensure, err, Context, Result};
use crate::json::Value;
use std::io::{Read, Write};
use std::net::TcpStream;

/// Largest accepted request head (request line + headers).
pub const MAX_HEADER: usize = 64 * 1024;

/// A parsed HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    /// Percent-decoded path (`/v1/runs/7/map`).
    pub path: String,
    /// Percent-decoded query pairs in order of appearance.
    pub query: Vec<(String, String)>,
    /// Header (name, value) pairs; names lowercased.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First query value for `key`, if present.
    pub fn query_get(&self, key: &str) -> Option<&str> {
        self.query.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Header value by case-insensitive name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// The Content-Type header ("" when absent).
    pub fn content_type(&self) -> &str {
        self.header("content-type").unwrap_or("")
    }
}

/// An HTTP response ready to serialise.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, value: &Value) -> Response {
        let mut body = value.to_string_compact().into_bytes();
        body.push(b'\n');
        Response { status, content_type: "application/json".into(), body }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response { status, content_type: "text/plain; charset=utf-8".into(), body: body.into() }
    }

    pub fn bytes(status: u16, content_type: &str, body: Vec<u8>) -> Response {
        Response { status, content_type: content_type.into(), body }
    }

    /// A JSON error envelope: `{"error": "..."}`.
    pub fn error(status: u16, message: &str) -> Response {
        Response::json(status, &Value::obj(vec![("error", Value::Str(message.into()))]))
    }
}

/// Reason phrases for the statuses the API uses.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "",
    }
}

/// Read and parse one request. Bodies are bounded by `max_body`
/// (413-worthy errors surface as `Err`).
pub fn read_request(stream: &mut impl Read, max_body: usize) -> Result<Request> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        ensure!(buf.len() <= MAX_HEADER, "request head exceeds {MAX_HEADER} bytes");
        let n = stream.read(&mut tmp)?;
        ensure!(n > 0, "connection closed mid-header");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = std::str::from_utf8(&buf[..header_end]).context("non-UTF-8 request head")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().ok_or_else(|| err!("empty request"))?;
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| err!("malformed request line {request_line:?}"))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| err!("malformed request line {request_line:?}"))?;
    let version = parts.next().unwrap_or("HTTP/1.1");
    ensure!(version.starts_with("HTTP/1."), "unsupported protocol {version:?}");

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| err!("malformed header line {line:?}"))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let content_length = match headers.iter().find(|(k, _)| k == "content-length") {
        None => 0,
        Some((_, v)) => v
            .parse::<usize>()
            .map_err(|_| err!("bad Content-Length {v:?}"))?,
    };
    ensure!(
        content_length <= max_body,
        "request body of {content_length} bytes exceeds the {max_body}-byte limit"
    );
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut tmp)?;
        ensure!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_length);

    let (path, query) = parse_target(target)?;
    Ok(Request { method, path, query, headers, body })
}

/// Serialise one response (`Connection: close` — one request per
/// connection keeps the server trivially correct under load).
pub fn write_response(stream: &mut impl Write, resp: &Response) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        status_text(resp.status),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

/// One client round-trip (the `bfast client` subcommand, the tests
/// and the CI smoke step): connect, send `method path` with the given
/// body, return `(status, response body)`.
pub fn roundtrip(
    addr: &str,
    method: &str,
    path: &str,
    content_type: &str,
    body: &[u8],
) -> Result<(u16, Vec<u8>)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?; // server closes after one response
    parse_response(&raw)
}

/// Split a raw HTTP response into (status, body).
pub fn parse_response(raw: &[u8]) -> Result<(u16, Vec<u8>)> {
    let pos = find_subslice(raw, b"\r\n\r\n").ok_or_else(|| err!("malformed HTTP response"))?;
    let head = std::str::from_utf8(&raw[..pos]).context("non-UTF-8 response head")?;
    let status_line = head.lines().next().ok_or_else(|| err!("empty response"))?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| err!("malformed status line {status_line:?}"))?
        .parse()
        .map_err(|_| err!("bad status in {status_line:?}"))?;
    Ok((status, raw[pos + 4..].to_vec()))
}

fn find_subslice(hay: &[u8], needle: &[u8]) -> Option<usize> {
    if hay.len() < needle.len() {
        return None;
    }
    hay.windows(needle.len()).position(|w| w == needle)
}

fn parse_target(target: &str) -> Result<(String, Vec<(String, String)>)> {
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let mut pairs = Vec::new();
    for part in query.split('&') {
        if part.is_empty() {
            continue;
        }
        let (k, v) = part.split_once('=').unwrap_or((part, ""));
        pairs.push((percent_decode(k)?, percent_decode(v)?));
    }
    Ok((percent_decode(path)?, pairs))
}

/// Decode `%XX` escapes (and `+` as space) — enough for curl-built
/// query strings.
pub fn percent_decode(s: &str) -> Result<String> {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' => {
                let hex = bytes
                    .get(i + 1..i + 3)
                    .ok_or_else(|| err!("truncated %-escape in {s:?}"))?;
                let v = u8::from_str_radix(std::str::from_utf8(hex)?, 16)
                    .map_err(|_| err!("bad %-escape in {s:?}"))?;
                out.push(v);
                i += 3;
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8(out).map_err(|_| err!("%-escapes in {s:?} are not UTF-8"))
}

const B64: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Standard base64 (with padding) — the JSON layer-ingest transport.
pub fn base64_encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = u32::from_be_bytes([0, b[0], b[1], b[2]]);
        out.push(B64[(n >> 18) as usize & 63] as char);
        out.push(B64[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { B64[(n >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { B64[n as usize & 63] as char } else { '=' });
    }
    out
}

/// Inverse of [`base64_encode`]; whitespace is ignored.
pub fn base64_decode(text: &str) -> Result<Vec<u8>> {
    fn val(c: u8) -> Result<u32> {
        Ok(match c {
            b'A'..=b'Z' => (c - b'A') as u32,
            b'a'..=b'z' => (c - b'a' + 26) as u32,
            b'0'..=b'9' => (c - b'0' + 52) as u32,
            b'+' => 62,
            b'/' => 63,
            other => bail!("invalid base64 byte {other:#04x}"),
        })
    }
    let bytes: Vec<u8> = text.bytes().filter(|b| !b.is_ascii_whitespace()).collect();
    ensure!(bytes.len() % 4 == 0, "base64 length {} is not a multiple of 4", bytes.len());
    let groups = bytes.len() / 4;
    let mut out = Vec::with_capacity(groups * 3);
    for (i, chunk) in bytes.chunks_exact(4).enumerate() {
        let pads = chunk.iter().rev().take_while(|&&c| c == b'=').count();
        ensure!(pads <= 2, "too much base64 padding");
        ensure!(pads == 0 || i == groups - 1, "misplaced base64 padding");
        ensure!(
            !chunk[..4 - pads].contains(&b'='),
            "misplaced base64 padding"
        );
        let mut n = 0u32;
        for &c in &chunk[..4 - pads] {
            n = (n << 6) | val(c)?;
        }
        n <<= 6 * pads as u32;
        let b = n.to_be_bytes();
        out.push(b[1]);
        if pads < 2 {
            out.push(b[2]);
        }
        if pads < 1 {
            out.push(b[3]);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_query_and_body() {
        let raw = b"POST /v1/sessions/alpha/ingest?t=41.5&format=json HTTP/1.1\r\n\
                    Host: x\r\nContent-Type: application/json\r\nContent-Length: 9\r\n\r\n\
                    {\"t\": 1}!extra";
        let req = read_request(&mut Cursor::new(&raw[..]), 1 << 20).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/sessions/alpha/ingest");
        assert_eq!(req.query_get("t"), Some("41.5"));
        assert_eq!(req.query_get("format"), Some("json"));
        assert_eq!(req.query_get("missing"), None);
        assert_eq!(req.content_type(), "application/json");
        assert_eq!(req.body, b"{\"t\": 1}!"); // pipelined bytes ignored
    }

    #[test]
    fn rejects_oversized_body_and_garbage() {
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 100\r\n\r\n";
        assert!(read_request(&mut Cursor::new(&raw[..]), 10).is_err());
        assert!(read_request(&mut Cursor::new(&b"garbage"[..]), 10).is_err());
        let raw = b"GET /x SPDY/9\r\n\r\n";
        assert!(read_request(&mut Cursor::new(&raw[..]), 10).is_err());
    }

    #[test]
    fn response_roundtrips_through_parse_response() {
        let resp = Response::error(429, "queue full");
        let mut wire = Vec::new();
        write_response(&mut wire, &resp).unwrap();
        let (status, body) = parse_response(&wire).unwrap();
        assert_eq!(status, 429);
        let v = crate::json::parse(std::str::from_utf8(&body).unwrap().trim()).unwrap();
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "queue full");
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Connection: close"));
    }

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("a%20b+c%2Fd").unwrap(), "a b c/d");
        assert_eq!(percent_decode("plain").unwrap(), "plain");
        assert!(percent_decode("bad%2").is_err());
        assert!(percent_decode("bad%zz").is_err());
    }

    #[test]
    fn base64_roundtrip_all_lengths() {
        for len in 0..40usize {
            let data: Vec<u8> =
                (0..len as u8).map(|b| b.wrapping_mul(37).wrapping_add(5)).collect();
            let enc = base64_encode(&data);
            assert_eq!(enc.len() % 4, 0);
            assert_eq!(base64_decode(&enc).unwrap(), data, "len {len}");
        }
        assert_eq!(base64_encode(b"Man"), "TWFu");
        assert_eq!(base64_encode(b"Ma"), "TWE=");
        assert_eq!(base64_decode("TWE=").unwrap(), b"Ma");
        for bad in ["TQ", "====", "T===", "=AAA", "TW=u", "T!Fu"] {
            assert!(base64_decode(bad).is_err(), "{bad:?}");
        }
    }
}
