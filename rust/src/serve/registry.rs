//! The multi-tenant session registry: named, long-lived
//! [`MonitorSession`]s that HTTP clients create, feed one layer at a
//! time and query for break/momax deltas — the PR 2 near-real-time
//! ingest loop made network-reachable.
//!
//! Every session sits behind its own mutex, so concurrent clients'
//! requests against one session serialise cleanly while different
//! sessions proceed in parallel. With a state directory configured,
//! each session persists under `<dir>/<name>/` through the monitor
//! session's staged save, and [`SessionRegistry::open`] resumes every
//! one of them — a killed-and-restarted server continues **bit-exact**
//! after the last acknowledged ingest (the save/load contract pinned
//! by `tests/monitor.rs`, exercised over sockets by `tests/serve.rs`).

use crate::error::{ensure, err, Context, Result};
use crate::monitor::{IngestDelta, MonitorSession};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Session names become path components under the state directory —
/// keep them boring: `[A-Za-z0-9_-]`, at most 64 bytes.
pub fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'-' || b == b'_')
}

/// Registry of named monitor sessions. See module docs.
pub struct SessionRegistry {
    state_dir: Option<PathBuf>,
    sessions: Mutex<BTreeMap<String, Arc<Mutex<MonitorSession>>>>,
    ingested: AtomicU64,
}

impl SessionRegistry {
    /// Open a registry. With a state directory, every
    /// `<dir>/<name>/session.json` is resumed (`threads` tunes the
    /// resumed sessions' ingest sharding in this process only).
    pub fn open(state_dir: Option<PathBuf>, threads: usize) -> Result<Self> {
        let mut sessions = BTreeMap::new();
        if let Some(dir) = &state_dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating state dir {}", dir.display()))?;
            for entry in std::fs::read_dir(dir)
                .with_context(|| format!("scanning state dir {}", dir.display()))?
            {
                let entry = entry?;
                let name = entry.file_name().to_string_lossy().into_owned();
                if !valid_name(&name) {
                    continue; // staging siblings (*.tmp / *.old), strays
                }
                if !entry.path().join("session.json").exists() {
                    continue;
                }
                let session = MonitorSession::load(entry.path(), threads)
                    .with_context(|| format!("resuming session {name:?}"))?;
                sessions.insert(name, Arc::new(Mutex::new(session)));
            }
        }
        Ok(Self {
            state_dir,
            sessions: Mutex::new(sessions),
            ingested: AtomicU64::new(0),
        })
    }

    /// Register (and persist) a freshly primed session.
    pub fn insert(&self, name: &str, session: MonitorSession) -> Result<()> {
        ensure!(
            valid_name(name),
            "invalid session name {name:?} (use [A-Za-z0-9_-], at most 64 chars)"
        );
        let arc = Arc::new(Mutex::new(session));
        {
            let mut map = self.sessions.lock().unwrap();
            ensure!(!map.contains_key(name), "session {name:?} already exists");
            map.insert(name.to_string(), Arc::clone(&arc));
        }
        if let Err(e) = self.persist(name, &arc) {
            self.sessions.lock().unwrap().remove(name);
            return Err(e);
        }
        Ok(())
    }

    pub fn contains(&self, name: &str) -> bool {
        self.sessions.lock().unwrap().contains_key(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.sessions.lock().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Layers ingested through this registry since it opened.
    pub fn layers_ingested(&self) -> u64 {
        self.ingested.load(Ordering::Relaxed)
    }

    fn get(&self, name: &str) -> Result<Arc<Mutex<MonitorSession>>> {
        self.sessions
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| err!("no session named {name:?}"))
    }

    /// Run `f` with the named session locked — the serialisation point
    /// that keeps concurrent clients' reads consistent with ingests.
    pub fn with_session<T>(&self, name: &str, f: impl FnOnce(&MonitorSession) -> T) -> Result<T> {
        let arc = self.get(name)?;
        let guard = arc.lock().unwrap();
        Ok(f(&guard))
    }

    /// Ingest one layer into the named session, persisting the grown
    /// state before returning — a killed-and-restarted server resumes
    /// exactly after the last acknowledged ingest.
    pub fn ingest(&self, name: &str, t: f64, layer: &[f32]) -> Result<IngestDelta> {
        let arc = self.get(name)?;
        let mut guard = arc.lock().unwrap();
        let delta = guard.ingest(t, layer)?;
        if let Some(dir) = &self.state_dir {
            guard
                .save(dir.join(name))
                .with_context(|| format!("persisting session {name:?}"))?;
        }
        self.ingested.fetch_add(1, Ordering::Relaxed);
        Ok(delta)
    }

    fn persist(&self, name: &str, session: &Arc<Mutex<MonitorSession>>) -> Result<()> {
        if let Some(dir) = &self.state_dir {
            session
                .lock()
                .unwrap()
                .save(dir.join(name))
                .with_context(|| format!("persisting session {name:?}"))?;
        }
        Ok(())
    }

    /// Persist every session (the shutdown path; each ingest already
    /// saved, so this only matters for just-created idle sessions).
    pub fn save_all(&self) -> Result<()> {
        let map = self.sessions.lock().unwrap();
        for (name, arc) in map.iter() {
            self.persist(name, arc)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::MonitorConfig;
    use crate::params::BfastParams;
    use crate::synth::ArtificialDataset;

    fn session(m: usize, seed: u64) -> MonitorSession {
        let params = BfastParams::with_lambda(44, 36, 12, 1, 12.0, 0.05, 3.0).unwrap();
        let data = ArtificialDataset::new(params.clone(), m, seed).generate();
        MonitorSession::start(&data.stack, &params, MonitorConfig::default()).unwrap()
    }

    #[test]
    fn name_validation() {
        for good in ["a", "forest-2026", "Tile_007", &"x".repeat(64)] {
            assert!(valid_name(good), "{good:?}");
        }
        for bad in ["", "a/b", "..", "a b", "é", &"x".repeat(65)] {
            assert!(!valid_name(bad), "{bad:?}");
        }
    }

    #[test]
    fn insert_rejects_duplicates_and_bad_names() {
        let reg = SessionRegistry::open(None, 2).unwrap();
        reg.insert("alpha", session(6, 1)).unwrap();
        assert!(reg.contains("alpha"));
        assert!(reg.insert("alpha", session(6, 2)).is_err());
        assert!(reg.insert("../evil", session(6, 3)).is_err());
        assert_eq!(reg.names(), vec!["alpha".to_string()]);
    }

    #[test]
    fn state_dir_roundtrip_resumes_sessions() {
        let dir = std::env::temp_dir().join(format!("bfast_reg_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        {
            let reg = SessionRegistry::open(Some(dir.clone()), 2).unwrap();
            reg.insert("tile-1", session(8, 4)).unwrap();
            reg.insert("tile-2", session(5, 5)).unwrap();
        }
        let reg = SessionRegistry::open(Some(dir.clone()), 2).unwrap();
        assert_eq!(reg.names(), vec!["tile-1".to_string(), "tile-2".to_string()]);
        let px = reg.with_session("tile-2", |s| s.n_pixels()).unwrap();
        assert_eq!(px, 5);
        assert!(reg.with_session("missing", |_| ()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
