//! The job scheduler behind `POST /v1/runs`: a **bounded FIFO** of
//! analysis jobs with per-job status, and a small worker pool that
//! drains it through one shared [`SharedBfastRunner`].
//!
//! Backpressure is explicit: once `capacity` jobs are waiting,
//! [`JobQueue::submit`] refuses with [`SubmitError::Full`] and the
//! HTTP layer answers 429 — the queue never grows without bound under
//! a traffic spike. Each run is internally parallel (staging workers +
//! executor), so a scheduler worker count of 1–2 keeps the machine
//! saturated without oversubscribing it.
//!
//! Shutdown is graceful end to end: [`JobQueue::shutdown`] stops
//! intake and wakes the workers, which finish every job already
//! accepted before [`Scheduler::join`] returns.

use crate::coordinator::{RunResult, SharedBfastRunner};
use crate::metrics::PhaseTimes;
use crate::params::BfastParams;
use crate::raster::TimeStack;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

/// One analysis job: a scene plus its (validated) parameters.
pub struct JobSpec {
    pub stack: TimeStack,
    pub params: BfastParams,
}

/// Lifecycle of a job.
#[derive(Clone, Debug, PartialEq)]
pub enum JobState {
    Queued,
    Running { chunks_done: usize, chunks_total: usize },
    Done,
    Failed { error: String },
}

impl JobState {
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running { .. } => "running",
            JobState::Done => "done",
            JobState::Failed { .. } => "failed",
        }
    }

    /// Fraction complete in [0, 1] (chunks executed / planned).
    pub fn progress(&self) -> f64 {
        match self {
            JobState::Queued => 0.0,
            JobState::Running { chunks_done, chunks_total } => {
                if *chunks_total == 0 {
                    0.0
                } else {
                    *chunks_done as f64 / *chunks_total as f64
                }
            }
            JobState::Done | JobState::Failed { .. } => 1.0,
        }
    }
}

/// Everything the API needs to answer status/map queries for one job.
pub struct JobRecord {
    pub id: u64,
    pub state: JobState,
    /// Scene geometry recorded at submission (PGM rendering).
    pub width: Option<usize>,
    pub height: Option<usize>,
    pub pixels: usize,
    pub result: Option<RunResult>,
}

/// Why a submission was refused.
#[derive(Debug)]
pub enum SubmitError {
    /// The bounded FIFO is at capacity — the HTTP 429 signal.
    Full { capacity: usize },
    /// The queue is shutting down — HTTP 503.
    ShuttingDown,
}

/// Counter snapshot for `/metrics`.
pub struct QueueStats {
    pub submitted: u64,
    pub rejected: u64,
    pub queued: usize,
    pub running: usize,
    pub done: usize,
    pub failed: usize,
    /// Engine phase times accumulated across every completed run.
    pub phases: PhaseTimes,
}

/// Finished-job records retained for status/map queries. The oldest
/// finished records beyond this are evicted — each one holds a full
/// break map, so retention must be bounded for a long-lived server
/// (pending/running jobs are never evicted).
pub const MAX_FINISHED_RECORDS: usize = 256;

struct QueueInner {
    pending: VecDeque<(u64, JobSpec)>,
    records: BTreeMap<u64, JobRecord>,
    next_id: u64,
    shutdown: bool,
    submitted: u64,
    rejected: u64,
    phases: PhaseTimes,
}

impl QueueInner {
    fn evict_finished(&mut self) {
        let finished: Vec<u64> = self
            .records
            .iter()
            .filter(|(_, r)| matches!(r.state, JobState::Done | JobState::Failed { .. }))
            .map(|(&id, _)| id)
            .collect();
        if finished.len() > MAX_FINISHED_RECORDS {
            // BTreeMap iterates id-ascending, so the front is oldest
            for id in &finished[..finished.len() - MAX_FINISHED_RECORDS] {
                self.records.remove(id);
            }
        }
    }
}

/// Bounded FIFO of analysis jobs. See module docs.
pub struct JobQueue {
    capacity: usize,
    inner: Mutex<QueueInner>,
    ready: Condvar,
}

impl JobQueue {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            inner: Mutex::new(QueueInner {
                pending: VecDeque::new(),
                records: BTreeMap::new(),
                next_id: 1,
                shutdown: false,
                submitted: 0,
                rejected: 0,
                phases: PhaseTimes::new(),
            }),
            ready: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Enqueue a job; `Err(Full)` is the 429 backpressure signal.
    pub fn submit(&self, spec: JobSpec) -> std::result::Result<u64, SubmitError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.shutdown {
            return Err(SubmitError::ShuttingDown);
        }
        if inner.pending.len() >= self.capacity {
            inner.rejected += 1;
            return Err(SubmitError::Full { capacity: self.capacity });
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.submitted += 1;
        inner.records.insert(
            id,
            JobRecord {
                id,
                state: JobState::Queued,
                width: spec.stack.width,
                height: spec.stack.height,
                pixels: spec.stack.n_pixels(),
                result: None,
            },
        );
        inner.pending.push_back((id, spec));
        drop(inner);
        self.ready.notify_one();
        Ok(id)
    }

    /// Blocking pop for scheduler workers; marks the job running.
    /// Returns `None` only once the queue is shut down *and* drained.
    fn next_job(&self) -> Option<(u64, JobSpec)> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some((id, spec)) = inner.pending.pop_front() {
                if let Some(rec) = inner.records.get_mut(&id) {
                    rec.state = JobState::Running { chunks_done: 0, chunks_total: 0 };
                }
                return Some((id, spec));
            }
            if inner.shutdown {
                return None;
            }
            inner = self.ready.wait(inner).unwrap();
        }
    }

    fn set_progress(&self, id: u64, done: usize, total: usize) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(rec) = inner.records.get_mut(&id) {
            rec.state = JobState::Running { chunks_done: done, chunks_total: total };
        }
    }

    fn complete(&self, id: u64, result: RunResult) {
        let mut inner = self.inner.lock().unwrap();
        inner.phases.merge(&result.phases);
        if let Some(rec) = inner.records.get_mut(&id) {
            rec.state = JobState::Done;
            rec.result = Some(result);
        }
        inner.evict_finished();
    }

    fn fail(&self, id: u64, error: String) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(rec) = inner.records.get_mut(&id) {
            rec.state = JobState::Failed { error };
        }
        inner.evict_finished();
    }

    /// Read one job's record under the lock.
    pub fn with_record<T>(&self, id: u64, f: impl FnOnce(&JobRecord) -> T) -> Option<T> {
        let inner = self.inner.lock().unwrap();
        inner.records.get(&id).map(f)
    }

    /// `(id, state)` of every retained job, in submission order
    /// (finished records beyond [`MAX_FINISHED_RECORDS`] are evicted).
    pub fn jobs(&self) -> Vec<(u64, JobState)> {
        let inner = self.inner.lock().unwrap();
        inner.records.values().map(|r| (r.id, r.state.clone())).collect()
    }

    /// Jobs waiting for a worker.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().pending.len()
    }

    /// Counters + per-state tallies + accumulated phase times.
    pub fn stats(&self) -> QueueStats {
        let inner = self.inner.lock().unwrap();
        let mut stats = QueueStats {
            submitted: inner.submitted,
            rejected: inner.rejected,
            queued: 0,
            running: 0,
            done: 0,
            failed: 0,
            phases: inner.phases.clone(),
        };
        for r in inner.records.values() {
            match &r.state {
                JobState::Queued => stats.queued += 1,
                JobState::Running { .. } => stats.running += 1,
                JobState::Done => stats.done += 1,
                JobState::Failed { .. } => stats.failed += 1,
            }
        }
        stats
    }

    /// Stop accepting work and wake every worker; jobs already
    /// accepted still run to completion before the workers exit.
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().shutdown = true;
        self.ready.notify_all();
    }
}

/// Scheduler workers draining the queue through one shared runner.
pub struct Scheduler {
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Scheduler {
    pub fn start(
        queue: Arc<JobQueue>,
        runner: Arc<SharedBfastRunner>,
        workers: usize,
    ) -> Scheduler {
        let workers = (0..workers.max(1))
            .map(|_| {
                let queue = Arc::clone(&queue);
                let runner = Arc::clone(&runner);
                std::thread::spawn(move || {
                    while let Some((id, spec)) = queue.next_job() {
                        // contain panics: a panicking run must mark its
                        // job failed, not kill the worker (with the
                        // default single worker that would stall the
                        // whole queue, jobs stuck in "running" forever)
                        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            runner.run_with_progress(&spec.stack, &spec.params, |done, total| {
                                queue.set_progress(id, done, total)
                            })
                        }));
                        match res {
                            Ok(Ok(r)) => queue.complete(id, r),
                            Ok(Err(e)) => queue.fail(id, format!("{e:#}")),
                            Err(_) => queue.fail(id, "analysis panicked".to_string()),
                        }
                    }
                })
            })
            .collect();
        Scheduler { workers }
    }

    /// Join every worker (call after [`JobQueue::shutdown`]).
    pub fn join(self) {
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RunnerConfig;
    use crate::synth::ArtificialDataset;

    fn spec(m: usize, seed: u64) -> JobSpec {
        let params = BfastParams::with_lambda(48, 36, 12, 1, 12.0, 0.05, 3.0).unwrap();
        let stack = ArtificialDataset::new(params.clone(), m, seed).generate().stack;
        JobSpec { stack, params }
    }

    #[test]
    fn backpressure_rejects_submissions_beyond_capacity() {
        // no scheduler attached: the queue fills deterministically
        let q = JobQueue::new(2);
        assert!(q.submit(spec(4, 1)).is_ok());
        assert!(q.submit(spec(4, 2)).is_ok());
        match q.submit(spec(4, 3)) {
            Err(SubmitError::Full { capacity }) => assert_eq!(capacity, 2),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.depth(), 2);
        let stats = q.stats();
        assert_eq!(stats.submitted, 2);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.queued, 2);
        q.shutdown();
        match q.submit(spec(4, 4)) {
            Err(SubmitError::ShuttingDown) => {}
            other => panic!("expected ShuttingDown, got {other:?}"),
        }
    }

    #[test]
    fn scheduler_drains_jobs_and_records_results() {
        let q = Arc::new(JobQueue::new(8));
        let runner =
            Arc::new(SharedBfastRunner::emulated_shared(RunnerConfig::default()).unwrap());
        let ids: Vec<u64> = (0..3).map(|i| q.submit(spec(40, i)).unwrap()).collect();
        let sched = Scheduler::start(Arc::clone(&q), runner, 2);
        q.shutdown(); // graceful: accepted jobs still run
        sched.join();
        for id in ids {
            let (label, breaks) = q
                .with_record(id, |rec| {
                    (rec.state.label(), rec.result.as_ref().map(|r| r.map.len()))
                })
                .unwrap();
            assert_eq!(label, "done", "job {id}");
            assert_eq!(breaks, Some(40), "job {id}");
        }
        let stats = q.stats();
        assert_eq!(stats.done, 3);
        assert_eq!(stats.failed, 0);
        assert!(stats.phases.total().as_secs_f64() >= 0.0);
    }

    #[test]
    fn failed_jobs_carry_their_error() {
        let q = Arc::new(JobQueue::new(4));
        let runner =
            Arc::new(SharedBfastRunner::emulated_shared(RunnerConfig::default()).unwrap());
        // params/stack mismatch surfaces as a failed job, not a panic
        let params = BfastParams::with_lambda(48, 36, 12, 1, 12.0, 0.05, 3.0).unwrap();
        let stack = crate::raster::TimeStack::zeros(10, 4);
        let id = q.submit(JobSpec { stack, params }).unwrap();
        let sched = Scheduler::start(Arc::clone(&q), runner, 1);
        q.shutdown();
        sched.join();
        let state = q.with_record(id, |rec| rec.state.clone()).unwrap();
        match state {
            JobState::Failed { error } => assert!(error.contains("10"), "{error}"),
            other => panic!("expected failure, got {other:?}"),
        }
    }
}
