//! The job scheduler behind `POST /v1/runs`: a **bounded FIFO** of
//! [`AnalysisRequest`]s with per-job status, and a small worker pool
//! that drains it through one shared [`SharedBfastRunner`].
//!
//! The queue speaks the `bfast::api` vocabulary end to end: what it
//! stores *is* the wire/job description (no private job struct), each
//! record carries the request's [`JobHandle`] so progress is observed
//! and cancellation ([`JobQueue::cancel`], `DELETE /v1/runs/{id}`)
//! reaches a running analysis at its next chunk boundary.
//!
//! Backpressure is explicit: once `capacity` jobs are waiting,
//! [`JobQueue::submit`] refuses with [`SubmitError::Full`] and the
//! HTTP layer answers 429 — the queue never grows without bound under
//! a traffic spike. Each run is internally parallel (staging workers +
//! executor), so a scheduler worker count of 1–2 keeps the machine
//! saturated without oversubscribing it.
//!
//! Queued requests that share a chunk contract (inline scenes over
//! the same time axis and bitwise-equal parameters — see
//! [`crate::cmd::batch_compatible`]) are drained **batched**: one
//! worker pops up to [`MAX_BATCH`] of them at once and executes them
//! through a single recorded multi-job command stream on one prepared
//! engine, so a lone worker saturates on many small requests. Every
//! batched job keeps its own record, result, and terminal state, and
//! its break map is bit-identical to running it alone. Jobs submitted
//! with `outputs.record` never batch — their `.bcmd` must describe
//! exactly one request — and instead attach the recorded stream for
//! `GET /v1/runs/{id}/cmdstream`.
//!
//! Finished records (each holds a full break map) are retained under a
//! configurable [`EvictionPolicy`] — a count cap plus a maximum age —
//! so a long-lived server's memory stays bounded no matter the traffic
//! shape. Pending/running jobs are never evicted.
//!
//! Shutdown is graceful end to end: [`JobQueue::shutdown`] stops
//! intake and wakes the workers, which finish every job already
//! accepted before [`Scheduler::join`] returns.

use crate::api::{self, AnalysisRequest, AnalysisResult, JobHandle};
use crate::coordinator::{RunResult, SharedBfastRunner};
use crate::error::Result;
use crate::metrics::{Histogram, PhaseTimes};
use crate::params::BfastParams;
use crate::raster::TimeStack;
use crate::store::ResultCache;
use crate::trace::{self, Recorder};
use std::borrow::Cow;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Upper bound on queued jobs drained into one batched command stream.
pub const MAX_BATCH: usize = 8;

/// Lifecycle of a job.
#[derive(Clone, Debug, PartialEq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed { error: String },
    Cancelled,
}

impl JobState {
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed { .. } => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// Terminal states (the ones the eviction policy may reap).
    pub fn is_finished(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed { .. } | JobState::Cancelled)
    }
}

/// Everything the API needs to answer status/map queries for one job.
pub struct JobRecord {
    pub id: u64,
    pub state: JobState,
    /// Progress + cancellation of this job (shared with the worker).
    pub handle: JobHandle,
    /// Request id stamped at submission (client-supplied or minted);
    /// every log line and trace span of this job carries it.
    pub request_id: String,
    /// Flight recorder for this job's span tree (`None` when tracing
    /// is disabled). Served by `GET /v1/runs/{id}/trace`.
    pub recorder: Option<Recorder>,
    /// When the job entered the queue (queue-wait + end-to-end
    /// latency histograms).
    pub submitted_at: Instant,
    /// Scene geometry recorded at submission (PGM rendering); known
    /// only for inline scenes until the run resolves the source.
    pub width: Option<usize>,
    pub height: Option<usize>,
    pub pixels: Option<usize>,
    /// Content digest of the request (scene bytes + result-relevant
    /// fields), when the front door computed one. Keys the result
    /// cache and doubles as the result endpoint's `ETag`.
    pub digest: Option<String>,
    /// The record was born finished from a cache hit: no queue wait,
    /// no scheduler worker, result attached at submission.
    pub cached: bool,
    pub result: Option<AnalysisResult>,
    /// Encoded `.bcmd` bytes, attached at completion for jobs
    /// submitted with `outputs.record` (the job executed by replaying
    /// exactly this stream). Served by `GET /v1/runs/{id}/cmdstream`.
    pub cmdstream: Option<Vec<u8>>,
    /// When the job reached a terminal state (age-based eviction).
    pub finished_at: Option<Instant>,
}

impl JobRecord {
    /// Fraction complete in [0, 1] (chunks executed / planned). Only
    /// `Done` reports 1.0; a cancelled or failed job reports how far
    /// it actually got, consistent with its `chunks_done/chunks_total`.
    pub fn progress(&self) -> f64 {
        match &self.state {
            JobState::Queued => 0.0,
            JobState::Done => 1.0,
            _ => {
                let (done, total) = self.handle.progress();
                if total == 0 {
                    0.0
                } else {
                    done as f64 / total as f64
                }
            }
        }
    }
}

/// Why a submission was refused.
#[derive(Debug)]
pub enum SubmitError {
    /// The bounded FIFO is at capacity — the HTTP 429 signal.
    Full { capacity: usize },
    /// The queue is shutting down — HTTP 503.
    ShuttingDown,
}

/// What [`JobQueue::cancel`] achieved.
#[derive(Debug, PartialEq, Eq)]
pub enum CancelOutcome {
    /// Cancellation took effect (immediately for a queued job; at the
    /// next chunk boundary for a running one).
    Cancelled,
    /// The job already reached a terminal state — HTTP 409.
    AlreadyFinished,
    /// No such job — HTTP 404.
    NotFound,
}

/// Retention of finished job records: keep at most `max_finished`, and
/// none older than `max_age` since finishing (`max_age` of zero means
/// *no age limit* — only the count cap applies). Both limits apply;
/// pending/running jobs are exempt.
#[derive(Clone, Debug)]
pub struct EvictionPolicy {
    pub max_finished: usize,
    pub max_age: Duration,
}

impl Default for EvictionPolicy {
    fn default() -> Self {
        Self { max_finished: 256, max_age: Duration::from_secs(3600) }
    }
}

/// Counter snapshot for `/metrics`.
pub struct QueueStats {
    pub submitted: u64,
    pub rejected: u64,
    /// Finished records reaped by the eviction policy.
    pub evicted: u64,
    pub queued: usize,
    pub running: usize,
    pub done: usize,
    pub failed: usize,
    pub cancelled: usize,
    /// Chunks executed across every completed run — the monotonic
    /// counter a gateway scrapes to estimate this worker's throughput.
    pub chunks_done: u64,
    /// Jobs that executed through a multi-job batched command stream
    /// (two or more compatible queued requests per prepared engine).
    pub batched: u64,
    /// Engine phase times accumulated across every completed run.
    pub phases: PhaseTimes,
}

struct QueueInner {
    pending: VecDeque<(u64, AnalysisRequest)>,
    records: BTreeMap<u64, JobRecord>,
    next_id: u64,
    shutdown: bool,
    submitted: u64,
    rejected: u64,
    evicted: u64,
    chunks_done: u64,
    batched: u64,
    phases: PhaseTimes,
}

impl QueueInner {
    /// Apply the eviction policy (called whenever the lock is already
    /// held and the record set may have changed).
    fn evict_finished(&mut self, policy: &EvictionPolicy) {
        let now = Instant::now();
        let mut finished: Vec<u64> = Vec::new();
        let mut expired: Vec<u64> = Vec::new();
        for (&id, rec) in &self.records {
            if !rec.state.is_finished() {
                continue;
            }
            // max_age zero = unlimited (the natural CLI spelling for
            // "keep until the count cap evicts it")
            let old = !policy.max_age.is_zero()
                && rec
                    .finished_at
                    .is_some_and(|at| now.duration_since(at) >= policy.max_age);
            if old {
                expired.push(id);
            } else {
                finished.push(id);
            }
        }
        for id in expired {
            self.records.remove(&id);
            self.evicted += 1;
        }
        if finished.len() > policy.max_finished {
            // BTreeMap iterates id-ascending, so the front is oldest
            for id in &finished[..finished.len() - policy.max_finished] {
                self.records.remove(id);
                self.evicted += 1;
            }
        }
    }
}

/// One unit of work handed to a scheduler worker by [`JobQueue::next_batch`].
struct NextJob {
    id: u64,
    req: AnalysisRequest,
    handle: JobHandle,
    request_id: String,
    recorder: Option<Recorder>,
}

/// Bounded FIFO of analysis jobs. See module docs.
pub struct JobQueue {
    capacity: usize,
    policy: EvictionPolicy,
    inner: Mutex<QueueInner>,
    ready: Condvar,
    /// Result cache filled when jobs with a digest complete (`None`
    /// when the server runs uncached).
    cache: Option<Arc<ResultCache>>,
    /// Seconds jobs spent queued before a worker picked them up.
    queue_wait: Histogram,
    /// Seconds from submission to a terminal state.
    run_latency: Histogram,
}

impl JobQueue {
    pub fn new(capacity: usize) -> Self {
        Self::with_policy(capacity, EvictionPolicy::default())
    }

    pub fn with_policy(capacity: usize, policy: EvictionPolicy) -> Self {
        Self {
            capacity: capacity.max(1),
            policy: EvictionPolicy { max_finished: policy.max_finished.max(1), ..policy },
            inner: Mutex::new(QueueInner {
                pending: VecDeque::new(),
                records: BTreeMap::new(),
                next_id: 1,
                shutdown: false,
                submitted: 0,
                rejected: 0,
                evicted: 0,
                chunks_done: 0,
                batched: 0,
                phases: PhaseTimes::new(),
            }),
            ready: Condvar::new(),
            cache: None,
            queue_wait: Histogram::queue_wait(),
            run_latency: Histogram::run_latency(),
        }
    }

    /// Attach a result cache: completed jobs carrying a digest publish
    /// their serialised envelope into it.
    pub fn with_cache(mut self, cache: Arc<ResultCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Queue-wait histogram (submission → worker pickup), for `/metrics`.
    pub fn queue_wait(&self) -> &Histogram {
        &self.queue_wait
    }

    /// End-to-end latency histogram (submission → terminal state).
    pub fn run_latency(&self) -> &Histogram {
        &self.run_latency
    }

    pub fn policy(&self) -> &EvictionPolicy {
        &self.policy
    }

    /// Enqueue a request; `Err(Full)` is the 429 backpressure signal.
    /// The job's request id is taken from the request (minted here
    /// when absent) and a flight recorder is opened for its span tree.
    pub fn submit(&self, req: AnalysisRequest) -> std::result::Result<u64, SubmitError> {
        self.submit_with_digest(req, None)
    }

    /// [`submit`](Self::submit) with the request's content digest
    /// attached (the front door computes it once for the cache lookup;
    /// carrying it here lets completion fill the cache and the result
    /// endpoint emit it as an `ETag`).
    pub fn submit_with_digest(
        &self,
        mut req: AnalysisRequest,
        digest: Option<String>,
    ) -> std::result::Result<u64, SubmitError> {
        let request_id =
            req.request_id.clone().unwrap_or_else(trace::new_request_id);
        req.request_id = Some(request_id.clone());
        let mut inner = self.inner.lock().unwrap();
        if inner.shutdown {
            return Err(SubmitError::ShuttingDown);
        }
        if inner.pending.len() >= self.capacity {
            inner.rejected += 1;
            return Err(SubmitError::Full { capacity: self.capacity });
        }
        let (width, height, pixels) = match &req.source {
            api::SceneSource::Inline(s) => (s.width, s.height, Some(s.n_pixels())),
            _ => (None, None, None),
        };
        let id = inner.next_id;
        inner.next_id += 1;
        inner.submitted += 1;
        inner.records.insert(
            id,
            JobRecord {
                id,
                state: JobState::Queued,
                handle: JobHandle::new(),
                request_id: request_id.clone(),
                recorder: Recorder::new(&request_id),
                submitted_at: Instant::now(),
                width,
                height,
                pixels,
                digest,
                cached: false,
                result: None,
                cmdstream: None,
                finished_at: None,
            },
        );
        inner.pending.push_back((id, req));
        inner.evict_finished(&self.policy); // lazy age sweep
        drop(inner);
        self.ready.notify_one();
        Ok(id)
    }

    /// Insert a record born `Done` from a result-cache hit: the
    /// finished result is attached at submission, the FIFO and the
    /// scheduler workers are never involved, and the record is marked
    /// `cached` so the status API can say so. Counts as a submission
    /// (and still refuses during shutdown, like [`submit`](Self::submit)).
    pub fn insert_cached(
        &self,
        request_id: Option<String>,
        digest: &str,
        result: AnalysisResult,
    ) -> std::result::Result<u64, SubmitError> {
        let request_id = request_id.unwrap_or_else(trace::new_request_id);
        let mut inner = self.inner.lock().unwrap();
        if inner.shutdown {
            return Err(SubmitError::ShuttingDown);
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.submitted += 1;
        let handle = JobHandle::new();
        handle.set_progress(result.chunks, result.chunks);
        let now = Instant::now();
        inner.records.insert(
            id,
            JobRecord {
                id,
                state: JobState::Done,
                handle,
                request_id: request_id.clone(),
                recorder: Recorder::new(&request_id),
                submitted_at: now,
                width: result.width,
                height: result.height,
                pixels: Some(result.map.len()),
                digest: Some(digest.to_string()),
                cached: true,
                result: Some(result),
                cmdstream: None,
                finished_at: Some(now),
            },
        );
        inner.evict_finished(&self.policy);
        Ok(id)
    }

    /// Mark a popped job running, observe its queue wait and build the
    /// worker handoff (`None` if its record vanished, which cannot
    /// happen: pending jobs are never evicted).
    fn claim_locked(
        &self,
        inner: &mut QueueInner,
        id: u64,
        req: AnalysisRequest,
    ) -> Option<NextJob> {
        let rec = inner.records.get_mut(&id)?;
        rec.state = JobState::Running;
        self.queue_wait.observe(rec.submitted_at.elapsed().as_secs_f64());
        Some(NextJob {
            id,
            req,
            handle: rec.handle.clone(),
            request_id: rec.request_id.clone(),
            recorder: rec.recorder.clone(),
        })
    }

    /// Blocking pop for scheduler workers: hands back the oldest
    /// queued job plus every younger queued job that can share its
    /// command stream (capped at [`MAX_BATCH`]; see
    /// [`crate::cmd::batch_compatible`]). Jobs recording a `.bcmd`
    /// never batch. Marks every returned job running and observes its
    /// queue wait. Returns `None` only once the queue is shut down
    /// *and* drained.
    fn next_batch(&self) -> Option<Vec<NextJob>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some((id, req)) = inner.pending.pop_front() {
                let Some(first) = self.claim_locked(&mut inner, id, req) else {
                    continue;
                };
                let mut batch = vec![first];
                if !batch[0].req.outputs.record {
                    while batch.len() < MAX_BATCH {
                        let next = inner.pending.iter().position(|(_, r)| {
                            !r.outputs.record && crate::cmd::batch_compatible(&batch[0].req, r)
                        });
                        let Some(pos) = next else { break };
                        let Some((id, req)) = inner.pending.remove(pos) else { break };
                        if let Some(job) = self.claim_locked(&mut inner, id, req) {
                            batch.push(job);
                        }
                    }
                }
                if batch.len() > 1 {
                    inner.batched += batch.len() as u64;
                }
                return Some(batch);
            }
            if inner.shutdown {
                return None;
            }
            inner = self.ready.wait(inner).unwrap();
        }
    }

    /// Attach the recorded `.bcmd` bytes to a job (worker-side, for
    /// requests submitted with `outputs.record`).
    fn attach_cmdstream(&self, id: u64, bytes: Vec<u8>) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(rec) = inner.records.get_mut(&id) {
            rec.cmdstream = Some(bytes);
        }
    }

    fn complete(&self, id: u64, result: AnalysisResult) {
        // Serialise the cache envelope before taking the queue lock:
        // envelopes are scene-sized and the lock is hot. The digest is
        // immutable after submission, so the two lock windows agree.
        let fill = self.cache.as_ref().filter(|c| c.enabled()).and_then(|cache| {
            let digest = self
                .inner
                .lock()
                .unwrap()
                .records
                .get(&id)
                .and_then(|rec| rec.digest.clone())?;
            Some((Arc::clone(cache), digest, Arc::<str>::from(result.to_json_string())))
        });
        let mut inner = self.inner.lock().unwrap();
        if let Some(p) = &result.phases {
            inner.phases.merge(p);
        }
        inner.chunks_done += result.chunks as u64;
        if let Some(rec) = inner.records.get_mut(&id) {
            rec.state = JobState::Done;
            self.run_latency.observe(rec.submitted_at.elapsed().as_secs_f64());
            // the run's own view wins: a pixel_range request analyses a
            // slice, whose map no longer matches the submitted scene's
            // geometry (PGM rendering would assert on the mismatch)
            rec.pixels = Some(result.map.len());
            rec.width = result.width;
            rec.height = result.height;
            rec.result = Some(result);
            rec.finished_at = Some(Instant::now());
        }
        inner.evict_finished(&self.policy);
        drop(inner);
        if let Some((cache, digest, body)) = fill {
            cache.put(&digest, body);
        }
    }

    fn fail(&self, id: u64, error: String) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(rec) = inner.records.get_mut(&id) {
            rec.state = JobState::Failed { error };
            self.run_latency.observe(rec.submitted_at.elapsed().as_secs_f64());
            rec.finished_at = Some(Instant::now());
        }
        inner.evict_finished(&self.policy);
    }

    /// The worker observed the run stop on a cancelled token.
    fn mark_cancelled(&self, id: u64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(rec) = inner.records.get_mut(&id) {
            rec.state = JobState::Cancelled;
            self.run_latency.observe(rec.submitted_at.elapsed().as_secs_f64());
            rec.finished_at = Some(Instant::now());
        }
        inner.evict_finished(&self.policy);
    }

    /// Cancel a job: a queued one is removed from the FIFO and marked
    /// immediately; a running one has its token set and stops at the
    /// next chunk boundary (the record transitions when the worker
    /// observes the cancelled run).
    pub fn cancel(&self, id: u64) -> CancelOutcome {
        let mut inner = self.inner.lock().unwrap();
        let state = match inner.records.get(&id) {
            None => return CancelOutcome::NotFound,
            Some(rec) => rec.state.clone(),
        };
        match state {
            JobState::Queued => {
                inner.pending.retain(|(pid, _)| *pid != id);
                if let Some(rec) = inner.records.get_mut(&id) {
                    rec.handle.cancel();
                    rec.state = JobState::Cancelled;
                    rec.finished_at = Some(Instant::now());
                }
                CancelOutcome::Cancelled
            }
            JobState::Running => {
                if let Some(rec) = inner.records.get(&id) {
                    rec.handle.cancel();
                }
                CancelOutcome::Cancelled
            }
            _ => CancelOutcome::AlreadyFinished,
        }
    }

    /// Read one job's record under the lock. Sweeps the eviction
    /// policy first, so an idle server's expired records disappear on
    /// read, not only at the next submit/terminal event.
    pub fn with_record<T>(&self, id: u64, f: impl FnOnce(&JobRecord) -> T) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        inner.evict_finished(&self.policy);
        inner.records.get(&id).map(f)
    }

    /// `(id, state, progress)` of every retained job, in submission
    /// order (finished records are reaped per the eviction policy).
    pub fn jobs(&self) -> Vec<(u64, JobState, f64)> {
        let mut inner = self.inner.lock().unwrap();
        inner.evict_finished(&self.policy);
        inner
            .records
            .values()
            .map(|r| (r.id, r.state.clone(), r.progress()))
            .collect()
    }

    /// Jobs waiting for a worker.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().pending.len()
    }

    /// Counters + per-state tallies + accumulated phase times (age
    /// sweep included, like the other read paths).
    pub fn stats(&self) -> QueueStats {
        let mut inner = self.inner.lock().unwrap();
        inner.evict_finished(&self.policy);
        let mut stats = QueueStats {
            submitted: inner.submitted,
            rejected: inner.rejected,
            evicted: inner.evicted,
            chunks_done: inner.chunks_done,
            batched: inner.batched,
            queued: 0,
            running: 0,
            done: 0,
            failed: 0,
            cancelled: 0,
            phases: inner.phases.clone(),
        };
        for r in inner.records.values() {
            match &r.state {
                JobState::Queued => stats.queued += 1,
                JobState::Running => stats.running += 1,
                JobState::Done => stats.done += 1,
                JobState::Failed { .. } => stats.failed += 1,
                JobState::Cancelled => stats.cancelled += 1,
            }
        }
        stats
    }

    /// Stop accepting work and wake every worker; jobs already
    /// accepted still run to completion before the workers exit.
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().shutdown = true;
        self.ready.notify_all();
    }
}

/// Scheduler workers draining the queue through one shared runner.
pub struct Scheduler {
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Scheduler {
    pub fn start(
        queue: Arc<JobQueue>,
        runner: Arc<SharedBfastRunner>,
        workers: usize,
    ) -> Scheduler {
        let workers = (0..workers.max(1))
            .map(|_| {
                let queue = Arc::clone(&queue);
                let runner = Arc::clone(&runner);
                std::thread::spawn(move || {
                    while let Some(mut batch) = queue.next_batch() {
                        if batch.len() > 1 {
                            run_batch(&queue, &runner, batch);
                            continue;
                        }
                        let Some(job) = batch.pop() else { continue };
                        let NextJob { id, req, handle, request_id, recorder } = job;
                        // contain panics: a panicking run must mark its
                        // job failed, not kill the worker (with the
                        // default single worker that would stall the
                        // whole queue, jobs stuck in "running" forever)
                        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            // root of this job's span tree; made
                            // current on this thread so the
                            // coordinator's chunk/phase spans parent
                            // under it. Dropped (and flushed) before
                            // the terminal state is recorded.
                            let _run = recorder.as_ref().map(|r| {
                                r.span("run")
                                    .with_attr("job", id)
                                    .with_attr("request_id", &request_id)
                            });
                            if req.outputs.record {
                                // recorded jobs execute by replaying
                                // the captured stream, so the attached
                                // .bcmd provably reproduces the result
                                // it is served next to
                                let (stream, res) = api::record_request(&req)?;
                                handle.set_progress(res.chunks, res.chunks);
                                Ok((Some(stream.encode()), res))
                            } else {
                                req.execute_on(runner.as_ref(), &handle).map(|r| (None, r))
                            }
                        }));
                        match res {
                            Ok(Ok((bytes, r))) => {
                                if let Some(bytes) = bytes {
                                    queue.attach_cmdstream(id, bytes);
                                }
                                queue.complete(id, r);
                            }
                            Ok(Err(e)) if api::is_cancelled(&e) => queue.mark_cancelled(id),
                            Ok(Err(e)) => {
                                trace::log!(
                                    Warn,
                                    "serve",
                                    "job_failed",
                                    "job" => id,
                                    "request_id" => &request_id,
                                    "error" => format!("{e:#}"),
                                );
                                queue.fail(id, format!("{e:#}"));
                            }
                            Err(_) => {
                                trace::log!(
                                    Error,
                                    "serve",
                                    "job_panicked",
                                    "job" => id,
                                    "request_id" => &request_id,
                                );
                                queue.fail(id, "analysis panicked".to_string());
                            }
                        }
                    }
                })
            })
            .collect();
        Scheduler { workers }
    }

    /// Join every worker (call after [`JobQueue::shutdown`]).
    pub fn join(self) {
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Resolve every live job's scene and execute them all through one
/// recorded multi-job command stream. Split out of [`run_batch`] so
/// the `?` plumbing stays typed under `catch_unwind`.
fn run_batch_inner<'a>(
    runner: &SharedBfastRunner,
    live: &'a [NextJob],
) -> Result<Vec<((Cow<'a, TimeStack>, BfastParams), RunResult)>> {
    let mut scenes = Vec::with_capacity(live.len());
    for job in live {
        scenes.push(job.req.resolve()?);
    }
    let jobs: Vec<crate::cmd::RecordJob<'_>> = live
        .iter()
        .zip(&scenes)
        .map(|(job, (stack, params))| crate::cmd::RecordJob {
            tag: job.request_id.clone(),
            stack: stack.as_ref(),
            params,
        })
        .collect();
    let results = runner.run_recorded(&jobs)?;
    drop(jobs);
    Ok(scenes.into_iter().zip(results).collect())
}

/// Execute two or more compatible queued jobs through one recorded
/// command stream on one prepared engine (the batching seam described
/// in the module docs). Every job still completes with its own result
/// record — bit-identical to running it alone — and a failure or
/// panic fails the whole batch.
fn run_batch(queue: &JobQueue, runner: &SharedBfastRunner, batch: Vec<NextJob>) {
    // replay has no chunk-boundary cancellation hook, so jobs
    // cancelled between claiming and execution drop out here
    let mut live: Vec<NextJob> = Vec::with_capacity(batch.len());
    for job in batch {
        if job.handle.is_cancelled() {
            queue.mark_cancelled(job.id);
        } else {
            live.push(job);
        }
    }
    if live.is_empty() {
        return;
    }
    let ids: Vec<u64> = live.iter().map(|j| j.id).collect();
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // the batch's span tree roots in the oldest job's recorder
        // (one stream executed — there is no per-job phase split)
        let _run = live[0].recorder.as_ref().map(|r| {
            r.span("batched run")
                .with_attr("jobs", live.len() as u64)
                .with_attr("request_id", &live[0].request_id)
        });
        run_batch_inner(runner, &live)
    }));
    match outcome {
        Ok(Ok(done)) => {
            for (job, ((stack, params), res)) in live.iter().zip(done) {
                job.handle.set_progress(res.chunks, res.chunks);
                if job.handle.is_cancelled() {
                    queue.mark_cancelled(job.id);
                    continue;
                }
                let result = AnalysisResult {
                    map: res.map,
                    params,
                    phases: Some(res.phases),
                    chunks: res.chunks,
                    artifact: res.artifact,
                    engine: runner.platform(),
                    wall: res.wall,
                    width: stack.width,
                    height: stack.height,
                };
                queue.complete(job.id, result);
            }
        }
        Ok(Err(e)) => {
            trace::log!(
                Warn,
                "serve",
                "batch_failed",
                "jobs" => format!("{ids:?}"),
                "error" => format!("{e:#}"),
            );
            for id in ids {
                queue.fail(id, format!("{e:#}"));
            }
        }
        Err(_) => {
            trace::log!(Error, "serve", "batch_panicked", "jobs" => format!("{ids:?}"));
            for id in ids {
                queue.fail(id, "analysis panicked".to_string());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{ParamSpec, SceneSource};
    use crate::coordinator::RunnerConfig;
    use crate::params::BfastParams;
    use crate::synth::ArtificialDataset;

    fn request(m: usize, seed: u64) -> AnalysisRequest {
        let params = BfastParams::with_lambda(48, 36, 12, 1, 12.0, 0.05, 3.0).unwrap();
        let stack = ArtificialDataset::new(params.clone(), m, seed).generate().stack;
        let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
        req.params = ParamSpec::from_params(&params);
        req
    }

    fn runner() -> Arc<SharedBfastRunner> {
        Arc::new(SharedBfastRunner::emulated_shared(RunnerConfig::default()).unwrap())
    }

    #[test]
    fn backpressure_rejects_submissions_beyond_capacity() {
        // no scheduler attached: the queue fills deterministically
        let q = JobQueue::new(2);
        assert!(q.submit(request(4, 1)).is_ok());
        assert!(q.submit(request(4, 2)).is_ok());
        match q.submit(request(4, 3)) {
            Err(SubmitError::Full { capacity }) => assert_eq!(capacity, 2),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.depth(), 2);
        let stats = q.stats();
        assert_eq!(stats.submitted, 2);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.queued, 2);
        q.shutdown();
        match q.submit(request(4, 4)) {
            Err(SubmitError::ShuttingDown) => {}
            other => panic!("expected ShuttingDown, got {other:?}"),
        }
    }

    #[test]
    fn scheduler_drains_jobs_and_records_results() {
        let q = Arc::new(JobQueue::new(8));
        let ids: Vec<u64> = (0..3).map(|i| q.submit(request(40, i)).unwrap()).collect();
        let sched = Scheduler::start(Arc::clone(&q), runner(), 2);
        q.shutdown(); // graceful: accepted jobs still run
        sched.join();
        for id in ids {
            let (label, pixels) = q
                .with_record(id, |rec| {
                    (rec.state.label(), rec.result.as_ref().map(|r| r.map.len()))
                })
                .unwrap();
            assert_eq!(label, "done", "job {id}");
            assert_eq!(pixels, Some(40), "job {id}");
        }
        let stats = q.stats();
        assert_eq!(stats.done, 3);
        assert_eq!(stats.failed, 0);
        assert!(stats.phases.total().as_secs_f64() >= 0.0);
    }

    #[test]
    fn failed_jobs_carry_their_error() {
        let q = Arc::new(JobQueue::new(4));
        // params/stack mismatch surfaces as a failed job, not a panic
        let params = BfastParams::with_lambda(48, 36, 12, 1, 12.0, 0.05, 3.0).unwrap();
        let stack = crate::raster::TimeStack::zeros(10, 4);
        let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
        req.params = ParamSpec::from_params(&params);
        let id = q.submit(req).unwrap();
        let sched = Scheduler::start(Arc::clone(&q), runner(), 1);
        q.shutdown();
        sched.join();
        let state = q.with_record(id, |rec| rec.state.clone()).unwrap();
        match state {
            JobState::Failed { error } => assert!(error.contains("10"), "{error}"),
            other => panic!("expected failure, got {other:?}"),
        }
    }

    #[test]
    fn queued_job_cancels_immediately_and_deterministically() {
        // no scheduler: both jobs stay queued
        let q = Arc::new(JobQueue::new(8));
        let keep = q.submit(request(8, 1)).unwrap();
        let kill = q.submit(request(8, 2)).unwrap();
        assert_eq!(q.cancel(kill), CancelOutcome::Cancelled);
        assert_eq!(q.depth(), 1);
        assert_eq!(q.with_record(kill, |r| r.state.clone()).unwrap(), JobState::Cancelled);
        // idempotence + unknown ids
        assert_eq!(q.cancel(kill), CancelOutcome::AlreadyFinished);
        assert_eq!(q.cancel(999), CancelOutcome::NotFound);
        // the surviving job still runs to completion
        let sched = Scheduler::start(Arc::clone(&q), runner(), 1);
        q.shutdown();
        sched.join();
        assert_eq!(q.with_record(keep, |r| r.state.label()).unwrap(), "done");
        let stats = q.stats();
        assert_eq!((stats.done, stats.cancelled), (1, 1));
    }

    #[test]
    fn running_job_stops_before_completing_all_chunks() {
        // a wide scene (default m_chunk 1024 → ~96 chunks) so the run
        // is mid-flight long enough to cancel deterministically
        let q = Arc::new(JobQueue::new(2));
        let id = q.submit(request(96 * 1024, 5)).unwrap();
        let sched = Scheduler::start(Arc::clone(&q), runner(), 1);
        // wait until at least one chunk has executed
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let (state, (done, _)) = q
                .with_record(id, |r| (r.state.clone(), r.handle.progress()))
                .unwrap();
            if state == JobState::Running && done >= 1 {
                break;
            }
            assert!(
                !state.is_finished(),
                "job finished before the test could cancel it ({state:?})"
            );
            assert!(Instant::now() < deadline, "job never started running");
            std::thread::sleep(Duration::from_micros(300));
        }
        assert_eq!(q.cancel(id), CancelOutcome::Cancelled);
        q.shutdown();
        sched.join();
        let (state, (done, total)) = q
            .with_record(id, |r| (r.state.clone(), r.handle.progress()))
            .unwrap();
        assert_eq!(state, JobState::Cancelled);
        assert!(total > 1, "scene should span many chunks, got {total}");
        assert!(
            done < total,
            "cancelled job must stop early, but executed {done}/{total} chunks"
        );
    }

    #[test]
    fn completion_fills_the_cache_and_cached_records_are_born_done() {
        let cache = Arc::new(ResultCache::new(64 << 20));
        let q = Arc::new(JobQueue::new(4).with_cache(Arc::clone(&cache)));
        let req = request(8, 3);
        let digest = req.request_digest().unwrap();
        let id = q.submit_with_digest(req, Some(digest.clone())).unwrap();
        let sched = Scheduler::start(Arc::clone(&q), runner(), 1);
        // wait for completion with the queue still accepting, so the
        // cached insertion below exercises the normal (open) path
        let deadline = Instant::now() + Duration::from_secs(60);
        while !q.with_record(id, |r| r.state.is_finished()).unwrap() {
            assert!(Instant::now() < deadline, "job never finished");
            std::thread::sleep(Duration::from_millis(1));
        }
        let (label, cached, serialized) = q
            .with_record(id, |r| {
                (r.state.label(), r.cached, r.result.as_ref().unwrap().to_json_string())
            })
            .unwrap();
        assert_eq!(label, "done");
        assert!(!cached, "a computed job must not claim to be cached");
        let body = cache.get(&digest).expect("completion must fill the cache");
        assert_eq!(&*body, serialized, "cached envelope must match the record's result");
        // a hit inserts a pre-completed record with a bit-identical result
        let hit = AnalysisResult::from_json_str(&body).unwrap();
        let cid = q.insert_cached(None, &digest, hit).unwrap();
        let (label, cached, progress, ser2) = q
            .with_record(cid, |r| {
                (
                    r.state.label(),
                    r.cached,
                    r.progress(),
                    r.result.as_ref().unwrap().to_json_string(),
                )
            })
            .unwrap();
        assert_eq!(label, "done");
        assert!(cached);
        assert_eq!(progress, 1.0);
        assert_eq!(ser2, serialized, "cache hit must re-serialise bit-identically");
        assert_eq!(q.stats().submitted, 2, "a hit still counts as a submission");
        q.shutdown();
        sched.join();
        // shutdown refuses cached insertions like it refuses submits
        let again = AnalysisResult::from_json_str(&body).unwrap();
        assert!(matches!(
            q.insert_cached(None, &digest, again),
            Err(SubmitError::ShuttingDown)
        ));
    }

    #[test]
    fn eviction_policy_count_cap() {
        let q = Arc::new(JobQueue::with_policy(
            8,
            EvictionPolicy { max_finished: 2, max_age: Duration::from_secs(3600) },
        ));
        let ids: Vec<u64> = (0..4).map(|i| q.submit(request(4, i)).unwrap()).collect();
        let sched = Scheduler::start(Arc::clone(&q), runner(), 1);
        q.shutdown();
        sched.join();
        // single worker drains in FIFO order → the two oldest are gone
        assert!(q.with_record(ids[0], |_| ()).is_none());
        assert!(q.with_record(ids[1], |_| ()).is_none());
        assert!(q.with_record(ids[2], |_| ()).is_some());
        assert!(q.with_record(ids[3], |_| ()).is_some());
        assert_eq!(q.stats().evicted, 2);
        assert_eq!(q.jobs().len(), 2);
    }

    #[test]
    fn eviction_policy_max_age() {
        let q = Arc::new(JobQueue::with_policy(
            8,
            EvictionPolicy { max_finished: 100, max_age: Duration::from_millis(40) },
        ));
        let id = q.submit(request(4, 9)).unwrap();
        let sched = Scheduler::start(Arc::clone(&q), runner(), 1);
        q.shutdown();
        sched.join();
        // fresh record still served...
        assert!(q.with_record(id, |_| ()).is_some());
        // ...and reaped by the read-path sweep once it has aged out,
        // even with no further queue mutations (idle-server contract)
        std::thread::sleep(Duration::from_millis(60));
        assert!(q.with_record(id, |_| ()).is_none());
        assert_eq!(q.stats().evicted, 1);
    }

    #[test]
    fn compatible_jobs_batch_through_one_stream_with_results_unchanged() {
        // submitted before the single worker starts, so the scheduler
        // sees all three together and drains them as one batch
        let q = Arc::new(JobQueue::new(8));
        let jobs = [(40usize, 21u64), (25, 22), (8, 23)];
        let ids: Vec<u64> =
            jobs.iter().map(|&(m, s)| q.submit(request(m, s)).unwrap()).collect();
        let sched = Scheduler::start(Arc::clone(&q), runner(), 1);
        q.shutdown();
        sched.join();
        assert_eq!(q.stats().batched, 3, "all three jobs must share one stream");
        let solo_runner = runner();
        for (&(m, seed), &id) in jobs.iter().zip(&ids) {
            let solo = request(m, seed)
                .execute_on(solo_runner.as_ref(), &JobHandle::new())
                .unwrap();
            let (label, map, progress) = q
                .with_record(id, |r| {
                    (r.state.label(), r.result.as_ref().unwrap().map.clone(), r.progress())
                })
                .unwrap();
            assert_eq!(label, "done", "job {id}");
            assert_eq!(progress, 1.0, "job {id}");
            assert_eq!(map.breaks, solo.map.breaks, "job {id}");
            assert_eq!(map.first, solo.map.first, "job {id}");
        }
    }

    #[test]
    fn record_flagged_jobs_attach_a_replayable_stream_and_never_batch() {
        let q = Arc::new(JobQueue::new(8));
        let mut rec_req = request(12, 31);
        rec_req.outputs.record = true;
        let rid = q.submit(rec_req).unwrap();
        let plain = q.submit(request(12, 32)).unwrap();
        let sched = Scheduler::start(Arc::clone(&q), runner(), 1);
        q.shutdown();
        sched.join();
        assert_eq!(q.stats().batched, 0, "record-flagged jobs must not batch");
        let (label, bytes) =
            q.with_record(rid, |r| (r.state.label(), r.cmdstream.clone())).unwrap();
        assert_eq!(label, "done");
        let bytes = bytes.expect("a recorded job must carry its .bcmd");
        let stream = crate::cmd::CmdStream::decode(&bytes).unwrap();
        assert_eq!(stream.jobs.len(), 1);
        assert_eq!(stream.jobs[0].m, 12);
        // the plain job ran solo and has no stream attached
        assert!(q.with_record(plain, |r| r.cmdstream.is_none()).unwrap());
    }

    #[test]
    fn zero_max_age_means_no_age_limit() {
        let q = Arc::new(JobQueue::with_policy(
            8,
            EvictionPolicy { max_finished: 100, max_age: Duration::ZERO },
        ));
        let id = q.submit(request(4, 11)).unwrap();
        let sched = Scheduler::start(Arc::clone(&q), runner(), 1);
        q.shutdown();
        sched.join();
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q.with_record(id, |r| r.state.label()).unwrap(), "done");
        assert_eq!(q.stats().evicted, 0);
    }
}
