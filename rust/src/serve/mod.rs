//! `bfast serve` — the break-detection service: a zero-dependency
//! HTTP/1.1 server (hand-rolled on `std::net::TcpListener`, requests
//! fanned out on the [`crate::threadpool::WorkerPool`]) in front of a
//! bounded [`queue::JobQueue`] of analysis jobs and a persistent
//! [`registry::SessionRegistry`] of live monitor sessions.
//!
//! The paper's point is that BFAST at device speed turns scene
//! analysis into an interactive operation; this layer serves that
//! capability: submit a scene, poll its job, fetch the break map —
//! or keep a named session open and POST each satellite revisit as it
//! arrives, getting the break/momax delta back in milliseconds. One
//! [`SharedBfastRunner`] is shared by every worker thread.
//!
//! ## API
//!
//! | method & path                      | body            | reply |
//! |------------------------------------|-----------------|-------|
//! | `GET  /healthz`                    | —               | status JSON |
//! | `GET  /metrics`                    | —               | Prometheus text |
//! | `POST /v1/runs`                    | [`AnalysisRequest`] JSON, or `.bsq` bytes + `?n-hist=..` | 202 `{job}` or 429 + `Retry-After` |
//! | `GET  /v1/runs`                    | —               | job list |
//! | `GET  /v1/runs/{id}`               | —               | status + progress |
//! | `DELETE /v1/runs/{id}`             | —               | cancel (200/404/409) |
//! | `GET  /v1/runs/{id}/result`        | —               | canonical v1 [`crate::api::AnalysisResult`] JSON |
//! | `GET  /v1/runs/{id}/map[?format=pgm]` | —            | break map JSON / PGM (sugar) |
//! | `GET  /v1/runs/{id}/trace`         | —               | Chrome trace-event JSON (flight recorder) |
//! | `GET  /v1/runs/{id}/cmdstream[?format=json]` | —     | recorded `.bcmd` command stream (submit with `outputs.record` or `?record=1`) |
//! | `GET  /v1/cache`                   | —               | result-cache stats JSON |
//! | `DELETE /v1/cache`                 | —               | drop cached results |
//! | `POST /v1/sessions/{name}`         | [`SessionInit`] JSON, or `.bsq` bytes + `?n-hist=..` | 201 summary |
//! | `GET  /v1/sessions[/{name}]`       | —               | list / summary |
//! | `POST /v1/sessions/{name}/ingest?t=..` | `.bten` f32 layer or [`SessionIngest`] JSON | ingest delta |
//! | `GET  /v1/sessions/{name}/map[?format=pgm]` | —      | break map JSON / PGM |
//! | `POST /shutdown`                   | —               | 200, then graceful stop |
//!
//! The JSON bodies are the canonical `bfast::api` wire schema (see
//! [`crate::api`]) — `bfast client submit` posts exactly the
//! [`AnalysisRequest`] the library executes and `/result` serves
//! exactly the [`crate::api::AnalysisResult`] it returns; the
//! query-string + raw-bytes + `/map` forms are curl-friendly sugar
//! that the handlers lower into (or render from) the same types.
//! Every non-2xx response is the uniform JSON error envelope
//! `{"error": {"status": .., "message": ..}}`
//! ([`http::Response::json_error`]); a 429 additionally carries a
//! `Retry-After` header (and `retry_after_s` envelope field) that
//! polite clients back off on. Connections are kept alive across
//! requests (HTTP/1.1 semantics; honour `Connection: close`).
//!
//! Every returned break map is **bit-identical** to a direct
//! [`BfastRunner::run`](crate::coordinator::BfastRunner::run) of the
//! same scene, and sessions resume bit-exactly across server restarts
//! — both pinned over real sockets by `tests/serve.rs`.

pub mod http;
pub mod queue;
pub mod registry;

use crate::api::{AnalysisRequest, ParamSpec, SceneSource, SessionIngest, SessionInit};
use crate::coordinator::{RunnerConfig, SharedBfastRunner};
use crate::error::{bail, err, Context, Result};
use crate::json::{self, Value};
use crate::metrics;
use crate::monitor::MonitorSession;
use crate::raster::{io as rio, pgm, BreakMap};
use crate::runtime::bten::{bten_from_bytes, Tensor};
use crate::store::{AnyDecoder, ResultCache};
use crate::threadpool::{self, WorkerPool};
use crate::trace;
use http::{Request, Response};
use queue::{
    CancelOutcome, EvictionPolicy, JobQueue, JobRecord, JobState, Scheduler, SubmitError,
};
use registry::SessionRegistry;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Cap on requests served over one keep-alive connection (bounds how
/// long a single socket can monopolise a pool worker).
const MAX_REQUESTS_PER_CONN: usize = 1024;

/// Server configuration (`bfast serve` flags).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address (`host:port`; port 0 picks an ephemeral port).
    pub addr: String,
    /// Session state directory; `None` = in-memory sessions only.
    pub state_dir: Option<PathBuf>,
    /// HTTP worker threads (0 = auto).
    pub http_threads: usize,
    /// Scheduler workers driving analysis runs (each run is itself
    /// parallel, so 1–2 saturates the machine).
    pub job_workers: usize,
    /// Bounded job-queue capacity; submissions beyond it get 429.
    pub queue_capacity: usize,
    /// Largest accepted request body, in bytes.
    pub max_body: usize,
    /// Finished job records retained for status/map queries (count cap
    /// of the eviction policy; each record holds a full break map).
    pub finished_cap: usize,
    /// Longest a finished job record is retained (age cap of the
    /// eviction policy; zero = no age limit, count cap only).
    pub finished_max_age: Duration,
    /// Content-addressed result cache capacity in bytes (0 disables
    /// caching): an identical resubmission is answered from the cache
    /// without queueing.
    pub cache_cap: usize,
    /// Coordinator configuration for the shared runner.
    pub runner: RunnerConfig,
    /// Gateway address to register with and heartbeat
    /// (`POST /v1/workers`); `None` = standalone worker.
    pub gateway: Option<String>,
    /// Address advertised to the gateway (defaults to the bound
    /// address — override when workers sit behind NAT or a proxy).
    pub advertise: Option<String>,
    /// Heartbeat interval when `gateway` is set.
    pub heartbeat: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let policy = EvictionPolicy::default();
        Self {
            addr: "127.0.0.1:7878".into(),
            state_dir: None,
            http_threads: 0,
            job_workers: 1,
            queue_capacity: 32,
            max_body: 256 << 20,
            finished_cap: policy.max_finished,
            finished_max_age: policy.max_age,
            cache_cap: 64 << 20,
            runner: RunnerConfig::default(),
            gateway: None,
            advertise: None,
            heartbeat: Duration::from_secs(1),
        }
    }
}

struct ServerState {
    addr: SocketAddr,
    runner: Arc<SharedBfastRunner>,
    queue: Arc<JobQueue>,
    cache: Arc<ResultCache>,
    registry: SessionRegistry,
    started: Instant,
    max_body: usize,
    shutdown: AtomicBool,
    requests: AtomicU64,
    errors: AtomicU64,
}

/// A running `bfast serve` instance. [`Server::start`] returns once
/// the socket is listening; requests are then served until
/// `POST /shutdown` or [`Server::stop`], both of which drain the job
/// queue, finish in-flight connections and persist every session
/// before the accept thread exits.
pub struct Server {
    addr: SocketAddr,
    state: Arc<ServerState>,
    accept: std::thread::JoinHandle<()>,
    beat: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind, resume persisted sessions, spawn the scheduler and HTTP
    /// workers, and start accepting.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let http_threads = if cfg.http_threads == 0 {
            threadpool::default_threads().clamp(2, 16)
        } else {
            cfg.http_threads
        };
        let runner = Arc::new(SharedBfastRunner::emulated_shared(cfg.runner.clone())?);
        let cache = Arc::new(ResultCache::new(cfg.cache_cap));
        let queue = Arc::new(
            JobQueue::with_policy(
                cfg.queue_capacity,
                EvictionPolicy { max_finished: cfg.finished_cap, max_age: cfg.finished_max_age },
            )
            .with_cache(Arc::clone(&cache)),
        );
        let registry =
            SessionRegistry::open(cfg.state_dir.clone(), threadpool::default_threads())?;
        let scheduler =
            Scheduler::start(Arc::clone(&queue), Arc::clone(&runner), cfg.job_workers);
        let state = Arc::new(ServerState {
            addr,
            runner,
            queue,
            cache,
            registry,
            started: Instant::now(),
            max_body: cfg.max_body,
            shutdown: AtomicBool::new(false),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
        });
        let accept_state = Arc::clone(&state);
        let accept = std::thread::spawn(move || {
            let mut pool = WorkerPool::new(http_threads);
            for conn in listener.incoming() {
                if accept_state.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let st = Arc::clone(&accept_state);
                if pool.execute(move || handle_connection(stream, &st)).is_err() {
                    break;
                }
            }
            // graceful teardown: stop intake, drain accepted jobs,
            // finish in-flight connections, persist sessions
            accept_state.queue.shutdown();
            scheduler.join();
            pool.shutdown();
            if let Err(e) = accept_state.registry.save_all() {
                trace::log!(
                    Error,
                    "serve",
                    "session_persist_failed",
                    "error" => format!("{e:#}"),
                );
            }
        });
        let beat = cfg.gateway.as_ref().map(|gateway| {
            let gateway = gateway.clone();
            let advertise = cfg.advertise.clone().unwrap_or_else(|| addr.to_string());
            let interval = cfg.heartbeat.max(Duration::from_millis(50));
            let beat_state = Arc::clone(&state);
            std::thread::spawn(move || {
                // Registration and heartbeat are the same idempotent
                // POST; failures are tolerated (the gateway may not be
                // up yet, or may restart) — the next beat re-registers.
                let body = Value::obj(vec![("addr", Value::Str(advertise))])
                    .to_string_compact();
                let mut next = Instant::now();
                while !beat_state.shutdown.load(Ordering::SeqCst) {
                    if Instant::now() >= next {
                        let io = Duration::from_secs(2);
                        let _ = http::Client::connect_timeout(&gateway, io).and_then(|mut c| {
                            c.request("POST", "/v1/workers", "application/json", body.as_bytes())
                        });
                        next = Instant::now() + interval;
                    }
                    // short ticks so shutdown is observed promptly
                    std::thread::sleep(Duration::from_millis(50));
                }
            })
        });
        Ok(Server { addr, state, accept, beat })
    }

    /// The bound address (resolves an ephemeral port request).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the server shuts down (`POST /shutdown` or
    /// [`Server::stop`] from another handle).
    pub fn wait(self) -> Result<()> {
        self.accept
            .join()
            .map_err(|_| err!("serve accept loop panicked"))?;
        // shutdown is already flagged once the accept loop exits, so
        // the heartbeat thread stops within one 50 ms tick
        if let Some(beat) = self.beat {
            beat.join().map_err(|_| err!("serve heartbeat loop panicked"))?;
        }
        Ok(())
    }

    /// Trigger a graceful shutdown and wait for it to complete.
    pub fn stop(self) -> Result<()> {
        trigger_shutdown(&self.state);
        self.wait()
    }
}

/// Flag the shutdown and poke the accept loop out of `incoming()`.
fn trigger_shutdown(state: &ServerState) {
    state.shutdown.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(state.addr);
}

fn handle_connection(stream: TcpStream, state: &ServerState) {
    let _ = stream.set_nodelay(true);
    // one read buffer per connection, reused across keep-alive
    // requests: read_request stays byte-precise without per-byte
    // syscalls, and pipelined bytes carry over to the next iteration
    let mut reader = std::io::BufReader::new(stream);
    let mut served = 0usize;
    loop {
        // generous timeout for the first request, shorter for idle
        // keep-alive waits so one quiet socket can't pin a pool worker
        // (an expired idle wait surfaces as Ok(None), a clean close)
        let timeout = if served == 0 { Duration::from_secs(30) } else { Duration::from_secs(5) };
        let _ = reader.get_ref().set_read_timeout(Some(timeout));
        let req = match http::read_request(&mut reader, state.max_body) {
            Ok(Some(req)) => req,
            Ok(None) => break, // client closed (or went idle) between requests
            Err(e) => {
                // malformed or oversized request: answer 400 and close
                state.requests.fetch_add(1, Ordering::Relaxed);
                state.errors.fetch_add(1, Ordering::Relaxed);
                let _ = http::write_response(
                    reader.get_mut(),
                    &Response::json_error(400, &format!("{e:#}")),
                    false,
                );
                break;
            }
        };
        state.requests.fetch_add(1, Ordering::Relaxed);
        let resp = route(&req, state);
        if resp.status >= 400 {
            state.errors.fetch_add(1, Ordering::Relaxed);
        }
        served += 1;
        let keep = req.keep_alive()
            && served < MAX_REQUESTS_PER_CONN
            && !state.shutdown.load(Ordering::SeqCst);
        if http::write_response(reader.get_mut(), &resp, keep).is_err() {
            break; // client may be gone
        }
        if !keep {
            break;
        }
    }
}

fn route(req: &Request, state: &ServerState) -> Response {
    let path = req.path.clone();
    let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => healthz(state),
        ("GET", ["metrics"]) => metrics(state),
        ("POST", ["shutdown"]) => {
            trigger_shutdown(state);
            Response::json(
                200,
                &Value::obj(vec![("status", Value::Str("shutting down".into()))]),
            )
        }
        ("POST", ["v1", "runs"]) => submit_run(req, state),
        ("GET", ["v1", "runs"]) => list_runs(state),
        ("GET", ["v1", "runs", id]) => run_status(id, state),
        ("DELETE", ["v1", "runs", id]) => cancel_run(id, state),
        ("GET", ["v1", "runs", id, "map"]) => run_map(req, id, state),
        ("GET", ["v1", "runs", id, "result"]) => run_result(req, id, state),
        ("GET", ["v1", "runs", id, "trace"]) => run_trace(id, state),
        ("GET", ["v1", "runs", id, "cmdstream"]) => run_cmdstream(req, id, state),
        ("GET", ["v1", "cache"]) => cache_stats(state),
        ("DELETE", ["v1", "cache"]) => cache_clear(state),
        ("GET", ["v1", "sessions"]) => list_sessions(state),
        ("POST", ["v1", "sessions", name]) => create_session(req, name, state),
        ("GET", ["v1", "sessions", name]) => session_status(name, state),
        ("POST", ["v1", "sessions", name, "ingest"]) => session_ingest(req, name, state),
        ("GET", ["v1", "sessions", name, "map"]) => session_map(req, name, state),
        (method, _) => Response::json_error(404, &format!("no route for {method} {}", req.path)),
    }
}

// -- simple endpoints ----------------------------------------------------

fn healthz(state: &ServerState) -> Response {
    Response::json(
        200,
        &Value::obj(vec![
            ("status", Value::Str("ok".into())),
            ("backend", Value::Str(state.runner.platform())),
            ("version", Value::Str(env!("CARGO_PKG_VERSION").into())),
            (
                "git_rev",
                Value::Str(option_env!("BFAST_GIT_REV").unwrap_or("unknown").into()),
            ),
            ("profile", Value::Str(metrics::build_profile().into())),
            ("uptime_s", Value::Num(state.started.elapsed().as_secs_f64())),
            ("sessions", Value::Num(state.registry.len() as f64)),
            ("queue_depth", Value::Num(state.queue.depth() as f64)),
        ]),
    )
}

fn metrics(state: &ServerState) -> Response {
    use crate::metrics::{prom_header, prom_metric};
    let stats = state.queue.stats();
    let mut out = String::new();
    metrics::prom_build_info(&mut out);
    prom_metric(
        &mut out,
        "gauge",
        "bfast_uptime_seconds",
        "seconds since this server started",
        state.started.elapsed().as_secs_f64(),
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_http_requests_total",
        "HTTP requests accepted",
        state.requests.load(Ordering::Relaxed) as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_http_errors_total",
        "HTTP responses with status >= 400",
        state.errors.load(Ordering::Relaxed) as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_jobs_submitted_total",
        "analysis jobs accepted into the queue",
        stats.submitted as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_jobs_rejected_total",
        "submissions refused by backpressure (HTTP 429)",
        stats.rejected as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_jobs_evicted_total",
        "finished job records reaped by the eviction policy",
        stats.evicted as f64,
    );
    // per-state tallies are gauges: they count *retained* records,
    // which shrink under eviction
    prom_metric(&mut out, "gauge", "bfast_jobs_queued", "jobs waiting for a worker", stats.queued as f64);
    prom_metric(&mut out, "gauge", "bfast_jobs_running", "jobs currently executing", stats.running as f64);
    prom_metric(&mut out, "gauge", "bfast_jobs_done", "retained completed jobs", stats.done as f64);
    prom_metric(&mut out, "gauge", "bfast_jobs_failed", "retained failed jobs", stats.failed as f64);
    prom_metric(
        &mut out,
        "gauge",
        "bfast_jobs_cancelled",
        "retained cancelled jobs",
        stats.cancelled as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_chunks_done_total",
        "chunks executed across every completed run",
        stats.chunks_done as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_jobs_batched_total",
        "jobs executed through a multi-job batched command stream",
        stats.batched as f64,
    );
    prom_metric(
        &mut out,
        "gauge",
        "bfast_queue_capacity",
        "bounded job-queue capacity",
        state.queue.capacity() as f64,
    );
    let cache = state.cache.stats();
    prom_metric(
        &mut out,
        "counter",
        "bfast_cache_hits_total",
        "submissions answered from the result cache",
        cache.hits as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_cache_misses_total",
        "cache lookups that fell through to a compute",
        cache.misses as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_cache_evictions_total",
        "cached results evicted to stay under capacity",
        cache.evictions as f64,
    );
    prom_metric(
        &mut out,
        "gauge",
        "bfast_cache_bytes",
        "bytes of serialised results held by the cache",
        cache.bytes as f64,
    );
    let policy = state.queue.policy();
    prom_metric(
        &mut out,
        "gauge",
        "bfast_finished_records_cap",
        "finished job records retained (count cap)",
        policy.max_finished as f64,
    );
    prom_metric(
        &mut out,
        "gauge",
        "bfast_finished_max_age_seconds",
        "longest a finished record is retained (0 = unlimited)",
        policy.max_age.as_secs_f64(),
    );
    prom_metric(
        &mut out,
        "gauge",
        "bfast_sessions",
        "live monitor sessions",
        state.registry.len() as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_session_layers_ingested_total",
        "layers absorbed across every monitor session",
        state.registry.layers_ingested() as f64,
    );
    state.queue.queue_wait().render(
        &mut out,
        "bfast_queue_wait_seconds",
        "seconds jobs waited in the queue before a worker picked them up",
    );
    state.queue.run_latency().render(
        &mut out,
        "bfast_run_latency_seconds",
        "seconds from job submission to a terminal state",
    );
    // accumulated seconds, but exposed as a labelled gauge family: the
    // name predates the HELP/TYPE discipline and renaming would break
    // scrapers (counters must end in _total)
    prom_header(
        &mut out,
        "gauge",
        "bfast_run_phase_seconds",
        "engine phase seconds accumulated across completed runs",
    );
    out.push_str(&stats.phases.to_prometheus("bfast_run_phase_seconds"));
    Response::text(200, &out)
}

// -- run endpoints -------------------------------------------------------

fn q_usize(req: &Request, key: &str, default: usize) -> Result<usize> {
    match req.query_get(key) {
        None => Ok(default),
        Some(s) => s
            .parse()
            .map_err(|_| err!("query {key}={s:?} is not an integer")),
    }
}

fn q_f64(req: &Request, key: &str, default: f64) -> Result<f64> {
    match req.query_get(key) {
        None => Ok(default),
        Some(s) => s.parse().map_err(|_| err!("query {key}={s:?} is not a number")),
    }
}

/// Analysis parameters from the query string (defaults mirror the
/// CLI's `run` command; N comes from the scene at execution time).
fn params_from_query(req: &Request) -> Result<ParamSpec> {
    let d = ParamSpec::default();
    Ok(ParamSpec {
        n_total: None,
        n_hist: q_usize(req, "n-hist", d.n_hist)?,
        h: q_usize(req, "h", d.h)?,
        k: q_usize(req, "k", d.k)?,
        freq: q_f64(req, "freq", d.freq)?,
        alpha: q_f64(req, "alpha", d.alpha)?,
        lambda: None,
    })
}

/// Remote callers must ship the scene with the request: honouring a
/// `path` source would let any client make the server read arbitrary
/// local files (the path form is for the CLI and for trusted
/// shard-fanout deployments with shared storage, not the open wire).
pub(crate) fn reject_path_source(source: &SceneSource) -> Result<()> {
    match source {
        SceneSource::Path(p) => {
            bail!("scene source {p:?} is a path; the wire only accepts inline scenes")
        }
        SceneSource::Inline(_) => Ok(()),
    }
}

/// Lower either submit body form into the one request type: a JSON
/// body *is* an [`AnalysisRequest`]; raw `.bsq` bytes + query params
/// are sugar for an inline request. Octet-stream bodies are sniffed
/// ([`AnyDecoder`]): gzip/zlib-wrapped `.bsq` uploads decode here
/// (bounded by `max_body`) so a `.bsq.gz` file posts as-is.
pub(crate) fn analysis_request_from(req: &Request, max_body: usize) -> Result<AnalysisRequest> {
    let analysis = if req.is_json() {
        let text = std::str::from_utf8(&req.body).context("non-UTF-8 JSON body")?;
        let ar = AnalysisRequest::from_json_str(text)?;
        reject_path_source(&ar.source)?;
        ar
    } else {
        let bytes = AnyDecoder::decode(&req.body, max_body)?;
        let stack = rio::stack_from_bytes(&bytes, "request body")?;
        let mut ar = AnalysisRequest::new(SceneSource::Inline(stack));
        ar.params = params_from_query(req)?;
        ar
    };
    // reject bad params / pixel ranges with a 400 at the door instead
    // of a 202 whose job fails later (and meanwhile eats queue slots)
    analysis.validate()?;
    Ok(analysis)
}

fn submit_run(req: &Request, state: &ServerState) -> Response {
    let mut analysis = match analysis_request_from(req, state.max_body) {
        Ok(a) => a,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    // request-id precedence at the front door: JSON field, then the
    // X-Request-Id header (how the gateway/shard layer propagates its
    // id), then minted by the queue
    if analysis.request_id.is_none() {
        analysis.request_id = req.header("x-request-id").map(str::to_string);
    }
    // query sugar for the OutputSpec field: ?record=1 asks the worker
    // to capture the run as a replayable .bcmd, served by
    // GET /v1/runs/{id}/cmdstream
    if matches!(req.query_get("record"), Some("1" | "true")) {
        analysis.outputs.record = true;
    }
    // content-addressed front door: hash the request once, and answer
    // an identical resubmission from the result cache — the record is
    // born Done and no scheduler worker ever sees it. Recorded jobs
    // always go to a worker: a cache hit would skip the recording
    // (the digest deliberately ignores output options).
    let digest = analysis.request_digest().ok();
    if let Some(d) = digest.as_deref().filter(|_| !analysis.outputs.record) {
        if let Some(body) = state.cache.get(d) {
            // a cache entry that no longer parses falls through to a
            // recompute (put() will overwrite it) instead of erroring
            if let Ok(res) = crate::api::AnalysisResult::from_json_str(&body) {
                match state.queue.insert_cached(analysis.request_id.clone(), d, res) {
                    Ok(id) => {
                        let request_id = state
                            .queue
                            .with_record(id, |rec| rec.request_id.clone())
                            .unwrap_or_default();
                        trace::log!(
                            Info,
                            "serve",
                            "job_cache_hit",
                            "job" => id,
                            "request_id" => &request_id,
                            "digest" => d,
                        );
                        return Response::json(
                            202,
                            &Value::obj(vec![
                                ("job", Value::Num(id as f64)),
                                ("status", Value::Str("done".into())),
                                ("cached", Value::Bool(true)),
                                ("request_id", Value::Str(request_id)),
                            ]),
                        );
                    }
                    Err(SubmitError::ShuttingDown) => {
                        return Response::json_error(503, "server is shutting down")
                    }
                    Err(SubmitError::Full { .. }) => {} // unreachable: hits skip the FIFO
                }
            }
        }
    }
    match state.queue.submit_with_digest(analysis, digest) {
        Ok(id) => {
            let request_id = state
                .queue
                .with_record(id, |rec| rec.request_id.clone())
                .unwrap_or_default();
            trace::log!(
                Info,
                "serve",
                "job_submitted",
                "job" => id,
                "request_id" => &request_id,
            );
            Response::json(
                202,
                &Value::obj(vec![
                    ("job", Value::Num(id as f64)),
                    ("status", Value::Str("queued".into())),
                    ("request_id", Value::Str(request_id)),
                ]),
            )
        }
        // 429 carries the retry hint twice: the standard Retry-After
        // header, and `retry_after_s` inside the error envelope for
        // body-only clients. `bfast client submit` and the shard
        // coordinator back off on it instead of failing outright.
        Err(SubmitError::Full { capacity }) => Response::json(
            429,
            &http::error_envelope(
                429,
                &format!("job queue is full ({capacity} pending); retry later"),
                &[("retry_after_s", Value::Num(RETRY_AFTER_S as f64))],
            ),
        )
        .with_header("Retry-After", &RETRY_AFTER_S.to_string()),
        Err(SubmitError::ShuttingDown) => Response::json_error(503, "server is shutting down"),
    }
}

/// The backoff hint a full queue advertises. One second: long enough
/// for a queue slot to open under normal drain rates, short enough
/// that a polite client barely notices.
const RETRY_AFTER_S: u64 = 1;

fn job_json(rec: &JobRecord) -> Value {
    let mut fields = vec![
        ("job", Value::Num(rec.id as f64)),
        ("status", Value::Str(rec.state.label().into())),
        ("request_id", Value::Str(rec.request_id.clone())),
        ("progress", Value::Num(rec.progress())),
    ];
    if let Some(px) = rec.pixels {
        fields.push(("pixels", Value::Num(px as f64)));
    }
    if rec.cached {
        fields.push(("cached", Value::Bool(true)));
    }
    let (chunks_done, chunks_total) = rec.handle.progress();
    match &rec.state {
        JobState::Running | JobState::Cancelled => {
            fields.push(("chunks_done", Value::Num(chunks_done as f64)));
            fields.push(("chunks_total", Value::Num(chunks_total as f64)));
        }
        JobState::Failed { error } => fields.push(("error", Value::Str(error.clone()))),
        _ => {}
    }
    if let Some(res) = &rec.result {
        fields.push(("breaks", Value::Num(res.map.break_count() as f64)));
        fields.push(("chunks", Value::Num(res.chunks as f64)));
        fields.push(("artifact", Value::Str(res.artifact.clone())));
        fields.push(("engine", Value::Str(res.engine.clone())));
        fields.push(("lambda", Value::Num(res.params.lambda)));
        fields.push(("wall_s", Value::Num(res.wall.as_secs_f64())));
    }
    Value::obj(fields)
}

fn list_runs(state: &ServerState) -> Response {
    let jobs = state.queue.jobs();
    let arr = jobs
        .into_iter()
        .map(|(id, st, progress)| {
            Value::obj(vec![
                ("job", Value::Num(id as f64)),
                ("status", Value::Str(st.label().into())),
                ("progress", Value::Num(progress)),
            ])
        })
        .collect();
    Response::json(200, &Value::obj(vec![("jobs", Value::Arr(arr))]))
}

fn parse_id(seg: &str) -> Result<u64> {
    seg.parse().map_err(|_| err!("job id {seg:?} must be an integer"))
}

fn run_status(id_seg: &str, state: &ServerState) -> Response {
    let id = match parse_id(id_seg) {
        Ok(id) => id,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    match state.queue.with_record(id, job_json) {
        Some(v) => Response::json(200, &v),
        None => Response::json_error(404, &format!("no job {id}")),
    }
}

/// `DELETE /v1/runs/{id}` — cooperative cancellation: a queued job is
/// withdrawn immediately, a running one stops at its next chunk
/// boundary (poll the job status for the transition to `cancelled`).
fn cancel_run(id_seg: &str, state: &ServerState) -> Response {
    let id = match parse_id(id_seg) {
        Ok(id) => id,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    match state.queue.cancel(id) {
        CancelOutcome::Cancelled => Response::json(
            200,
            &Value::obj(vec![
                ("job", Value::Num(id as f64)),
                ("status", Value::Str("cancelling".into())),
            ]),
        ),
        CancelOutcome::AlreadyFinished => {
            Response::json_error(409, &format!("job {id} already finished"))
        }
        CancelOutcome::NotFound => Response::json_error(404, &format!("no job {id}")),
    }
}

fn run_map(req: &Request, id_seg: &str, state: &ServerState) -> Response {
    let id = match parse_id(id_seg) {
        Ok(id) => id,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    let resp = state.queue.with_record(id, |rec| match (&rec.state, &rec.result) {
        (JobState::Done, Some(res)) => map_response(req, &res.map, rec.width, rec.height),
        (JobState::Failed { error }, _) => {
            Response::json_error(409, &format!("job {id} failed: {error}"))
        }
        (JobState::Cancelled, _) => Response::json_error(409, &format!("job {id} was cancelled")),
        _ => Response::json_error(409, &format!("job {id} is not finished")),
    });
    resp.unwrap_or_else(|| Response::json_error(404, &format!("no job {id}")))
}

/// `GET /v1/runs/{id}/result` — the canonical v1
/// [`crate::api::AnalysisResult`] envelope: pinned parameters, phase
/// times, and the break map as a **lossless** base64 `.bten` payload.
/// This is the back door's typed counterpart of `POST /v1/runs` (and
/// what the shard coordinator fetches per worker); the `/map` routes
/// stay as float-array / PGM sugar over the same record.
///
/// The request digest doubles as a strong `ETag`: a re-fetch with
/// `If-None-Match` answers `304` with no body, and `Accept-Encoding:
/// gzip` callers get the envelope compressed when that actually helps.
fn run_result(req: &Request, id_seg: &str, state: &ServerState) -> Response {
    let id = match parse_id(id_seg) {
        Ok(id) => id,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    let resp = state.queue.with_record(id, |rec| match (&rec.state, &rec.result) {
        (JobState::Done, Some(res)) => {
            let etag = rec.digest.as_ref().map(|d| format!("\"{d}\""));
            if let Some(etag) = &etag {
                let matched = req
                    .header("if-none-match")
                    .is_some_and(|v| etag_matches(v, etag));
                if matched {
                    return Response::text(304, "").with_header("ETag", etag);
                }
            }
            let resp = Response::json(200, &res.to_json());
            match etag {
                Some(etag) => resp.with_header("ETag", &etag),
                None => resp,
            }
        }
        (JobState::Failed { error }, _) => {
            Response::json_error(409, &format!("job {id} failed: {error}"))
        }
        (JobState::Cancelled, _) => {
            Response::json_error(409, &format!("job {id} was cancelled"))
        }
        _ => Response::json_error(409, &format!("job {id} is not finished")),
    });
    resp.unwrap_or_else(|| Response::json_error(404, &format!("no job {id}")))
        .gzip_if_accepted(req)
}

/// `If-None-Match` comparison: a comma-separated list of entity tags
/// (or `*`), matched byte-for-byte — our tags are strong.
pub(crate) fn etag_matches(header: &str, etag: &str) -> bool {
    header.split(',').map(str::trim).any(|t| t == "*" || t == etag)
}

/// `GET /v1/cache` — result-cache counters and occupancy.
fn cache_stats(state: &ServerState) -> Response {
    let s = state.cache.stats();
    Response::json(
        200,
        &Value::obj(vec![
            ("enabled", Value::Bool(state.cache.enabled())),
            ("capacity", Value::Num(s.capacity as f64)),
            ("entries", Value::Num(s.entries as f64)),
            ("bytes", Value::Num(s.bytes as f64)),
            ("hits", Value::Num(s.hits as f64)),
            ("misses", Value::Num(s.misses as f64)),
            ("evictions", Value::Num(s.evictions as f64)),
        ]),
    )
}

/// `DELETE /v1/cache` — drop every cached result (counters survive).
fn cache_clear(state: &ServerState) -> Response {
    let cleared = state.cache.clear();
    Response::json(200, &Value::obj(vec![("cleared", Value::Num(cleared as f64))]))
}

/// `GET /v1/runs/{id}/trace` — the job's flight-recorder span tree as
/// Chrome trace-event JSON (load it in Perfetto / `chrome://tracing`).
/// Served for any job state: a running job yields its spans so far.
fn run_trace(id_seg: &str, state: &ServerState) -> Response {
    let id = match parse_id(id_seg) {
        Ok(id) => id,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    let resp = state.queue.with_record(id, |rec| match &rec.recorder {
        Some(r) => Response::json(200, &r.to_chrome_trace(1, "bfast serve")),
        None => Response::json_error(
            409,
            &format!("job {id} has no trace (tracing disabled at submission)"),
        ),
    });
    resp.unwrap_or_else(|| Response::json_error(404, &format!("no job {id}")))
}

/// `GET /v1/runs/{id}/cmdstream` — the job's recorded `.bcmd` command
/// stream, byte-for-byte as the worker encoded (and replayed) it.
/// Present only for jobs submitted with `outputs.record` (JSON field)
/// or `?record=1` (query sugar); everyone else gets a 409 explaining
/// how to ask for one. `?format=json` serves the decoded JSON dump of
/// the same stream instead of the binary form.
fn run_cmdstream(req: &Request, id_seg: &str, state: &ServerState) -> Response {
    let id = match parse_id(id_seg) {
        Ok(id) => id,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    let resp = state.queue.with_record(id, |rec| match &rec.cmdstream {
        Some(bytes) => match req.query_get("format") {
            Some("json") => match crate::cmd::CmdStream::decode(bytes) {
                Ok(stream) => Response::json(200, &stream.to_json()),
                Err(e) => {
                    Response::json_error(500, &format!("stored stream is corrupt: {e:#}"))
                }
            },
            Some(other) if other != "bcmd" => {
                Response::json_error(400, &format!("unknown format {other:?} (bcmd|json)"))
            }
            _ => Response::bytes(200, "application/octet-stream", bytes.clone()),
        },
        None => Response::json_error(
            409,
            &format!(
                "job {id} has no recorded command stream \
                 (submit with outputs.record or ?record=1)"
            ),
        ),
    });
    resp.unwrap_or_else(|| Response::json_error(404, &format!("no job {id}")))
}

/// Break map as JSON, or as a momax-heatmap PGM with `?format=pgm`.
pub(crate) fn map_response(
    req: &Request,
    map: &BreakMap,
    width: Option<usize>,
    height: Option<usize>,
) -> Response {
    match req.query_get("format") {
        Some("pgm") => {
            let (w, h) = match (width, height) {
                (Some(w), Some(h)) => (w, h),
                _ => (map.len(), 1),
            };
            let (lo, hi) = pgm::autoscale_range(&map.momax);
            Response::bytes(
                200,
                "image/x-portable-graymap",
                pgm::encode_pgm(&map.momax, w, h, lo, hi),
            )
        }
        Some(other) if other != "json" => {
            Response::json_error(400, &format!("unknown format {other:?} (json|pgm)"))
        }
        _ => Response::json(200, &map_json(map, width, height)),
    }
}

fn map_json(map: &BreakMap, width: Option<usize>, height: Option<usize>) -> Value {
    let mut fields = vec![("pixels", Value::Num(map.len() as f64))];
    if let (Some(w), Some(h)) = (width, height) {
        fields.push(("width", Value::Num(w as f64)));
        fields.push(("height", Value::Num(h as f64)));
    }
    fields.push((
        "breaks",
        Value::Arr(map.breaks.iter().map(|&b| Value::Num(b as f64)).collect()),
    ));
    fields.push((
        "first",
        Value::Arr(map.first.iter().map(|&f| Value::Num(f as f64)).collect()),
    ));
    fields.push((
        "momax",
        Value::Arr(map.momax.iter().map(|&x| Value::Num(x as f64)).collect()),
    ));
    Value::obj(fields)
}

// -- session endpoints ---------------------------------------------------

fn session_summary(name: &str, s: &MonitorSession) -> Value {
    let mut fields = vec![
        ("name", Value::Str(name.to_string())),
        ("pixels", Value::Num(s.n_pixels() as f64)),
        ("layers_seen", Value::Num(s.n_seen() as f64)),
        ("n_hist", Value::Num(s.params().n_hist as f64)),
        ("h", Value::Num(s.params().h as f64)),
        ("k", Value::Num(s.params().k as f64)),
        ("lambda", Value::Num(s.params().lambda)),
        ("last_t", Value::Num(s.time_axis().last().copied().unwrap_or(f64::NAN))),
        ("breaks", Value::Num(s.break_count() as f64)),
    ];
    if let (Some(w), Some(h)) = s.geometry() {
        fields.push(("width", Value::Num(w as f64)));
        fields.push(("height", Value::Num(h as f64)));
    }
    Value::obj(fields)
}

fn list_sessions(state: &ServerState) -> Response {
    let arr = state.registry.names().into_iter().map(Value::Str).collect();
    Response::json(200, &Value::obj(vec![("sessions", Value::Arr(arr))]))
}

fn create_session(req: &Request, name: &str, state: &ServerState) -> Response {
    if !registry::valid_name(name) {
        return Response::json_error(
            400,
            &format!("invalid session name {name:?} (use [A-Za-z0-9_-], at most 64 chars)"),
        );
    }
    let built = || -> Result<MonitorSession> {
        let init = if req.is_json() {
            let text = std::str::from_utf8(&req.body).context("non-UTF-8 JSON body")?;
            let init = SessionInit::from_json(&json::parse(text)?)?;
            reject_path_source(&init.source)?;
            init
        } else {
            let stack = rio::stack_from_bytes(&req.body, "request body")?;
            SessionInit {
                source: SceneSource::Inline(stack),
                params: params_from_query(req)?,
                init_layers: q_usize(req, "init-layers", 0)?,
            }
        };
        init.start_on(state.runner.as_ref())
    };
    let session = match built() {
        Ok(s) => s,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    let summary = session_summary(name, &session);
    match state.registry.insert(name, session) {
        Ok(()) => Response::json(201, &summary),
        Err(e) => Response::json_error(409, &format!("{e:#}")),
    }
}

fn session_status(name: &str, state: &ServerState) -> Response {
    match state.registry.with_session(name, |s| session_summary(name, s)) {
        Ok(v) => Response::json(200, &v),
        Err(e) => Response::json_error(404, &format!("{e:#}")),
    }
}

fn session_map(req: &Request, name: &str, state: &ServerState) -> Response {
    match state.registry.with_session(name, |s| (s.break_map(), s.geometry())) {
        Ok((map, (w, h))) => map_response(req, &map, w, h),
        Err(e) => Response::json_error(404, &format!("{e:#}")),
    }
}

fn session_ingest(req: &Request, name: &str, state: &ServerState) -> Response {
    if !state.registry.contains(name) {
        return Response::json_error(404, &format!("no session named {name:?}"));
    }
    let parsed = if req.is_json() {
        parse_json_layer(req)
    } else {
        parse_bten_layer(req)
    };
    let ingest = match parsed {
        Ok(v) => v,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    match state.registry.ingest(name, ingest.t, &ingest.values) {
        Ok(delta) => Response::json(200, &delta.to_json()),
        Err(e) => Response::json_error(400, &format!("{e:#}")),
    }
}

/// Octet-stream ingest: the body is a `.bten` f32 tensor, the
/// acquisition time rides in `?t=`.
fn parse_bten_layer(req: &Request) -> Result<SessionIngest> {
    let t: f64 = req
        .query_get("t")
        .ok_or_else(|| err!("query parameter t is required for bten ingest"))?
        .parse()
        .map_err(|_| err!("query t is not a number"))?;
    match bten_from_bytes(&req.body, "request body")? {
        Tensor::F32 { data, .. } => Ok(SessionIngest { t, values: data }),
        other => bail!("layer tensor must be f32 (got shape {:?})", other.shape()),
    }
}

/// JSON ingest — the [`SessionIngest`] wire form.
fn parse_json_layer(req: &Request) -> Result<SessionIngest> {
    let v = json::parse(std::str::from_utf8(&req.body).context("non-UTF-8 JSON body")?)?;
    SessionIngest::from_json(&v)
}

// ServerState crosses into pool workers behind an Arc — assert the
// shared pieces really are thread-safe (compile-time only).
#[allow(dead_code)]
fn assert_thread_safe() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<SharedBfastRunner>();
    assert_send_sync::<JobQueue>();
    assert_send_sync::<SessionRegistry>();
    assert_send_sync::<ServerState>();
}
