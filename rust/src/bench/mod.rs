//! `bfast bench` — the pinned perf-trajectory harness.
//!
//! The paper's claim is a speed number; this module makes the repo's
//! own speed numbers first-class artifacts. It runs the fig2/fig3
//! scenes (fixed seeds, pinned `BFAST_BENCH_SCALE`, warmup + N
//! trials) against the named engines, collects wall and per-phase
//! integer-ns medians via [`PhaseTimes`], and emits a canonical JSON
//! report (`BENCH_PR6.json` et seq.) carrying an environment
//! fingerprint — host threads, cargo profile, git rev, scale — so a
//! later PR's `bench diff OLD.json NEW.json` is an apples-to-apples
//! regression check.
//!
//! The JSON form follows the `api` discipline: `to_json` → `from_json`
//! is an exact round-trip and serialisation is a fixed point, so
//! committed reports can be schema-validated in CI without touching
//! timings.

use crate::coordinator::{BfastRunner, RunnerConfig};
use crate::cpu::FusedCpuBfast;
use crate::error::{bail, ensure, Context, Result};
use crate::json::{self, Value};
use crate::metrics::PhaseTimes;
use crate::params::BfastParams;
use crate::pixel::DirectBfast;
use crate::raster::TimeStack;
use crate::synth::ArtificialDataset;
use std::time::{Duration, Instant};

/// Schema version of the emitted report.
pub const SCHEMA_VERSION: u64 = 1;

/// Engine names accepted by the harness (`--engines`).
pub const ENGINE_FUSED: &str = "fused-cpu";
pub const ENGINE_DIRECT: &str = "direct";
pub const ENGINE_EMULATED: &str = "emulated";
pub const ENGINE_EMULATED_PHASED: &str = "emulated-phased";
pub const ENGINE_CMD: &str = "cmd-replay";

/// Fingerprint `source` for reports emitted by this harness. Reports
/// measured by other instruments (e.g. the committed kernel-replica
/// trajectory) must label themselves differently so a diff between
/// unlike sources is visibly unlike.
pub const SOURCE_HARNESS: &str = "bfast-bench";

/// Environment fingerprint carried by every report.
#[derive(Clone, Debug, PartialEq)]
pub struct Fingerprint {
    pub host_threads: usize,
    pub cargo_profile: String,
    pub git_rev: String,
    pub scale: f64,
    pub warmup: usize,
    pub trials: usize,
    /// What produced the numbers (see [`SOURCE_HARNESS`]).
    pub source: String,
}

impl Fingerprint {
    pub fn current(cfg: &BenchConfig) -> Self {
        Self {
            host_threads: crate::threadpool::default_threads(),
            cargo_profile: cargo_profile().to_string(),
            git_rev: git_rev(),
            scale: cfg.scale,
            warmup: cfg.warmup,
            trials: cfg.trials,
            source: SOURCE_HARNESS.to_string(),
        }
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("host_threads", Value::Num(self.host_threads as f64)),
            ("cargo_profile", Value::Str(self.cargo_profile.clone())),
            ("git_rev", Value::Str(self.git_rev.clone())),
            ("scale", Value::Num(self.scale)),
            ("warmup", Value::Num(self.warmup as f64)),
            ("trials", Value::Num(self.trials as f64)),
            ("source", Value::Str(self.source.clone())),
        ])
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            host_threads: v.get("host_threads")?.as_usize()?,
            cargo_profile: v.get("cargo_profile")?.as_str()?.to_string(),
            git_rev: v.get("git_rev")?.as_str()?.to_string(),
            scale: v.get("scale")?.as_f64()?,
            warmup: v.get("warmup")?.as_usize()?,
            trials: v.get("trials")?.as_usize()?,
            source: v.get("source")?.as_str()?.to_string(),
        })
    }
}

/// The cargo profile this binary was built under.
pub fn cargo_profile() -> &'static str {
    if cfg!(debug_assertions) {
        "debug"
    } else {
        "release"
    }
}

/// Short git revision of the enclosing checkout: walk up from the
/// current directory to `.git/HEAD`, follow one `ref:` indirection
/// (loose ref, then `packed-refs`). `"unknown"` when not in a repo —
/// the report stays emittable from an exported tarball.
pub fn git_rev() -> String {
    fn short(h: &str) -> String {
        h.chars().take(12).collect()
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| std::path::PathBuf::from("."));
    for _ in 0..16 {
        let head = dir.join(".git").join("HEAD");
        if let Ok(txt) = std::fs::read_to_string(&head) {
            let txt = txt.trim();
            let Some(rf) = txt.strip_prefix("ref: ") else {
                return short(txt); // detached HEAD: the hash itself
            };
            if let Ok(h) = std::fs::read_to_string(dir.join(".git").join(rf)) {
                return short(h.trim());
            }
            if let Ok(packed) = std::fs::read_to_string(dir.join(".git").join("packed-refs")) {
                for line in packed.lines() {
                    if let Some(hash) = line.trim_end().strip_suffix(rf) {
                        return short(hash.trim());
                    }
                }
            }
            return "unknown".to_string();
        }
        if !dir.pop() {
            break;
        }
    }
    "unknown".to_string()
}

/// One benchmark scene (paper figure analogue).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub about: &'static str,
    pub params: BfastParams,
    /// Pixel count at scale 1.0 (scaled by [`BenchConfig::scale`]).
    pub base_m: usize,
    pub seed: u64,
    pub engines: &'static [&'static str],
}

/// The pinned scenario set. Names, seeds and shapes are part of the
/// trajectory contract: changing them breaks comparability and must
/// re-baseline.
pub fn scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "fig2",
            about: "paper-shaped synthetic scene, implementation comparison",
            params: BfastParams::paper_synthetic(),
            base_m: 20_000,
            seed: 42,
            engines: &[ENGINE_FUSED, ENGINE_DIRECT, ENGINE_EMULATED, ENGINE_CMD],
        },
        Scenario {
            name: "fig3",
            about: "per-phase breakdown through the coordinated pipeline",
            params: BfastParams::paper_synthetic(),
            base_m: 50_000,
            seed: 42,
            engines: &[ENGINE_FUSED, ENGINE_EMULATED_PHASED],
        },
    ]
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub scale: f64,
    pub warmup: usize,
    pub trials: usize,
    /// Scenario-name filter; empty = all.
    pub scenarios: Vec<String>,
    /// Engine-name filter; empty = each scenario's full set.
    pub engines: Vec<String>,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            scale: crate::bench_support::bench_scale(),
            warmup: 1,
            trials: 5,
            scenarios: Vec::new(),
            engines: Vec::new(),
        }
    }
}

/// Timings of one engine on one scenario (all integer nanoseconds).
#[derive(Clone, Debug, PartialEq)]
pub struct EngineResult {
    pub engine: String,
    /// Wall time of every measured trial, in run order.
    pub trials_ns: Vec<u64>,
    pub median_ns: u64,
    pub min_ns: u64,
    /// Median per-phase breakdown, in the engine's phase order.
    pub phases_ns: Vec<(String, u64)>,
}

impl EngineResult {
    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("engine", Value::Str(self.engine.clone())),
            (
                "trials_ns",
                Value::Arr(self.trials_ns.iter().map(|&t| Value::Num(t as f64)).collect()),
            ),
            ("median_ns", Value::Num(self.median_ns as f64)),
            ("min_ns", Value::Num(self.min_ns as f64)),
            (
                "phases_ns",
                Value::Obj(
                    self.phases_ns
                        .iter()
                        .map(|(n, ns)| (n.clone(), Value::Num(*ns as f64)))
                        .collect(),
                ),
            ),
        ])
    }

    fn from_json(v: &Value) -> Result<Self> {
        let trials_ns = v
            .get("trials_ns")?
            .as_arr()?
            .iter()
            .map(|t| Ok(t.as_usize()? as u64))
            .collect::<Result<Vec<_>>>()?;
        let phases_ns = v
            .get("phases_ns")?
            .as_obj()?
            .iter()
            .map(|(n, ns)| Ok((n.clone(), ns.as_usize()? as u64)))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            engine: v.get("engine")?.as_str()?.to_string(),
            trials_ns,
            median_ns: v.get("median_ns")?.as_usize()? as u64,
            min_ns: v.get("min_ns")?.as_usize()? as u64,
            phases_ns,
        })
    }
}

/// All engine timings for one scenario at one scale.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioResult {
    pub scenario: String,
    pub about: String,
    pub m: usize,
    pub n_total: usize,
    pub n_hist: usize,
    pub h: usize,
    pub k: usize,
    pub seed: u64,
    pub engines: Vec<EngineResult>,
}

impl ScenarioResult {
    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("scenario", Value::Str(self.scenario.clone())),
            ("about", Value::Str(self.about.clone())),
            ("m", Value::Num(self.m as f64)),
            ("n_total", Value::Num(self.n_total as f64)),
            ("n_hist", Value::Num(self.n_hist as f64)),
            ("h", Value::Num(self.h as f64)),
            ("k", Value::Num(self.k as f64)),
            ("seed", Value::Num(self.seed as f64)),
            ("engines", Value::Arr(self.engines.iter().map(|e| e.to_json()).collect())),
        ])
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            scenario: v.get("scenario")?.as_str()?.to_string(),
            about: v.get("about")?.as_str()?.to_string(),
            m: v.get("m")?.as_usize()?,
            n_total: v.get("n_total")?.as_usize()?,
            n_hist: v.get("n_hist")?.as_usize()?,
            h: v.get("h")?.as_usize()?,
            k: v.get("k")?.as_usize()?,
            seed: v.get("seed")?.as_usize()? as u64,
            engines: v
                .get("engines")?
                .as_arr()?
                .iter()
                .map(EngineResult::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

/// A full harness report: the unit `bench diff` compares.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    pub version: u64,
    pub fingerprint: Fingerprint,
    pub scenarios: Vec<ScenarioResult>,
}

impl BenchReport {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("version", Value::Num(self.version as f64)),
            ("fingerprint", self.fingerprint.to_json()),
            ("scenarios", Value::Arr(self.scenarios.iter().map(|s| s.to_json()).collect())),
        ])
    }

    /// Canonical serialised form (pretty, stable key order; a fixed
    /// point of parse → serialise).
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let version = v.get("version")?.as_usize()? as u64;
        ensure!(
            version == SCHEMA_VERSION,
            "bench report schema v{version} unsupported (this build reads v{SCHEMA_VERSION})"
        );
        Ok(Self {
            version,
            fingerprint: Fingerprint::from_json(v.get("fingerprint")?)
                .context("bench report fingerprint")?,
            scenarios: v
                .get("scenarios")?
                .as_arr()?
                .iter()
                .map(ScenarioResult::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }

    pub fn from_json_str(s: &str) -> Result<Self> {
        Self::from_json(&json::parse(s)?)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let path = path.as_ref();
        let txt = std::fs::read_to_string(path)
            .with_context(|| format!("read bench report {}", path.display()))?;
        Self::from_json_str(&txt).with_context(|| format!("parse bench report {}", path.display()))
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json_string() + "\n")
            .with_context(|| format!("write bench report {}", path.display()))
    }

    /// Human-readable summary of the report.
    pub fn table(&self) -> String {
        use std::fmt::Write;
        let f = &self.fingerprint;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "bench report v{} | source={} profile={} rev={} threads={} scale={} \
             warmup={} trials={}",
            self.version,
            f.source,
            f.cargo_profile,
            f.git_rev,
            f.host_threads,
            f.scale,
            f.warmup,
            f.trials
        );
        for sc in &self.scenarios {
            let _ = writeln!(
                s,
                "{} (m={}, N={}, n={}, h={}, k={}, seed={}): {}",
                sc.scenario, sc.m, sc.n_total, sc.n_hist, sc.h, sc.k, sc.seed, sc.about
            );
            for er in &sc.engines {
                let _ = writeln!(
                    s,
                    "  {:<16} median {:>13} ns   min {:>13} ns",
                    er.engine, er.median_ns, er.min_ns
                );
                for (ph, ns) in &er.phases_ns {
                    let _ = writeln!(s, "      {ph:<24} {ns:>13} ns");
                }
            }
        }
        s
    }
}

/// Run the full (filtered) scenario grid.
pub fn run_all(cfg: &BenchConfig) -> Result<BenchReport> {
    let mut out = Vec::new();
    for sc in scenarios() {
        if !cfg.scenarios.is_empty() && !cfg.scenarios.iter().any(|s| s == sc.name) {
            continue;
        }
        out.push(run_scenario(&sc, cfg)?);
    }
    ensure!(
        !out.is_empty(),
        "no scenario matched the filter (known: {})",
        scenarios().iter().map(|s| s.name).collect::<Vec<_>>().join(", ")
    );
    Ok(BenchReport {
        version: SCHEMA_VERSION,
        fingerprint: Fingerprint::current(cfg),
        scenarios: out,
    })
}

/// Run one scenario: generate the scene once, build each engine once,
/// then warmup + trials per engine.
pub fn run_scenario(sc: &Scenario, cfg: &BenchConfig) -> Result<ScenarioResult> {
    let m = ((sc.base_m as f64 * cfg.scale) as usize).max(16);
    let p = &sc.params;
    let data = ArtificialDataset::new(p.clone(), m, sc.seed).generate();
    let mut engines = Vec::new();
    for &name in sc.engines {
        if !cfg.engines.is_empty() && !cfg.engines.iter().any(|e| e == name) {
            continue;
        }
        let mut run = engine_runner(name, p, &data.stack)?;
        for _ in 0..cfg.warmup {
            let _ = run()?;
        }
        let mut trials_ns = Vec::with_capacity(cfg.trials.max(1));
        let mut per_phase: Vec<(String, Vec<u64>)> = Vec::new();
        for _ in 0..cfg.trials.max(1) {
            let (wall, phases, n_breaks) = run()?;
            crate::bench_support::black_box(n_breaks);
            trials_ns.push(wall.as_nanos() as u64);
            for (ph, d) in phases.iter() {
                let ns = d.as_nanos() as u64;
                match per_phase.iter_mut().find(|(n, _)| n == ph) {
                    Some((_, v)) => v.push(ns),
                    None => per_phase.push((ph.to_string(), vec![ns])),
                }
            }
        }
        let median_ns = median_u64(&mut trials_ns.clone());
        let min_ns = *trials_ns.iter().min().expect("at least one trial");
        let phases_ns = per_phase
            .into_iter()
            .map(|(n, mut v)| (n, median_u64(&mut v)))
            .collect();
        engines.push(EngineResult { engine: name.to_string(), trials_ns, median_ns, min_ns, phases_ns });
    }
    Ok(ScenarioResult {
        scenario: sc.name.to_string(),
        about: sc.about.to_string(),
        m,
        n_total: p.n_total,
        n_hist: p.n_hist,
        h: p.h,
        k: p.k,
        seed: sc.seed,
        engines,
    })
}

/// Build the measured closure for one engine. Construction (design
/// matrices, runner state) happens once, outside the trial clock —
/// trials measure steady-state scene analysis.
#[allow(clippy::type_complexity)]
fn engine_runner<'a>(
    name: &str,
    p: &'a BfastParams,
    stack: &'a TimeStack,
) -> Result<Box<dyn FnMut() -> Result<(Duration, PhaseTimes, usize)> + 'a>> {
    match name {
        ENGINE_FUSED => {
            let eng = FusedCpuBfast::new(p.clone(), &stack.time_axis)?;
            Ok(Box::new(move || {
                let t0 = Instant::now();
                let (map, times) = eng.run(stack)?;
                Ok((t0.elapsed(), times, map.break_count()))
            }))
        }
        ENGINE_DIRECT => {
            let eng = DirectBfast::new(p.clone(), &stack.time_axis)?;
            Ok(Box::new(move || {
                let t0 = Instant::now();
                let map = eng.run(stack)?;
                Ok((t0.elapsed(), PhaseTimes::new(), map.break_count()))
            }))
        }
        ENGINE_EMULATED => {
            let runner = BfastRunner::emulated(RunnerConfig::default())?;
            Ok(Box::new(move || {
                let t0 = Instant::now();
                let res = runner.run(stack, p)?;
                Ok((t0.elapsed(), res.phases, res.map.break_count()))
            }))
        }
        ENGINE_EMULATED_PHASED => {
            let runner =
                BfastRunner::emulated(RunnerConfig { phased: true, ..Default::default() })?;
            Ok(Box::new(move || {
                let t0 = Instant::now();
                let res = runner.run(stack, p)?;
                Ok((t0.elapsed(), res.phases, res.map.break_count()))
            }))
        }
        ENGINE_CMD => {
            // record-then-replay: the stream is re-recorded every trial
            // so the measured number is the full command-stream path,
            // not just executor dispatch
            let runner = BfastRunner::cmdstream(RunnerConfig::default())?;
            Ok(Box::new(move || {
                let t0 = Instant::now();
                let res = runner.run(stack, p)?;
                Ok((t0.elapsed(), res.phases, res.map.break_count()))
            }))
        }
        other => bail!(
            "unknown engine {other:?} (known: {ENGINE_FUSED}, {ENGINE_DIRECT}, \
             {ENGINE_EMULATED}, {ENGINE_EMULATED_PHASED}, {ENGINE_CMD})"
        ),
    }
}

/// One comparable (scenario, engine) pair in a diff.
#[derive(Clone, Debug)]
pub struct DiffRow {
    pub scenario: String,
    pub engine: String,
    pub base_ns: u64,
    pub new_ns: u64,
    /// base/new: > 1 is faster, < 1 is slower.
    pub speedup: f64,
}

/// `bench diff` result: matched rows plus anything incomparable.
#[derive(Clone, Debug, Default)]
pub struct DiffReport {
    pub rows: Vec<DiffRow>,
    /// (scenario, engine) pairs present in base but absent or
    /// incomparable (different m) in new.
    pub missing: Vec<String>,
}

impl DiffReport {
    /// Rows slower than `1 + tolerance` (e.g. 0.1 = flag >10% slower).
    pub fn regressions(&self, tolerance: f64) -> Vec<&DiffRow> {
        self.rows.iter().filter(|r| r.speedup < 1.0 / (1.0 + tolerance)).collect()
    }

    pub fn table(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "{:<10} {:<16} {:>13} {:>13} {:>9}",
            "scenario", "engine", "base ns", "new ns", "speedup"
        );
        for r in &self.rows {
            let _ = writeln!(
                s,
                "{:<10} {:<16} {:>13} {:>13} {:>8.2}x",
                r.scenario, r.engine, r.base_ns, r.new_ns, r.speedup
            );
        }
        for m in &self.missing {
            let _ = writeln!(s, "! {m}");
        }
        s
    }
}

/// Compare two reports by (scenario, engine) median wall time.
pub fn diff(base: &BenchReport, new: &BenchReport) -> DiffReport {
    let mut out = DiffReport::default();
    for sc in &base.scenarios {
        let Some(nsc) = new.scenarios.iter().find(|s| s.scenario == sc.scenario) else {
            out.missing.push(format!("scenario {:?} absent from new report", sc.scenario));
            continue;
        };
        for er in &sc.engines {
            let Some(ner) = nsc.engines.iter().find(|e| e.engine == er.engine) else {
                out.missing
                    .push(format!("{}/{} absent from new report", sc.scenario, er.engine));
                continue;
            };
            if sc.m != nsc.m {
                out.missing.push(format!(
                    "{}/{}: m {} vs {} — incomparable (different scale?)",
                    sc.scenario, er.engine, sc.m, nsc.m
                ));
                continue;
            }
            let speedup = if ner.median_ns > 0 {
                er.median_ns as f64 / ner.median_ns as f64
            } else {
                f64::INFINITY
            };
            out.rows.push(DiffRow {
                scenario: sc.scenario.clone(),
                engine: er.engine.clone(),
                base_ns: er.median_ns,
                new_ns: ner.median_ns,
                speedup,
            });
        }
    }
    out
}

/// Fixed seed for chunk-width autotuning runs.
pub const TUNE_SEED: u64 = 42;

/// Default chunk-width candidates for [`tune_m_chunk`].
pub const TUNE_CANDIDATES: &[usize] = &[256, 512, 1024, 2048, 4096];

/// Measure the coordinated emulated pipeline at each candidate
/// `m_chunk` (1 warmup + `trials` measured runs each) and return
/// `(best, [(candidate, median_ns)])`. The winner is what
/// `RunnerConfig::m_chunk` should be seeded with on this host.
pub fn tune_m_chunk(
    params: &BfastParams,
    m: usize,
    candidates: &[usize],
    trials: usize,
) -> Result<(usize, Vec<(usize, u64)>)> {
    ensure!(!candidates.is_empty(), "no m_chunk candidates to tune over");
    let data = ArtificialDataset::new(params.clone(), m, TUNE_SEED).generate();
    let mut measured = Vec::with_capacity(candidates.len());
    for &mc in candidates {
        ensure!(mc >= 1, "m_chunk candidate must be >= 1, got {mc}");
        let runner =
            BfastRunner::emulated(RunnerConfig { m_chunk: Some(mc), ..Default::default() })?;
        let _ = runner.run(&data.stack, params)?; // warmup
        let mut walls = Vec::with_capacity(trials.max(1));
        for _ in 0..trials.max(1) {
            let t0 = Instant::now();
            let res = runner.run(&data.stack, params)?;
            crate::bench_support::black_box(res.map.break_count());
            walls.push(t0.elapsed().as_nanos() as u64);
        }
        measured.push((mc, median_u64(&mut walls)));
    }
    let best = measured.iter().min_by_key(|&&(_, ns)| ns).map(|&(mc, _)| mc).expect("non-empty");
    Ok((best, measured))
}

/// Integer median (lower-biased mean of the middle pair for even n).
fn median_u64(xs: &mut [u64]) -> u64 {
    assert!(!xs.is_empty());
    xs.sort_unstable();
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        let (a, b) = (xs[n / 2 - 1], xs[n / 2]);
        a / 2 + b / 2 + (a % 2 + b % 2) / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> BenchReport {
        BenchReport {
            version: SCHEMA_VERSION,
            fingerprint: Fingerprint {
                host_threads: 8,
                cargo_profile: "release".into(),
                git_rev: "abc123def456".into(),
                scale: 0.25,
                warmup: 1,
                trials: 5,
                source: SOURCE_HARNESS.into(),
            },
            scenarios: vec![ScenarioResult {
                scenario: "fig2".into(),
                about: "test".into(),
                m: 5000,
                n_total: 200,
                n_hist: 100,
                h: 50,
                k: 3,
                seed: 42,
                engines: vec![EngineResult {
                    engine: ENGINE_FUSED.into(),
                    trials_ns: vec![120, 100, 110],
                    median_ns: 110,
                    min_ns: 100,
                    phases_ns: vec![("create model".into(), 40), ("mosum".into(), 30)],
                }],
            }],
        }
    }

    #[test]
    fn json_roundtrip_is_a_fixed_point() {
        let r = sample_report();
        let s1 = r.to_json_string();
        let back = BenchReport::from_json_str(&s1).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.to_json_string(), s1, "serialise is a fixed point");
        // phase order survives
        assert_eq!(back.scenarios[0].engines[0].phases_ns[0].0, "create model");
    }

    #[test]
    fn rejects_unknown_schema_version() {
        let mut r = sample_report();
        r.version = SCHEMA_VERSION + 1;
        let err = BenchReport::from_json_str(&r.to_json_string()).unwrap_err().to_string();
        assert!(err.contains("schema"), "{err}");
    }

    #[test]
    fn diff_matches_pairs_and_flags_missing() {
        let base = sample_report();
        let mut new = sample_report();
        new.scenarios[0].engines[0].median_ns = 55; // 2x faster
        let d = diff(&base, &new);
        assert_eq!(d.rows.len(), 1);
        assert!((d.rows[0].speedup - 2.0).abs() < 1e-9, "{}", d.rows[0].speedup);
        assert!(d.missing.is_empty());
        assert!(d.regressions(0.1).is_empty());

        // slower new run is a regression
        new.scenarios[0].engines[0].median_ns = 200;
        let d = diff(&base, &new);
        assert_eq!(d.regressions(0.1).len(), 1);

        // m mismatch is incomparable, engine absence is reported
        new.scenarios[0].engines[0].median_ns = 110;
        new.scenarios[0].m = 1;
        let d = diff(&base, &new);
        assert!(d.rows.is_empty());
        assert_eq!(d.missing.len(), 1, "{:?}", d.missing);
        new.scenarios.clear();
        let d = diff(&base, &new);
        assert_eq!(d.missing.len(), 1);
        assert!(d.table().contains('!'));
    }

    #[test]
    fn median_u64_odd_even() {
        assert_eq!(median_u64(&mut [3, 1, 2]), 2);
        assert_eq!(median_u64(&mut [4, 1, 2, 3]), 2);
        assert_eq!(median_u64(&mut [7]), 7);
        assert_eq!(median_u64(&mut [u64::MAX, u64::MAX]), u64::MAX);
    }

    #[test]
    fn fingerprint_smoke() {
        assert!(matches!(cargo_profile(), "debug" | "release"));
        let rev = git_rev();
        assert!(!rev.is_empty() && rev.len() <= 12, "{rev}");
        let f = Fingerprint::current(&BenchConfig::default());
        assert_eq!(f.source, SOURCE_HARNESS);
        assert!(f.host_threads >= 1);
    }

    #[test]
    fn scenario_names_are_unique_and_engines_known() {
        let known =
            [ENGINE_FUSED, ENGINE_DIRECT, ENGINE_EMULATED, ENGINE_EMULATED_PHASED, ENGINE_CMD];
        let scs = scenarios();
        for (i, a) in scs.iter().enumerate() {
            assert!(scs[i + 1..].iter().all(|b| b.name != a.name), "dup {}", a.name);
            for e in a.engines {
                assert!(known.contains(e), "unknown engine {e}");
            }
        }
    }
}
