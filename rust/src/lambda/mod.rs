//! Critical value λ(α, h/n, N/n) for the MOSUM boundary (paper §2.1).
//!
//! The paper: *"the specific value of λ has been found by simulation
//! of different values of α, h, and N/n"*. This module reproduces that
//! simulation substrate two ways:
//!
//! * [`critical_value`] — the production lookup: Monte-Carlo on the
//!   *limit process* of the OLS-MOSUM monitoring statistic
//!   (`W(s) − W(s−h̄) − h̄·W(1)` for s ∈ (1, N/n], with the boundary
//!   shape √log₊ s divided out, so λ is the (1−α)-quantile of the
//!   normalised supremum). Deterministic seed, memoised per
//!   (α, h̄, horizon).
//! * [`simulate_lambda`] — the finite-sample check: simulates the
//!   *actual* pipeline (season-trend OLS fit on iid noise, MOSUM,
//!   sup |MO|/√log₊) for a concrete [`BfastParams`]; used by tests to
//!   validate the limit approximation and by the `lambda-table` CLI.
//!
//! For the paper's Chile setting (h/n = 0.5, N/n = 2, α = 0.05) the
//! paper quotes a boundary of 2.39 — the reference point our tests pin
//! within tolerance.

use crate::design;
use crate::mosum;
use crate::params::BfastParams;
use crate::prng::{Normal, Pcg32};
use crate::threadpool;
use crate::error::{ensure, Result};
use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// Grid resolution (steps per unit of rescaled time) for the limit MC.
const GRID: usize = 256;
/// Replications for the limit MC.
const REPS: usize = 20_000;

fn cache() -> &'static Mutex<HashMap<(u64, u64, u64), f64>> {
    static CACHE: OnceLock<Mutex<HashMap<(u64, u64, u64), f64>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// λ such that the normalised MOSUM limit process crosses the boundary
/// with probability α over the monitoring horizon.
///
/// * `alpha` ∈ (0, 1) — crossing probability (paper uses 0.05)
/// * `h_frac` = h/n ∈ (0, 1]
/// * `horizon` = N/n ∈ (1, 16]
pub fn critical_value(alpha: f64, h_frac: f64, horizon: f64) -> Result<f64> {
    ensure!(alpha > 0.0 && alpha < 1.0, "alpha in (0,1), got {alpha}");
    ensure!(h_frac > 0.0 && h_frac <= 1.0, "h/n in (0,1], got {h_frac}");
    ensure!(horizon > 1.0 && horizon <= 16.0, "N/n in (1,16], got {horizon}");
    let key = (alpha.to_bits(), h_frac.to_bits(), horizon.to_bits());
    if let Some(&v) = cache().lock().unwrap().get(&key) {
        return Ok(v);
    }
    let v = limit_mc(alpha, h_frac, horizon, REPS, 0x1A3B_5C7D);
    cache().lock().unwrap().insert(key, v);
    Ok(v)
}

/// One path of the limit statistic: sup over the monitor grid of
/// |W(s) − W(s−h̄) − h̄·W(1)| / √log₊(s).
fn limit_path_stat(rng: &mut Normal, h_frac: f64, horizon: f64) -> f64 {
    let steps_total = (horizon * GRID as f64).round() as usize;
    let steps_hist = GRID; // history is [0, 1]
    let dt_sqrt = (1.0 / GRID as f64).sqrt();
    // prefix sums of Brownian increments
    let mut w = Vec::with_capacity(steps_total + 1);
    w.push(0.0);
    let mut acc = 0.0;
    for _ in 0..steps_total {
        acc += dt_sqrt * rng.sample();
        w.push(acc);
    }
    let w1 = w[steps_hist];
    let hsteps = ((h_frac * GRID as f64).round() as usize).max(1);
    let mut stat: f64 = 0.0;
    for i in steps_hist + 1..=steps_total {
        let s = i as f64 / GRID as f64;
        let mo = w[i] - w[i - hsteps] - h_frac * w1;
        let norm = mosum::log_plus(s).sqrt();
        let v = (mo / norm).abs();
        if v > stat {
            stat = v;
        }
    }
    stat
}

fn limit_mc(alpha: f64, h_frac: f64, horizon: f64, reps: usize, seed: u64) -> f64 {
    let threads = threadpool::default_threads();
    let stats = threadpool::parallel_map(reps, threads, |i| {
        let mut nrm = Normal::new(Pcg32::with_stream(seed, i as u64));
        limit_path_stat(&mut nrm, h_frac, horizon)
    });
    quantile(stats, 1.0 - alpha)
}

/// Empirical quantile (linear interpolation between order statistics).
pub fn quantile(mut xs: Vec<f64>, q: f64) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (xs.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    xs[lo] * (1.0 - frac) + xs[hi] * frac
}

/// Finite-sample λ for concrete params: run the real pipeline on iid
/// Gaussian noise `reps` times, return the (1−α) quantile of
/// sup_t |MO_t| / √log₊(t/n).
pub fn simulate_lambda(params: &BfastParams, reps: usize, seed: u64) -> f64 {
    let x = design::design_for(params);
    let m = design::history_pinv(&x, params.n_hist).expect("design full rank");
    let n_total = params.n_total;
    let threads = threadpool::default_threads();
    let stats = threadpool::parallel_map(reps, threads, |i| {
        let mut nrm = Normal::new(Pcg32::with_stream(seed, i as u64));
        let y: Vec<f64> = (0..n_total).map(|_| nrm.sample()).collect();
        let beta = m.matvec(&y[..params.n_hist]).expect("shapes");
        let mut r = vec![0.0; n_total];
        for t in 0..n_total {
            let mut pred = 0.0;
            for (j, &b) in beta.iter().enumerate() {
                pred += x[(j, t)] * b;
            }
            r[t] = y[t] - pred;
        }
        let mo = mosum::mosum_process(&r, params);
        let n = params.n_hist as f64;
        mo.iter()
            .enumerate()
            .map(|(idx, &v)| {
                let t = (params.n_hist + 1 + idx) as f64;
                v.abs() / mosum::log_plus(t / n).sqrt()
            })
            .fold(0.0f64, f64::max)
    });
    quantile(stats, 1.0 - params.alpha)
}

/// Pretty table over (α, h̄) for a fixed horizon — the `lambda-table`
/// CLI output, analogous to the simulated tables in Verbesselt et al.
pub fn table(horizon: f64, alphas: &[f64], h_fracs: &[f64]) -> Result<String> {
    use std::fmt::Write;
    let mut s = String::new();
    writeln!(s, "critical values lambda(alpha, h/n) at horizon N/n = {horizon}")?;
    write!(s, "{:>8}", "h/n\\a")?;
    for &a in alphas {
        write!(s, "{a:>9.3}")?;
    }
    writeln!(s)?;
    for &hf in h_fracs {
        write!(s, "{hf:>8.3}")?;
        for &a in alphas {
            write!(s, "{:>9.3}", critical_value(a, hf, horizon)?)?;
        }
        writeln!(s)?;
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_basics() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(xs.clone(), 0.0), 1.0);
        assert_eq!(quantile(xs.clone(), 1.0), 4.0);
        assert!((quantile(xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_alpha_and_h() {
        // smaller alpha -> larger lambda; larger window -> larger sums
        let l05 = critical_value(0.05, 0.5, 2.0).unwrap();
        let l10 = critical_value(0.10, 0.5, 2.0).unwrap();
        let l01 = critical_value(0.01, 0.5, 2.0).unwrap();
        assert!(l01 > l05 && l05 > l10, "{l01} {l05} {l10}");
        let small_h = critical_value(0.05, 0.25, 2.0).unwrap();
        assert!(l05 > small_h, "{l05} vs {small_h}");
    }

    #[test]
    fn chile_setting_near_paper_quoted_239() {
        // paper §4.3: boundary at 2.39 for h/n=0.5, N/n=2, alpha=0.05
        let lam = critical_value(0.05, 0.5, 2.0).unwrap();
        assert!(
            (lam - 2.39).abs() < 0.25,
            "limit-MC lambda {lam} too far from paper's 2.39"
        );
    }

    #[test]
    fn limit_matches_mean_model_finite_sample() {
        // Validate the limit MC against a finite-sample process whose
        // design matches its assumptions (intercept-only OLS): the
        // (1-alpha) quantile of sup |MO|/sqrt(log+) must agree.
        let (n, n_tot, h) = (100usize, 200usize, 50usize);
        let reps = 4000;
        let threads = crate::threadpool::default_threads();
        let stats = crate::threadpool::parallel_map(reps, threads, |i| {
            let mut nrm = Normal::new(crate::prng::Pcg32::with_stream(99, i as u64));
            let y: Vec<f64> = (0..n_tot).map(|_| nrm.sample()).collect();
            let mean = y[..n].iter().sum::<f64>() / n as f64;
            let r: Vec<f64> = y.iter().map(|v| v - mean).collect();
            let sigma =
                (r[..n].iter().map(|x| x * x).sum::<f64>() / (n - 1) as f64).sqrt();
            let denom = sigma * (n as f64).sqrt();
            let mut acc: f64 = r[n + 1 - h..=n].iter().sum();
            let mut stat: f64 = 0.0;
            for t in n + 1..=n_tot {
                if t > n + 1 {
                    acc += r[t - 1] - r[t - 1 - h];
                }
                let norm = crate::mosum::log_plus(t as f64 / n as f64).sqrt();
                stat = stat.max((acc / denom / norm).abs());
            }
            stat
        });
        let fin = quantile(stats, 0.95);
        let lim = critical_value(0.05, 0.5, 2.0).unwrap();
        let rel = (fin - lim).abs() / lim;
        assert!(rel < 0.1, "finite {fin} vs limit {lim} (rel {rel:.3})");
    }

    #[test]
    fn finite_sample_with_trend_exceeds_limit() {
        // With a trending regressor the beta-hat extrapolation drift
        // inflates the finite-sample quantile above the limit value —
        // the reason bfastmonitor analyses use conservative alphas.
        // Documented in EXPERIMENTS.md; here we pin the ordering.
        let p = BfastParams::with_lambda(200, 100, 50, 3, 23.0, 0.05, 1.0).unwrap();
        let fin = simulate_lambda(&p, 1000, 7);
        let lim = critical_value(0.05, 0.5, 2.0).unwrap();
        assert!(fin > lim, "finite {fin} should exceed limit {lim}");
        assert!(fin < 4.0 * lim, "finite {fin} implausibly large vs {lim}");
    }

    #[test]
    fn determinism() {
        let a = critical_value(0.05, 0.5, 2.0).unwrap();
        let b = critical_value(0.05, 0.5, 2.0).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn rejects_bad_domain() {
        assert!(critical_value(0.0, 0.5, 2.0).is_err());
        assert!(critical_value(0.05, 0.0, 2.0).is_err());
        assert!(critical_value(0.05, 1.5, 2.0).is_err());
        assert!(critical_value(0.05, 0.5, 1.0).is_err());
    }

    #[test]
    fn table_renders() {
        let t = table(2.0, &[0.05], &[0.5]).unwrap();
        assert!(t.contains("lambda"));
        assert!(t.lines().count() >= 3);
    }
}
