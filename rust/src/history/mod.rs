//! Stable-history-period selection — the ROC (reverse-ordered CUSUM)
//! procedure of Verbesselt et al. (2012), the BFAST(monitor) component
//! that *chooses* n when it is not known a priori.
//!
//! The paper's pipeline assumes "a stable history period … known in
//! advance" (§2.1); bfastmonitor in practice derives it with ROC: run
//! a *recursive CUSUM* test backwards from the monitoring start and cut
//! the history at the latest boundary crossing. This module provides
//!
//! * [`Rls`] — recursive least squares (Sherman–Morrison P-matrix
//!   updates), the substrate for recursive residuals;
//! * [`rec_cusum`] — the Brown–Durbin–Evans recursive-CUSUM process;
//! * [`roc_history_start`] — the reverse-ordered scan returning the
//!   first index of the stable history.

use crate::linalg::Mat;
use crate::error::{ensure, Result};

/// Recursive least squares over a fixed design.
///
/// Maintains β̂_t and P_t = (X_{1..t}ᵀ X_{1..t})⁻¹ via rank-one
/// Sherman–Morrison updates; yields the standardised *recursive
/// residuals* `w_t = (y_t − x_tᵀ β̂_{t−1}) / √(1 + x_tᵀ P_{t−1} x_t)`
/// that the CUSUM test is built on.
pub struct Rls {
    p: usize,
    beta: Vec<f64>,
    pmat: Mat,
    seen: usize,
}

impl Rls {
    /// Initialise from the first p observations (exact solve).
    pub fn init(xs: &[&[f64]], ys: &[f64]) -> Result<Self> {
        let p = xs.first().map(|x| x.len()).unwrap_or(0);
        ensure!(p > 0 && xs.len() == p && ys.len() == p, "RLS init needs exactly p rows");
        let mut g = Mat::zeros(p, p);
        let mut xty = vec![0.0; p];
        for (x, &y) in xs.iter().zip(ys) {
            ensure!(x.len() == p, "row arity");
            for i in 0..p {
                for j in 0..p {
                    g[(i, j)] += x[i] * x[j];
                }
                xty[i] += x[i] * y;
            }
        }
        // ridge the init Gram very slightly: the first p harmonic rows
        // can be near-collinear for small t spans
        for i in 0..p {
            g[(i, i)] += 1e-10;
        }
        let pmat = g.inverse()?;
        let beta = pmat.matvec(&xty)?;
        Ok(Self { p, beta, pmat, seen: p })
    }

    /// Observations consumed so far.
    pub fn seen(&self) -> usize {
        self.seen
    }

    pub fn beta(&self) -> &[f64] {
        &self.beta
    }

    /// Feed one observation; returns the standardised recursive
    /// residual w_t (prediction error before updating).
    pub fn update(&mut self, x: &[f64], y: f64) -> f64 {
        debug_assert_eq!(x.len(), self.p);
        // v = P x ; s = 1 + xᵀ P x
        let v: Vec<f64> = (0..self.p)
            .map(|i| (0..self.p).map(|j| self.pmat[(i, j)] * x[j]).sum())
            .collect();
        let s: f64 = 1.0 + x.iter().zip(&v).map(|(a, b)| a * b).sum::<f64>();
        let pred: f64 = x.iter().zip(&self.beta).map(|(a, b)| a * b).sum();
        let err = y - pred;
        // beta += P x err / s ; P -= v vᵀ / s
        for i in 0..self.p {
            self.beta[i] += v[i] * err / s;
        }
        for i in 0..self.p {
            for j in 0..self.p {
                self.pmat[(i, j)] -= v[i] * v[j] / s;
            }
        }
        self.seen += 1;
        err / s.sqrt()
    }
}

/// Recursive-CUSUM process over (X, y): returns the scaled partial
/// sums `W_j = Σ_{t=p+1..j} w_t / (σ̂ √(n−p))` for j = p+1..n
/// (Brown–Durbin–Evans efp), where σ̂ is the sd of the recursive
/// residuals.
pub fn rec_cusum(x: &Mat, y: &[f64]) -> Result<Vec<f64>> {
    let p = x.rows();
    let n = y.len();
    ensure!(x.cols() == n, "design is {}x{}, y has {}", x.rows(), x.cols(), n);
    ensure!(n > p + 1, "need more than p+1 observations");
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|t| (0..p).map(|j| x[(j, t)]).collect())
        .collect();
    let init_rows: Vec<&[f64]> = rows[..p].iter().map(|r| r.as_slice()).collect();
    let mut rls = Rls::init(&init_rows, &y[..p])?;
    let mut w = Vec::with_capacity(n - p);
    for t in p..n {
        w.push(rls.update(&rows[t], y[t]));
    }
    let mean = w.iter().sum::<f64>() / w.len() as f64;
    let sigma = (w.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
        / (w.len() as f64 - 1.0))
        .sqrt();
    let denom = sigma * (w.len() as f64).sqrt();
    let mut acc = 0.0;
    Ok(w.iter()
        .map(|v| {
            acc += v;
            acc / denom
        })
        .collect())
}

/// Brown–Durbin–Evans critical value for the recursive-CUSUM boundary
/// `b(s) = λ (1 + 2s)`, s ∈ [0, 1].
pub fn rec_cusum_lambda(alpha: f64) -> f64 {
    // classical tabulated values (BDE 1975 / strucchange)
    match alpha {
        a if a <= 0.01 => 1.143,
        a if a <= 0.05 => 0.948,
        a if a <= 0.10 => 0.850,
        _ => 0.850,
    }
}

/// ROC: reverse-ordered CUSUM history selection, amortised across a
/// scene.
///
/// The reversed candidate-history design and the critical value are
/// shared by every pixel, so a scene-wide scan (the monitor session's
/// `--roc` pre-pass) builds one scanner and calls [`RocScanner::scan`]
/// per series instead of re-deriving the design m times.
pub struct RocScanner {
    xr: Mat,
    lam: f64,
    p: usize,
    n: usize,
}

impl RocScanner {
    /// `x` is the (p × n_hist) design of the candidate history (in
    /// chronological order); `alpha` the BDE significance level.
    pub fn new(x: &Mat, alpha: f64) -> Result<Self> {
        let p = x.rows();
        let n = x.cols();
        ensure!(n >= 1, "empty candidate history");
        let xr = Mat::from_fn(p, n, |i, j| x[(i, n - 1 - j)]);
        Ok(Self { xr, lam: rec_cusum_lambda(alpha), p, n })
    }

    /// Candidate-history length the scanner was built for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Scan one series (chronological order, length n_hist): returns
    /// the 0-based index where the stable history begins — the sample
    /// just after the latest boundary crossing, or 0 if the whole
    /// history is stable.
    pub fn scan(&self, y: &[f64]) -> Result<usize> {
        let (p, n) = (self.p, self.n);
        ensure!(y.len() == n, "history has {} samples, scanner expects {}", y.len(), n);
        if n <= 2 * p + 2 {
            return Ok(0); // too short to test — keep everything
        }
        let yr: Vec<f64> = y.iter().rev().copied().collect();
        let cus = rec_cusum(&self.xr, &yr)?;
        let m = cus.len() as f64;
        let mut crossing: Option<usize> = None; // index into cus (reversed axis)
        for (j, &v) in cus.iter().enumerate() {
            let s = (j + 1) as f64 / m;
            if v.abs() > self.lam * (1.0 + 2.0 * s) {
                crossing = Some(j);
                break; // first crossing in reverse order = latest in time
            }
        }
        Ok(match crossing {
            // cus index j corresponds to reversed position p + j, i.e.
            // chronological index n - 1 - (p + j); history starts after it
            Some(j) => n - (p + j),
            None => 0,
        })
    }
}

/// One-shot ROC scan (see [`RocScanner`]): `x` is the (p × n_hist)
/// design of the candidate history, `y` the candidate history
/// observations (chronological order).
pub fn roc_history_start(x: &Mat, y: &[f64], alpha: f64) -> Result<usize> {
    ensure!(x.cols() == y.len(), "design/history length mismatch");
    RocScanner::new(x, alpha)?.scan(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design;
    use crate::prng::Normal;

    fn design(n: usize) -> Mat {
        design::design_matrix(&design::regular_time_axis(n), 12.0, 1)
    }

    #[test]
    fn rls_matches_batch_ols() {
        let n = 60;
        let x = design(n);
        let mut nrm = Normal::from_seed(1);
        let y: Vec<f64> = (0..n)
            .map(|t| {
                0.4 + 0.02 * (t as f64 / 12.0)
                    + 0.3 * (2.0 * std::f64::consts::PI * t as f64 / 12.0).sin()
                    + 0.05 * nrm.sample()
            })
            .collect();
        let p = x.rows();
        let rows: Vec<Vec<f64>> = (0..n).map(|t| (0..p).map(|j| x[(j, t)]).collect()).collect();
        let init: Vec<&[f64]> = rows[..p].iter().map(|r| r.as_slice()).collect();
        let mut rls = Rls::init(&init, &y[..p]).unwrap();
        for t in p..n {
            rls.update(&rows[t], y[t]);
        }
        // batch OLS
        let m = design::history_pinv(&x, n).unwrap();
        let beta = m.matvec(&y).unwrap();
        for (a, b) in rls.beta().iter().zip(&beta) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert_eq!(rls.seen(), n);
    }

    #[test]
    fn cusum_stays_inside_under_null() {
        let n = 80;
        let x = design(n);
        let mut nrm = Normal::from_seed(2);
        let y: Vec<f64> = (0..n).map(|_| nrm.sample()).collect();
        let cus = rec_cusum(&x, &y).unwrap();
        let lam = rec_cusum_lambda(0.01); // conservative
        let m = cus.len() as f64;
        let inside = cus
            .iter()
            .enumerate()
            .all(|(j, v)| v.abs() <= lam * (1.0 + 2.0 * (j + 1) as f64 / m));
        assert!(inside, "null series crossed the 1% boundary");
    }

    #[test]
    fn roc_keeps_stable_history() {
        let n = 100;
        let x = design(n);
        let mut nrm = Normal::from_seed(3);
        let y: Vec<f64> = (0..n)
            .map(|t| {
                0.3 * (2.0 * std::f64::consts::PI * t as f64 / 12.0).sin() + 0.02 * nrm.sample()
            })
            .collect();
        assert_eq!(roc_history_start(&x, &y, 0.05).unwrap(), 0);
    }

    #[test]
    fn roc_cuts_at_level_shift() {
        let n = 120;
        let shift_at = 40; // chronological index of the break
        let x = design(n);
        let mut nrm = Normal::from_seed(4);
        let y: Vec<f64> = (0..n)
            .map(|t| {
                let base = if t < shift_at { 2.0 } else { 0.0 };
                base + 0.1 * (2.0 * std::f64::consts::PI * t as f64 / 12.0).sin()
                    + 0.03 * nrm.sample()
            })
            .collect();
        let start = roc_history_start(&x, &y, 0.05).unwrap();
        // CUSUM has a detection lag of a few samples when walking
        // backwards past the break, so allow a small contamination
        // window before the shift, and bounded trimming after it.
        assert!(start >= shift_at - 12, "start {start} vs shift {shift_at}");
        assert!(start <= shift_at + 25, "start {start} discards stable data");
        assert!(start > 0, "the break must cut the history");
    }

    #[test]
    fn roc_short_history_kept_whole() {
        let n = 8;
        let x = design(n);
        let y = vec![0.1; n];
        assert_eq!(roc_history_start(&x, &y, 0.05).unwrap(), 0);
    }

    #[test]
    fn rec_cusum_shape_errors() {
        let x = design(10);
        assert!(rec_cusum(&x, &[0.0; 4]).is_err());
    }

    #[test]
    fn scanner_reused_across_series() {
        let n = 120;
        let x = design(n);
        let scanner = RocScanner::new(&x, 0.05).unwrap();
        assert_eq!(scanner.n(), n);
        let mut nrm = Normal::from_seed(11);
        for shift_at in [30usize, 60] {
            let y: Vec<f64> = (0..n)
                .map(|t| {
                    let base = if t < shift_at { 2.0 } else { 0.0 };
                    base + 0.1 * (2.0 * std::f64::consts::PI * t as f64 / 12.0).sin()
                        + 0.03 * nrm.sample()
                })
                .collect();
            let a = scanner.scan(&y).unwrap();
            let b = roc_history_start(&x, &y, 0.05).unwrap();
            assert_eq!(a, b, "scanner vs one-shot at shift {shift_at}");
            assert!(a > 0, "shift at {shift_at} must cut the history");
        }
        assert!(scanner.scan(&[0.0; 5]).is_err());
    }
}
