//! The BFAST(R) analogue: deliberately per-pixel *everything*.
//!
//! For each series this re-builds the design matrix, re-computes the
//! Gram matrix and its inverse, and allocates every intermediate —
//! mirroring how the general-purpose R implementation treats each
//! pixel as an independent analysis (plus sanity checks and
//! per-call overhead). No work is shared across pixels by design;
//! this is the Fig. 2 lower bound.

use crate::design;
use crate::mosum;
use crate::params::BfastParams;
use crate::raster::{BreakMap, TimeStack};

use super::PixelResult;

/// Per-pixel, zero-sharing BFAST. See module docs.
pub struct NaiveBfast {
    pub params: BfastParams,
}

impl NaiveBfast {
    pub fn new(params: BfastParams) -> Self {
        Self { params }
    }

    /// Analyse a single series (allocates everything, every call).
    pub fn run_pixel(&self, t: &[f64], y: &[f64]) -> crate::error::Result<PixelResult> {
        let p = &self.params;
        // 1. design matrix — rebuilt per pixel (R behaviour)
        let x = design::design_matrix(t, p.freq, p.k);
        // 2. Gram + inverse — re-factorised per pixel
        let xh = crate::linalg::Mat::from_fn(p.p(), p.n_hist, |i, j| x[(i, j)]);
        let g = xh.matmul_nt(&xh)?;
        let ginv = g.inverse()?; // explicit inverse, as in Eq. (6)
        let m = ginv.matmul(&xh)?;
        // 3. fit + predict
        let beta = m.matvec(&y[..p.n_hist])?;
        let yhat = x.transpose().matvec(&beta)?;
        // 4. residuals / MOSUM / scan
        let r: Vec<f64> = y.iter().zip(&yhat).map(|(a, b)| a - b).collect();
        let mo = mosum::mosum_process(&r, p);
        let bound = mosum::boundary(p); // recomputed per pixel, naively
        let scan = mosum::scan_breaks(&mo, &bound);
        Ok(PixelResult { scan, mosum: mo })
    }

    /// Analyse a whole stack sequentially (single-threaded, like R).
    pub fn run(&self, stack: &TimeStack) -> crate::error::Result<BreakMap> {
        let m = stack.n_pixels();
        let mut out = BreakMap::with_capacity(m);
        for px in 0..m {
            let y = stack.series_f64(px);
            let res = self.run_pixel(&stack.time_axis, &y)?;
            out.breaks.push(res.scan.has_break as i32);
            out.first.push(res.scan.first);
            out.momax.push(res.scan.momax as f32);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::ArtificialDataset;

    #[test]
    fn detects_injected_breaks() {
        // lambda well above the finite-sample 5% quantile (trend
        // extrapolation inflates MOSUM drift; see lambda::tests) so
        // clean pixels stay clean while 100x-sigma shifts still flag.
        let p = BfastParams::with_lambda(60, 40, 20, 2, 12.0, 0.05, 6.0).unwrap();
        let data = ArtificialDataset::new(p.clone(), 20, 1)
            .with_noise(0.005, 0.5)
            .generate();
        let map = NaiveBfast::new(p).run(&data.stack).unwrap();
        let (tpr, fpr) = data.score(&map.breaks);
        assert_eq!(tpr, 1.0, "all injected breaks found");
        assert!(fpr < 0.2, "fpr {fpr}");
        // first-crossing indices of detected pixels are in range
        for (i, &b) in map.breaks.iter().enumerate() {
            if b != 0 {
                assert!(map.first[i] >= 0 && (map.first[i] as usize) < 20);
            }
        }
    }

    #[test]
    fn momax_positive() {
        let p = BfastParams::with_lambda(60, 40, 20, 2, 12.0, 0.05, 2.5).unwrap();
        let data = ArtificialDataset::new(p.clone(), 4, 2).generate();
        let map = NaiveBfast::new(p).run(&data.stack).unwrap();
        assert!(map.momax.iter().all(|&v| v > 0.0));
    }
}
