//! The BFAST(Python) analogue: Algorithm 1 per pixel, with the
//! design-side quantities (X, M = (X_h X_hᵀ)⁻¹X_h, boundary) computed
//! once and reused — what a straightforward numpy port does. Still a
//! per-pixel loop; no cross-pixel batching of the matmuls.

use crate::design;
use crate::linalg::Mat;
use crate::mosum;
use crate::params::BfastParams;
use crate::raster::{BreakMap, TimeStack};

use super::PixelResult;

/// Shared-precomputation, per-pixel-loop BFAST. See module docs.
pub struct DirectBfast {
    pub params: BfastParams,
    x: Mat,
    xt: Mat,
    m: Mat,
    bound: Vec<f64>,
}

impl DirectBfast {
    /// Precompute X, M and the boundary for a given time axis.
    pub fn new(params: BfastParams, time_axis: &[f64]) -> crate::error::Result<Self> {
        crate::ensure!(
            time_axis.len() == params.n_total,
            "time axis length {} != N {}",
            time_axis.len(),
            params.n_total
        );
        let x = design::design_matrix(time_axis, params.freq, params.k);
        let m = design::history_pinv(&x, params.n_hist)?;
        let bound = mosum::boundary(&params);
        Ok(Self { xt: x.transpose(), x, m, params, bound })
    }

    /// Analyse one series, reusing the precomputed design quantities.
    pub fn run_pixel(&self, y: &[f64]) -> crate::error::Result<PixelResult> {
        let p = &self.params;
        let beta = self.m.matvec(&y[..p.n_hist])?;
        let yhat = self.xt.matvec(&beta)?;
        let r: Vec<f64> = y.iter().zip(&yhat).map(|(a, b)| a - b).collect();
        let mo = mosum::mosum_process(&r, p);
        let scan = mosum::scan_breaks(&mo, &self.bound);
        Ok(PixelResult { scan, mosum: mo })
    }

    /// Fitted coefficients for one pixel (analysis/debug API — the
    /// paper's "perform the analysis on the CPU for these specific
    /// time series after learning where the breaks are").
    pub fn fit_pixel(&self, y: &[f64]) -> crate::error::Result<Vec<f64>> {
        self.m.matvec(&y[..self.params.n_hist])
    }

    /// Full predictions for one pixel.
    pub fn predict_pixel(&self, beta: &[f64]) -> crate::error::Result<Vec<f64>> {
        self.xt.matvec(beta)
    }

    pub fn design(&self) -> &Mat {
        &self.x
    }

    /// Analyse a whole stack (single-threaded per-pixel loop).
    pub fn run(&self, stack: &TimeStack) -> crate::error::Result<BreakMap> {
        let m = stack.n_pixels();
        let mut out = BreakMap::with_capacity(m);
        for px in 0..m {
            let y = stack.series_f64(px);
            let res = self.run_pixel(&y)?;
            out.breaks.push(res.scan.has_break as i32);
            out.first.push(res.scan.first);
            out.momax.push(res.scan.momax as f32);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pixel::NaiveBfast;
    use crate::synth::ArtificialDataset;

    #[test]
    fn agrees_with_naive_exactly() {
        let p = BfastParams::with_lambda(60, 40, 20, 2, 12.0, 0.05, 2.5).unwrap();
        let data = ArtificialDataset::new(p.clone(), 16, 3).generate();
        let naive = NaiveBfast::new(p.clone()).run(&data.stack).unwrap();
        let direct = DirectBfast::new(p, &data.stack.time_axis)
            .unwrap()
            .run(&data.stack)
            .unwrap();
        assert_eq!(naive.breaks, direct.breaks);
        assert_eq!(naive.first, direct.first);
        for (a, b) in naive.momax.iter().zip(&direct.momax) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn fit_predict_roundtrip_on_clean_signal() {
        // A pure season-trend signal must be reproduced ~exactly.
        let p = BfastParams::with_lambda(60, 40, 20, 1, 12.0, 0.05, 2.5).unwrap();
        let t = design::regular_time_axis(60);
        let d = DirectBfast::new(p, &t).unwrap();
        let y: Vec<f64> = t
            .iter()
            .map(|&tt| {
                0.3 + 0.01 * tt / 12.0
                    + 0.2 * (2.0 * std::f64::consts::PI * tt / 12.0).sin()
            })
            .collect();
        let beta = d.fit_pixel(&y).unwrap();
        let yhat = d.predict_pixel(&beta).unwrap();
        for (a, b) in y.iter().zip(&yhat) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn rejects_mismatched_axis() {
        let p = BfastParams::with_lambda(60, 40, 20, 2, 12.0, 0.05, 2.5).unwrap();
        assert!(DirectBfast::new(p, &[1.0, 2.0]).is_err());
    }
}
