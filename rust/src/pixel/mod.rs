//! Per-pixel reference implementations — the paper's two slow
//! baselines.
//!
//! * [`naive`] — the BFAST(R) analogue: every pixel rebuilds the
//!   design matrix, re-factorises the Gram matrix, and allocates
//!   afresh, the way the general-purpose R implementation behaves.
//! * [`direct`] — the BFAST(Python) analogue: Algorithm 1 run per
//!   pixel, but the design matrix and pseudo-inverse are reused
//!   across pixels (what a straightforward numpy port does).
//!
//! Both produce exactly the same statistics as the fused CPU and
//! device implementations (cross-checked in tests); they exist to
//! reproduce the runtime orderings of Fig. 2.

pub mod direct;
pub mod naive;

pub use direct::DirectBfast;
pub use naive::NaiveBfast;

use crate::mosum::BreakScan;

/// Per-pixel result of any single-series implementation.
#[derive(Clone, Debug)]
pub struct PixelResult {
    pub scan: BreakScan,
    /// Full MOSUM process (kept by the per-pixel baselines; the
    /// device path only returns the scan, as in the paper).
    pub mosum: Vec<f64>,
}
