//! Standard base64 (with padding) — the binary-payload transport of
//! the `bfast::api` wire forms (inline `.bsq` scenes, f32 layers) and
//! the serving layer's JSON ingest. Lives below both so the front
//! door does not depend on the HTTP substrate
//! (`serve::http` re-exports these for compatibility).

use crate::error::{bail, ensure, Result};

const B64: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Standard base64 (with padding).
pub fn base64_encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = u32::from_be_bytes([0, b[0], b[1], b[2]]);
        out.push(B64[(n >> 18) as usize & 63] as char);
        out.push(B64[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { B64[(n >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { B64[n as usize & 63] as char } else { '=' });
    }
    out
}

/// Inverse of [`base64_encode`]; whitespace is ignored.
pub fn base64_decode(text: &str) -> Result<Vec<u8>> {
    fn val(c: u8) -> Result<u32> {
        Ok(match c {
            b'A'..=b'Z' => (c - b'A') as u32,
            b'a'..=b'z' => (c - b'a' + 26) as u32,
            b'0'..=b'9' => (c - b'0' + 52) as u32,
            b'+' => 62,
            b'/' => 63,
            other => bail!("invalid base64 byte {other:#04x}"),
        })
    }
    let bytes: Vec<u8> = text.bytes().filter(|b| !b.is_ascii_whitespace()).collect();
    ensure!(bytes.len() % 4 == 0, "base64 length {} is not a multiple of 4", bytes.len());
    let groups = bytes.len() / 4;
    let mut out = Vec::with_capacity(groups * 3);
    for (i, chunk) in bytes.chunks_exact(4).enumerate() {
        let pads = chunk.iter().rev().take_while(|&&c| c == b'=').count();
        ensure!(pads <= 2, "too much base64 padding");
        ensure!(pads == 0 || i == groups - 1, "misplaced base64 padding");
        ensure!(
            !chunk[..4 - pads].contains(&b'='),
            "misplaced base64 padding"
        );
        let mut n = 0u32;
        for &c in &chunk[..4 - pads] {
            n = (n << 6) | val(c)?;
        }
        n <<= 6 * pads as u32;
        let b = n.to_be_bytes();
        out.push(b[1]);
        if pads < 2 {
            out.push(b[2]);
        }
        if pads < 1 {
            out.push(b[3]);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base64_roundtrip_all_lengths() {
        for len in 0..40usize {
            let data: Vec<u8> =
                (0..len as u8).map(|b| b.wrapping_mul(37).wrapping_add(5)).collect();
            let enc = base64_encode(&data);
            assert_eq!(enc.len() % 4, 0);
            assert_eq!(base64_decode(&enc).unwrap(), data, "len {len}");
        }
        assert_eq!(base64_encode(b"Man"), "TWFu");
        assert_eq!(base64_encode(b"Ma"), "TWE=");
        assert_eq!(base64_decode("TWE=").unwrap(), b"Ma");
        for bad in ["TQ", "====", "T===", "=AAA", "TW=u", "T!Fu"] {
            assert!(base64_decode(bad).is_err(), "{bad:?}");
        }
    }
}
