//! `bfast gateway` — the resident fleet coordinator: one `/v1` facade
//! over many `bfast serve` workers.
//!
//! The one-shot [`crate::shard`] coordinator proved the mechanics
//! (bit-exact split/merge, aggregate progress, cancel fan-out); this
//! layer makes the fleet a *service*. A gateway process is a drop-in
//! replacement for a single `bfast serve` endpoint — same
//! `POST /v1/runs` / poll / `/result` protocol, same error envelopes —
//! except that behind the facade every run is split across the live
//! worker fleet and survives workers dying mid-run:
//!
//! * **Registration + heartbeat** — workers announce themselves with
//!   `POST /v1/workers` (`bfast serve --gateway` self-registers on an
//!   interval); a worker whose beats stop is *stale*, and one that
//!   fails a placement or health probe is *down*. Statically seeded
//!   workers (`--workers`) are health-probed by the sweep instead.
//! * **Throughput-weighted placement** — the sweep scrapes each live
//!   worker's `/metrics` for `bfast_chunks_done_total` and maintains a
//!   chunks/sec EMA; shard widths are apportioned ∝ that rate
//!   ([`crate::shard::split_weighted`]), so a 4× faster worker gets a
//!   4× wider pixel strip. Workers without an observation yet get an
//!   average-sized strip; `POST /v1/workers` can pin an explicit
//!   `weight` instead.
//! * **Mid-run rebalancing** — a shard whose worker dies mid-run
//!   ([`PlaceError::WorkerDown`]) is not retried whole at a static
//!   slot: the worker is marked down and the shard's pixel range is
//!   **re-split across the surviving fleet** (recursively, up to
//!   `--max-resplits`), so the work redistributes at the same
//!   throughput-weighted proportions as the original placement.
//!   `bfast_gateway_rebalances_total` counts these events.
//! * **Bit-exactness** — however many times a run is re-split, the
//!   merged map equals a single-process
//!   [`BfastRunner::run`](crate::coordinator::BfastRunner::run)
//!   bit-for-bit ([`PartialResult`] association), pinned over real
//!   sockets — including deterministic worker murder via
//!   [`chaos::ChaosProxy`] — by `tests/gateway.rs` and
//!   `tests/chaos.rs`.
//! * **Content-addressed result cache** — `POST /v1/runs` hashes the
//!   request ([`AnalysisRequest::request_digest`]) and answers an
//!   identical resubmission from the cache with **zero worker
//!   traffic**: the job record is born `Done` (marked `cached`) and
//!   `/result` serves the byte-identical envelope, `ETag`'d by the
//!   digest. `--cache-cap-mb 0` disables it; `DELETE /v1/cache`
//!   invalidates at runtime.
//!
//! Monitor sessions don't partition by pixel (their state lives where
//! the history was fitted), so `/v1/sessions` routes are proxied: the
//! gateway picks the least-loaded live worker at create, remembers the
//! owner, and forwards every later session request to it.

pub mod chaos;

use crate::api::{
    self, AnalysisRequest, AnalysisResult, ChunkSpec, EngineSpec, JobHandle, ParamSpec,
    PartialResult,
};
use crate::cli::{Command, Matches};
use crate::error::{ensure, err, Context, Result};
use crate::json::Value;
use crate::metrics::{self, Histogram, PhaseTimes};
use crate::raster::TimeStack;
use crate::report;
use crate::serve::http::{self, Client, Request, Response};
use crate::serve::queue::JobState;
use crate::shard::{self, PlaceError, PlaceOptions, ShardReport};
use crate::store::ResultCache;
use crate::threadpool::{self, WorkerPool};
use crate::trace::{self, Recorder, SpanHandle};
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Cap on requests served over one keep-alive connection (same bound
/// as the worker-side server).
const MAX_REQUESTS_PER_CONN: usize = 1024;

/// The backoff hint an over-admitted gateway advertises (parity with
/// the worker's 429).
const RETRY_AFTER_S: u64 = 1;

/// Gateway configuration (`bfast gateway` flags).
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Listen address (`host:port`; port 0 picks an ephemeral port).
    pub addr: String,
    /// Statically seeded workers (health-probed by the sweep);
    /// dynamic workers join via `POST /v1/workers` at any time.
    pub workers: Vec<String>,
    /// HTTP worker threads (0 = auto).
    pub http_threads: usize,
    /// Largest accepted request body, in bytes.
    pub max_body: usize,
    /// Per-shard worker job poll interval.
    pub poll: Duration,
    /// Per-I/O timeout on worker sockets — bounds how long a
    /// black-holed worker can stall a shard before it rebalances.
    pub io_timeout: Duration,
    /// A worker whose last beat is older than this is stale (not
    /// placed on) until it beats again.
    pub heartbeat_timeout: Duration,
    /// Health sweep + throughput scrape interval.
    pub sweep: Duration,
    /// Bounded 429-backoff tries per shard submit.
    pub submit_attempts: usize,
    /// Re-split budget per pixel range: how many times one range may
    /// be rebalanced onto survivors before the run fails.
    pub max_resplits: usize,
    /// Concurrent runs admitted before `POST /v1/runs` answers 429.
    pub max_inflight: usize,
    /// Finished run records retained for status/map queries.
    pub finished_cap: usize,
    /// Content-addressed result cache capacity in bytes (0 disables):
    /// an identical resubmission is answered gateway-side with **zero
    /// worker traffic**.
    pub cache_cap: usize,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7979".into(),
            workers: Vec::new(),
            http_threads: 0,
            max_body: 256 << 20,
            poll: Duration::from_millis(25),
            io_timeout: Duration::from_secs(10),
            heartbeat_timeout: Duration::from_secs(5),
            sweep: Duration::from_secs(1),
            submit_attempts: 8,
            max_resplits: 4,
            max_inflight: 8,
            finished_cap: 256,
            cache_cap: 64 << 20,
        }
    }
}

// -- the fleet registry --------------------------------------------------

/// Public snapshot of one registered worker (the `GET /v1/workers` /
/// [`report::workers_table`] row).
#[derive(Clone, Debug)]
pub struct WorkerInfo {
    pub addr: String,
    /// Eligible for placement: not down, beaten recently.
    pub alive: bool,
    /// Explicitly marked dead (failed placement or health probe).
    pub down: bool,
    /// Seeded via `--workers` (health-probed) rather than
    /// self-registered (heartbeating).
    pub is_static: bool,
    /// Effective placement weight (pinned, or the observed rate).
    pub weight: f64,
    /// Observed throughput EMA, chunks/sec (0 = no observation yet).
    pub rate: f64,
    /// Heartbeats received (probe successes count for statics).
    pub beats: u64,
    /// Time since the last beat.
    pub last_beat: Duration,
}

impl WorkerInfo {
    pub fn status(&self) -> &'static str {
        if self.alive {
            "alive"
        } else if self.down {
            "down"
        } else {
            "stale"
        }
    }
}

struct WorkerEntry {
    last_beat: Instant,
    down: bool,
    is_static: bool,
    pinned_weight: Option<f64>,
    /// Chunks/sec EMA from `/metrics` scrapes (0 = never observed).
    rate: f64,
    /// Last scraped (chunks_done_total, when) for rate deltas.
    last_scrape: Option<(u64, Instant)>,
    beats: u64,
}

impl WorkerEntry {
    fn new(is_static: bool) -> Self {
        Self {
            last_beat: Instant::now(),
            down: false,
            is_static,
            pinned_weight: None,
            rate: 0.0,
            last_scrape: None,
            beats: 0,
        }
    }

    fn alive(&self, timeout: Duration) -> bool {
        !self.down && self.last_beat.elapsed() <= timeout
    }

    /// Placement weight: an operator-pinned weight wins; otherwise the
    /// observed rate (0.0 = "unknown", which [`shard::split_weighted`]
    /// replaces with the fleet average).
    fn weight(&self) -> f64 {
        self.pinned_weight.unwrap_or(self.rate)
    }
}

/// Who is in the fleet and how healthy/fast each member is.
struct Fleet {
    timeout: Duration,
    workers: Mutex<BTreeMap<String, WorkerEntry>>,
    heartbeats: AtomicU64,
}

impl Fleet {
    fn new(timeout: Duration) -> Self {
        Self { timeout, workers: Mutex::new(BTreeMap::new()), heartbeats: AtomicU64::new(0) }
    }

    /// Seed a static worker (grace of one timeout before its first
    /// probe result is in).
    fn seed(&self, addr: &str) {
        self.workers
            .lock()
            .unwrap()
            .entry(addr.to_string())
            .or_insert_with(|| WorkerEntry::new(true));
    }

    /// A heartbeat (`POST /v1/workers`, or a static's probe success):
    /// refreshes liveness and *clears* a down mark — recovered workers
    /// rejoin the fleet on their next beat.
    fn beat(&self, addr: &str, weight: Option<f64>) {
        let mut ws = self.workers.lock().unwrap();
        let e = ws.entry(addr.to_string()).or_insert_with(|| WorkerEntry::new(false));
        e.last_beat = Instant::now();
        e.down = false;
        e.beats += 1;
        if let Some(w) = weight {
            e.pinned_weight = Some(w);
        }
        self.heartbeats.fetch_add(1, Ordering::Relaxed);
    }

    fn mark_down(&self, addr: &str) {
        if let Some(e) = self.workers.lock().unwrap().get_mut(addr) {
            e.down = true;
        }
    }

    fn remove(&self, addr: &str) -> bool {
        self.workers.lock().unwrap().remove(addr).is_some()
    }

    fn is_alive(&self, addr: &str) -> bool {
        self.workers
            .lock()
            .unwrap()
            .get(addr)
            .is_some_and(|e| e.alive(self.timeout))
    }

    /// `(addr, weight)` of every placeable worker, address-ordered
    /// (deterministic placement for a given fleet state).
    fn placement(&self) -> Vec<(String, f64)> {
        self.workers
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, e)| e.alive(self.timeout))
            .map(|(a, e)| (a.clone(), e.weight()))
            .collect()
    }

    fn statics(&self) -> Vec<String> {
        self.workers
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, e)| e.is_static)
            .map(|(a, _)| a.clone())
            .collect()
    }

    fn alive_addrs(&self) -> Vec<String> {
        self.workers
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, e)| e.alive(self.timeout))
            .map(|(a, _)| a.clone())
            .collect()
    }

    /// Fold a scraped cumulative chunk counter into the worker's
    /// chunks/sec EMA. Only *positive* deltas update the rate: an idle
    /// worker keeps its last known speed (decaying an idle worker to
    /// zero would starve the fastest machine of its next shard). A
    /// counter that went backwards (worker restart) just re-anchors.
    fn observe_chunks(&self, addr: &str, chunks: u64, now: Instant) {
        let mut ws = self.workers.lock().unwrap();
        if let Some(e) = ws.get_mut(addr) {
            if let Some((prev, at)) = e.last_scrape {
                let dt = now.duration_since(at).as_secs_f64();
                if dt > 0.0 && chunks > prev {
                    let sample = (chunks - prev) as f64 / dt;
                    e.rate = metrics::ema(e.rate, sample, 0.5);
                }
            }
            e.last_scrape = Some((chunks, now));
        }
    }

    fn snapshot(&self) -> Vec<WorkerInfo> {
        self.workers
            .lock()
            .unwrap()
            .iter()
            .map(|(a, e)| WorkerInfo {
                addr: a.clone(),
                alive: e.alive(self.timeout),
                down: e.down,
                is_static: e.is_static,
                weight: e.weight(),
                rate: e.rate,
                beats: e.beats,
                last_beat: e.last_beat.elapsed(),
            })
            .collect()
    }

    fn counts(&self) -> (usize, usize) {
        let ws = self.workers.lock().unwrap();
        let alive = ws.values().filter(|e| e.alive(self.timeout)).count();
        (ws.len(), alive)
    }
}

// -- gateway state + jobs ------------------------------------------------

/// One worker placement of a (sub-)shard, as observed at submit time
/// (202 from the worker) — recorded even when the placement later
/// fails, so the distributed trace can fetch the orphaned worker job's
/// spans after the worker recovers.
#[derive(Clone)]
struct PlacedShard {
    /// Worker address the gateway submitted to.
    worker: String,
    /// The worker-side job id.
    job: u64,
    /// The gateway shard span this placement ran under (0 = tracing
    /// off); worker trace roots are re-parented beneath it on merge.
    span_id: u64,
}

struct GwJob {
    id: u64,
    state: JobState,
    handle: JobHandle,
    /// Request id minted (or propagated) at `POST /v1/runs`.
    request_id: String,
    /// Content digest of the request (cache key + result `ETag`).
    digest: Option<String>,
    /// Answered from the result cache: born `Done`, zero worker
    /// traffic.
    cached: bool,
    /// Gateway-side flight recorder (`None` = tracing disabled).
    recorder: Option<Recorder>,
    /// Every worker placement this run made, in submit order (shared
    /// with the run thread; the trace endpoint reads it to stitch the
    /// distributed trace).
    placements: Arc<Mutex<Vec<PlacedShard>>>,
    submitted_at: Instant,
    pixels: Option<usize>,
    result: Option<AnalysisResult>,
    shards: Vec<ShardReport>,
    finished_at: Option<Instant>,
}

impl GwJob {
    fn progress(&self) -> f64 {
        match &self.state {
            JobState::Queued => 0.0,
            JobState::Done => 1.0,
            _ => {
                let (done, total) = self.handle.progress();
                if total == 0 {
                    0.0
                } else {
                    done as f64 / total as f64
                }
            }
        }
    }
}

struct Jobs {
    next: u64,
    map: BTreeMap<u64, GwJob>,
}

struct GatewayState {
    addr: SocketAddr,
    cfg: GatewayConfig,
    fleet: Fleet,
    cache: Arc<ResultCache>,
    jobs: Mutex<Jobs>,
    /// Session name → owning worker address.
    sessions: Mutex<BTreeMap<String, String>>,
    run_threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
    phases: Mutex<PhaseTimes>,
    /// Seconds from run submission to a terminal state.
    run_latency: Histogram,
    rebalances: AtomicU64,
    submitted: AtomicU64,
    rejected: AtomicU64,
    requests: AtomicU64,
    errors: AtomicU64,
    started: Instant,
    shutdown: AtomicBool,
}

impl GatewayState {
    fn inflight(&self) -> usize {
        self.jobs
            .lock()
            .unwrap()
            .map
            .values()
            .filter(|j| !j.state.is_finished())
            .count()
    }
}

/// Per-run progress: each in-flight pixel range reports its worker's
/// `(chunks_done, chunks_total)` here; the sum streams into the run's
/// aggregate [`JobHandle`]. Ranges come and go as rebalances re-split
/// the work, so totals may move — fine for a progress bar, and the
/// final publish (everything done) is exact.
struct RunProgress {
    cells: Mutex<BTreeMap<(usize, usize), (usize, usize)>>,
}

impl RunProgress {
    fn new() -> Self {
        Self { cells: Mutex::new(BTreeMap::new()) }
    }

    fn set(&self, range: (usize, usize), done: usize, total: usize) {
        self.cells.lock().unwrap().insert(range, (done, total));
    }

    fn clear(&self, range: (usize, usize)) {
        self.cells.lock().unwrap().remove(&range);
    }

    fn publish(&self, handle: &JobHandle) {
        let cells = self.cells.lock().unwrap();
        let done = cells.values().map(|c| c.0).sum();
        let total = cells.values().map(|c| c.1).sum();
        drop(cells);
        handle.set_progress(done, total);
    }
}

// -- the run engine: weighted fan-out with recursive rebalancing ---------

struct RunCtx<'a> {
    state: &'a GatewayState,
    stack: &'a TimeStack,
    params: ParamSpec,
    engine: &'a EngineSpec,
    chunking: &'a ChunkSpec,
    handle: &'a JobHandle,
    progress: &'a RunProgress,
    acc: &'a Mutex<Vec<(PartialResult, ShardReport)>>,
    popts: PlaceOptions,
    /// The run's request id, propagated to every worker placement as
    /// `X-Request-Id`.
    request_id: &'a str,
    /// Worker placements observed at submit time (shared with the
    /// job record; see [`PlacedShard`]).
    placements: &'a Arc<Mutex<Vec<PlacedShard>>>,
}

/// Execute one request across the live fleet; the returned result is
/// bit-identical to a single-process run of the same request.
fn drive_run(
    state: &GatewayState,
    req: &AnalysisRequest,
    handle: &JobHandle,
    request_id: &str,
    placements: &Arc<Mutex<Vec<PlacedShard>>>,
) -> Result<(AnalysisResult, Vec<ShardReport>)> {
    let (stack, params) = req.resolve()?;
    let pixels = stack.n_pixels();
    ensure!(pixels > 0, "scene has no pixels");
    // pin every parameter (λ included) gateway-side, so every shard —
    // and every rebalanced re-placement — analyses under identical
    // numbers
    let pinned = ParamSpec::from_params(&params);
    let progress = RunProgress::new();
    let acc = Mutex::new(Vec::new());
    let mut popts = PlaceOptions {
        poll: state.cfg.poll,
        submit_attempts: state.cfg.submit_attempts,
        io_timeout: state.cfg.io_timeout,
        request_id: None,
        on_submit: None,
    };
    popts.request_id = Some(request_id.to_string());
    let ctx = RunCtx {
        state,
        stack: &stack,
        // (resolve returns Cow<TimeStack>; &*cow is the strip itself)
        params: pinned,
        engine: &req.engine,
        chunking: &req.chunking,
        handle,
        progress: &progress,
        acc: &acc,
        popts,
        request_id,
        placements,
    };
    // the run root span lives on this thread (opened by run_job);
    // shard spans open under it via the handle inside scoped threads
    let root = trace::current_handle();
    drive_range(&ctx, (0, pixels), 0, &root)?;
    let mut entries = acc.into_inner().unwrap();
    entries.sort_by_key(|(_, rep)| rep.pixel_range.0);
    for (i, (_, rep)) in entries.iter_mut().enumerate() {
        rep.shard = i; // shard ids = final pixel order, not spawn order
    }
    let (parts, reports): (Vec<_>, Vec<_>) = entries.into_iter().unzip();
    let result = PartialResult::assemble(parts)?.into_full(pixels, stack.width, stack.height)?;
    Ok((result, reports))
}

/// Place `range` across the currently-live fleet, splitting it by
/// observed throughput. Each sub-range that loses its worker mid-run
/// recurses (depth-bounded) over whatever fleet is alive *then*.
/// `parent` is the span the new shard spans open under: the run root
/// at depth 0, the failed shard's span on a rebalance (so retries are
/// visibly parented under the placement they replace).
fn drive_range(
    ctx: &RunCtx<'_>,
    range: (usize, usize),
    depth: usize,
    parent: &Option<SpanHandle>,
) -> Result<()> {
    if ctx.handle.is_cancelled() {
        return Err(api::cancelled());
    }
    let placement = ctx.state.fleet.placement();
    // bounded, typed refusal — a fleet with no live workers must fail
    // the run promptly, never hang it
    ensure!(
        !placement.is_empty(),
        "no live workers to place pixels [{}, {}) on — register workers \
         (POST /v1/workers) or wait for heartbeats",
        range.0,
        range.1
    );
    let weights: Vec<f64> = placement.iter().map(|(_, w)| *w).collect();
    let spans = shard::split_weighted(range.1 - range.0, &weights);
    let outcomes: Vec<Result<()>> = std::thread::scope(|scope| {
        let threads: Vec<_> = spans
            .iter()
            .zip(placement.iter())
            .filter(|(&(a, b), _)| a < b)
            .map(|(&(a, b), (worker, _))| {
                let sub = (range.0 + a, range.0 + b);
                scope.spawn(move || {
                    let span = trace::span_under(parent, "shard").map(|s| {
                        s.with_attr("worker", worker)
                            .with_attr("pixels_start", sub.0)
                            .with_attr("pixels_end", sub.1)
                            .with_attr("attempt", depth + 1)
                    });
                    drive_sub(ctx, worker, sub, depth, span)
                })
            })
            .collect();
        threads
            .into_iter()
            .map(|t| {
                t.join()
                    .unwrap_or_else(|_| Err(err!("gateway shard thread panicked")))
            })
            .collect()
    });
    let mut cancelled = ctx.handle.is_cancelled();
    let mut first_err = None;
    for outcome in outcomes {
        match outcome {
            Ok(()) => {}
            Err(e) if api::is_cancelled(&e) => cancelled = true,
            Err(e) => {
                first_err.get_or_insert(e);
            }
        }
    }
    if cancelled {
        return Err(api::cancelled());
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Drive one contiguous sub-range on one worker. A dead worker
/// ([`PlaceError::WorkerDown`]) is marked down and the range re-split
/// across the survivors; a job-side failure fails the run. `span` is
/// this placement's shard span — on a rebalance the replacement shard
/// spans open under it.
fn drive_sub(
    ctx: &RunCtx<'_>,
    worker: &str,
    range: (usize, usize),
    depth: usize,
    span: Option<trace::Span>,
) -> Result<()> {
    // ship only this range's pixel strip (see run_one_shard in
    // crate::shard for why slicing here is bit-equivalent), encoded
    // straight from the scene buffer — no intermediate sliced stack,
    // so an N-way fan-out holds one body per shard, not a stack copy
    // plus a body each. The request id travels as X-Request-Id
    // (PlaceOptions), keeping the shipped body canonical.
    let body =
        api::slice_request_body(ctx.stack, range, &ctx.params, ctx.engine, ctx.chunking, None);
    let progress = |done: usize, total: usize| {
        ctx.progress.set(range, done, total);
        ctx.progress.publish(ctx.handle);
    };
    // record every worker-side job id the moment the worker 202s, even
    // if this placement later dies — the trace endpoint needs orphaned
    // jobs too
    let mut popts = ctx.popts.clone();
    {
        let placements = Arc::clone(ctx.placements);
        let worker_owned = worker.to_string();
        let span_id = span.as_ref().map(|s| s.id()).unwrap_or(0);
        popts.on_submit = Some(Arc::new(move |job| {
            placements.lock().unwrap().push(PlacedShard {
                worker: worker_owned.clone(),
                job,
                span_id,
            });
        }));
    }
    match shard::place_on_worker(worker, &body, range, &popts, ctx.handle, &progress) {
        Ok(p) => {
            ctx.acc.lock().unwrap().push((
                p.partial,
                ShardReport {
                    shard: 0, // renumbered after assembly
                    pixel_range: range,
                    worker: worker.to_string(),
                    attempts: depth + 1,
                    chunks: p.chunks,
                    wall: p.wall,
                },
            ));
            Ok(())
        }
        Err(e) if e.is_cancelled() => Err(e.into_inner()),
        Err(PlaceError::Job(e)) => {
            Err(e.push_context(format!("pixels [{}, {}) on {worker}", range.0, range.1)))
        }
        Err(PlaceError::WorkerDown(e)) => {
            // the rebalance: bury the worker, return this range's
            // progress to zero, and re-split it over the survivors
            ctx.state.fleet.mark_down(worker);
            ctx.state.rebalances.fetch_add(1, Ordering::Relaxed);
            ctx.progress.clear(range);
            ctx.progress.publish(ctx.handle);
            trace::log!(
                Warn,
                "gateway",
                "worker_down",
                "worker" => worker,
                "request_id" => ctx.request_id,
                "pixels_start" => range.0,
                "pixels_end" => range.1,
                "error" => format!("{e:#}"),
            );
            ensure!(
                depth < ctx.state.cfg.max_resplits,
                "pixels [{}, {}): re-split budget ({}) exhausted — last worker {worker}: {e:#}",
                range.0,
                range.1,
                ctx.state.cfg.max_resplits
            );
            // close the failed placement's span (its duration = time
            // to detect the death) but keep its identity: replacement
            // shards parent under it
            let retry_parent = span.as_ref().map(|s| s.handle());
            drop(span);
            drive_range(ctx, range, depth + 1, &retry_parent)
        }
    }
}

/// The detached run thread: drive the fan-out, record the outcome.
fn run_job(state: &Arc<GatewayState>, id: u64, req: AnalysisRequest, handle: JobHandle) {
    let (request_id, recorder, placements) = {
        let mut jobs = state.jobs.lock().unwrap();
        let Some(job) = jobs.map.get_mut(&id) else { return };
        job.state = JobState::Running;
        (job.request_id.clone(), job.recorder.clone(), Arc::clone(&job.placements))
    };
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // root of the gateway-side span tree; worker trees re-parent
        // under its shard children on trace merge. Dropped (flushed)
        // before the terminal state is published.
        let _run = recorder.as_ref().map(|r| {
            r.span("run").with_attr("job", id).with_attr("request_id", &request_id)
        });
        drive_run(state, &req, &handle, &request_id, &placements)
    }));
    // cache fill: serialise outside the jobs lock (envelopes are
    // scene-sized); the digest is immutable after submission
    let cache_fill = match &outcome {
        Ok(Ok((result, _))) if state.cache.enabled() => state
            .jobs
            .lock()
            .unwrap()
            .map
            .get(&id)
            .and_then(|j| j.digest.clone())
            .map(|d| (d, Arc::<str>::from(result.to_json_string()))),
        _ => None,
    };
    let mut jobs = state.jobs.lock().unwrap();
    let Some(job) = jobs.map.get_mut(&id) else { return };
    job.finished_at = Some(Instant::now());
    state.run_latency.observe(job.submitted_at.elapsed().as_secs_f64());
    match outcome {
        Ok(Ok((result, shards))) => {
            if let Some(p) = &result.phases {
                state.phases.lock().unwrap().merge(p);
            }
            trace::log!(
                Info,
                "gateway",
                "job_done",
                "job" => id,
                "request_id" => &request_id,
                "pixels" => result.map.len(),
                "shards" => shards.len(),
                "wall_s" => result.wall.as_secs_f64(),
            );
            if trace::level_enabled(trace::Level::Debug) {
                eprint!("{}", report::shard_table(&shards).to_console());
            }
            job.pixels = Some(result.map.len());
            job.result = Some(result);
            job.shards = shards;
            job.state = JobState::Done;
        }
        Ok(Err(e)) if api::is_cancelled(&e) => {
            trace::log!(
                Info,
                "gateway",
                "job_cancelled",
                "job" => id,
                "request_id" => &request_id,
            );
            job.state = JobState::Cancelled;
        }
        Ok(Err(e)) => {
            trace::log!(
                Warn,
                "gateway",
                "job_failed",
                "job" => id,
                "request_id" => &request_id,
                "error" => format!("{e:#}"),
            );
            job.state = JobState::Failed { error: format!("{e:#}") };
        }
        Err(_) => {
            trace::log!(
                Error,
                "gateway",
                "job_panicked",
                "job" => id,
                "request_id" => &request_id,
            );
            job.state = JobState::Failed { error: "gateway run panicked".into() };
        }
    }
    // count-capped retention, oldest finished first (ids ascend)
    let finished: Vec<u64> = jobs
        .map
        .iter()
        .filter(|(_, j)| j.state.is_finished())
        .map(|(&i, _)| i)
        .collect();
    if finished.len() > state.cfg.finished_cap.max(1) {
        for i in &finished[..finished.len() - state.cfg.finished_cap.max(1)] {
            jobs.map.remove(i);
        }
    }
    drop(jobs);
    if let Some((digest, body)) = cache_fill {
        state.cache.put(&digest, body);
    }
}

// -- the health sweep ----------------------------------------------------

/// One sweep pass: probe statics' `/healthz` (success = synthetic
/// beat, failure = down), then scrape every live worker's `/metrics`
/// for its cumulative chunk counter.
fn sweep_once(state: &GatewayState) {
    // probing can't outlast the heartbeat budget — a worker that can't
    // answer /healthz within it isn't meaningfully alive
    let io = state
        .cfg
        .io_timeout
        .min(state.cfg.heartbeat_timeout.max(Duration::from_millis(100)));
    for addr in state.fleet.statics() {
        let ok = Client::connect_timeout(&addr, io)
            .and_then(|mut c| c.request("GET", "/healthz", "", &[]))
            .map(|(status, _)| status == 200)
            .unwrap_or(false);
        if ok {
            state.fleet.beat(&addr, None);
        } else {
            state.fleet.mark_down(&addr);
        }
    }
    for addr in state.fleet.alive_addrs() {
        let scraped = Client::connect_timeout(&addr, io)
            .and_then(|mut c| c.request("GET", "/metrics", "", &[]))
            .ok()
            .filter(|(status, _)| *status == 200)
            .and_then(|(_, body)| scrape_counter(&body, "bfast_chunks_done_total"));
        if let Some(chunks) = scraped {
            state.fleet.observe_chunks(&addr, chunks, Instant::now());
        }
    }
}

/// Pull one integer-valued counter out of a Prometheus text page.
fn scrape_counter(body: &[u8], name: &str) -> Option<u64> {
    let text = std::str::from_utf8(body).ok()?;
    text.lines().find_map(|line| {
        let rest = line.strip_prefix(name)?;
        rest.trim().parse().ok()
    })
}

// -- the HTTP front door -------------------------------------------------

/// A running `bfast gateway` instance. [`Gateway::start`] returns once
/// the socket is listening; [`Gateway::wait`] blocks until
/// `POST /shutdown` (or [`Gateway::stop`]) and drains in-flight runs.
pub struct Gateway {
    addr: SocketAddr,
    state: Arc<GatewayState>,
    accept: std::thread::JoinHandle<()>,
    sweep: std::thread::JoinHandle<()>,
}

impl Gateway {
    pub fn start(cfg: GatewayConfig) -> Result<Gateway> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let http_threads = if cfg.http_threads == 0 {
            threadpool::default_threads().clamp(2, 16)
        } else {
            cfg.http_threads
        };
        let fleet = Fleet::new(cfg.heartbeat_timeout);
        for w in &cfg.workers {
            fleet.seed(w);
        }
        let cache = Arc::new(ResultCache::new(cfg.cache_cap));
        let state = Arc::new(GatewayState {
            addr,
            cfg,
            fleet,
            cache,
            jobs: Mutex::new(Jobs { next: 1, map: BTreeMap::new() }),
            sessions: Mutex::new(BTreeMap::new()),
            run_threads: Mutex::new(Vec::new()),
            phases: Mutex::new(PhaseTimes::new()),
            run_latency: Histogram::run_latency(),
            rebalances: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            started: Instant::now(),
            shutdown: AtomicBool::new(false),
        });
        let accept_state = Arc::clone(&state);
        let accept = std::thread::spawn(move || {
            let mut pool = WorkerPool::new(http_threads);
            for conn in listener.incoming() {
                if accept_state.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let st = Arc::clone(&accept_state);
                if pool.execute(move || handle_connection(stream, &st)).is_err() {
                    break;
                }
            }
            pool.shutdown();
        });
        let sweep_state = Arc::clone(&state);
        let sweep = std::thread::spawn(move || {
            let interval = sweep_state.cfg.sweep.max(Duration::from_millis(10));
            let tick = Duration::from_millis(25).min(interval);
            let mut next = Instant::now(); // first sweep immediately
            while !sweep_state.shutdown.load(Ordering::SeqCst) {
                if Instant::now() >= next {
                    sweep_once(&sweep_state);
                    next = Instant::now() + interval;
                }
                std::thread::sleep(tick);
            }
        });
        Ok(Gateway { addr, state, accept, sweep })
    }

    /// The bound address (resolves an ephemeral port request).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until shutdown, then drain: in-flight run threads are
    /// joined (each is I/O-bounded by `io_timeout`), the sweep stops.
    pub fn wait(self) -> Result<()> {
        self.accept
            .join()
            .map_err(|_| err!("gateway accept loop panicked"))?;
        self.sweep
            .join()
            .map_err(|_| err!("gateway sweep loop panicked"))?;
        loop {
            // take the lock only to pop, never across the join
            let Some(t) = self.state.run_threads.lock().unwrap().pop() else {
                break;
            };
            let _ = t.join();
        }
        Ok(())
    }

    /// Trigger a graceful shutdown and wait for it to complete.
    pub fn stop(self) -> Result<()> {
        trigger_shutdown(&self.state);
        self.wait()
    }
}

fn trigger_shutdown(state: &GatewayState) {
    state.shutdown.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(state.addr);
}

fn handle_connection(stream: TcpStream, state: &Arc<GatewayState>) {
    let _ = stream.set_nodelay(true);
    let mut reader = std::io::BufReader::new(stream);
    let mut served = 0usize;
    loop {
        let timeout = if served == 0 { Duration::from_secs(30) } else { Duration::from_secs(5) };
        let _ = reader.get_ref().set_read_timeout(Some(timeout));
        let req = match http::read_request(&mut reader, state.cfg.max_body) {
            Ok(Some(req)) => req,
            Ok(None) => break,
            Err(e) => {
                state.requests.fetch_add(1, Ordering::Relaxed);
                state.errors.fetch_add(1, Ordering::Relaxed);
                let _ = http::write_response(
                    reader.get_mut(),
                    &Response::json_error(400, &format!("{e:#}")),
                    false,
                );
                break;
            }
        };
        state.requests.fetch_add(1, Ordering::Relaxed);
        let resp = route(&req, state);
        if resp.status >= 400 {
            state.errors.fetch_add(1, Ordering::Relaxed);
        }
        served += 1;
        let keep = req.keep_alive()
            && served < MAX_REQUESTS_PER_CONN
            && !state.shutdown.load(Ordering::SeqCst);
        if http::write_response(reader.get_mut(), &resp, keep).is_err() {
            break;
        }
        if !keep {
            break;
        }
    }
}

fn route(req: &Request, state: &Arc<GatewayState>) -> Response {
    let path = req.path.clone();
    let segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => healthz(state),
        ("GET", ["metrics"]) => metrics_page(state),
        ("POST", ["shutdown"]) => {
            trigger_shutdown(state);
            Response::json(
                200,
                &Value::obj(vec![("status", Value::Str("shutting down".into()))]),
            )
        }
        ("POST", ["v1", "workers"]) => worker_register(req, state),
        ("GET", ["v1", "workers"]) => worker_list(state),
        ("DELETE", ["v1", "workers", addr]) => worker_remove(addr, state),
        ("POST", ["v1", "runs"]) => submit_run(req, state),
        ("GET", ["v1", "runs"]) => list_runs(state),
        ("GET", ["v1", "runs", id]) => run_status(id, state),
        ("DELETE", ["v1", "runs", id]) => cancel_run(id, state),
        ("GET", ["v1", "runs", id, "map"]) => run_map(req, id, state),
        ("GET", ["v1", "runs", id, "result"]) => run_result(req, id, state),
        ("GET", ["v1", "runs", id, "trace"]) => run_trace(id, state),
        ("GET", ["v1", "runs", id, "cmdstream"]) => run_cmdstream(id, state),
        ("GET", ["v1", "cache"]) => cache_stats(state),
        ("DELETE", ["v1", "cache"]) => cache_clear(state),
        ("GET", ["v1", "sessions"]) => list_sessions(state),
        ("POST", ["v1", "sessions", name]) => create_session(req, name, state),
        ("GET", ["v1", "sessions", name])
        | ("POST", ["v1", "sessions", name, "ingest"])
        | ("GET", ["v1", "sessions", name, "map"]) => proxy_session(req, name, state),
        (method, _) => Response::json_error(404, &format!("no route for {method} {}", req.path)),
    }
}

fn healthz(state: &GatewayState) -> Response {
    let (workers, alive) = state.fleet.counts();
    Response::json(
        200,
        &Value::obj(vec![
            ("status", Value::Str("ok".into())),
            ("role", Value::Str("gateway".into())),
            ("version", Value::Str(env!("CARGO_PKG_VERSION").into())),
            (
                "git_rev",
                Value::Str(option_env!("BFAST_GIT_REV").unwrap_or("unknown").into()),
            ),
            ("profile", Value::Str(metrics::build_profile().into())),
            ("uptime_s", Value::Num(state.started.elapsed().as_secs_f64())),
            ("workers", Value::Num(workers as f64)),
            ("workers_alive", Value::Num(alive as f64)),
            ("jobs_inflight", Value::Num(state.inflight() as f64)),
            ("sessions", Value::Num(state.sessions.lock().unwrap().len() as f64)),
        ]),
    )
}

fn metrics_page(state: &GatewayState) -> Response {
    use crate::metrics::{prom_header, prom_metric};
    use std::fmt::Write as _;
    let (workers, alive) = state.fleet.counts();
    let (mut done, mut failed, mut cancelled, mut inflight) = (0u64, 0u64, 0u64, 0u64);
    for j in state.jobs.lock().unwrap().map.values() {
        match &j.state {
            JobState::Done => done += 1,
            JobState::Failed { .. } => failed += 1,
            JobState::Cancelled => cancelled += 1,
            _ => inflight += 1,
        }
    }
    let mut out = String::new();
    metrics::prom_build_info(&mut out);
    prom_metric(
        &mut out,
        "gauge",
        "bfast_gateway_uptime_seconds",
        "seconds since this gateway started",
        state.started.elapsed().as_secs_f64(),
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_gateway_http_requests_total",
        "HTTP requests accepted",
        state.requests.load(Ordering::Relaxed) as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_gateway_http_errors_total",
        "HTTP responses with status >= 400",
        state.errors.load(Ordering::Relaxed) as f64,
    );
    prom_metric(
        &mut out,
        "gauge",
        "bfast_gateway_workers",
        "registered workers (any state)",
        workers as f64,
    );
    prom_metric(
        &mut out,
        "gauge",
        "bfast_gateway_workers_alive",
        "workers eligible for placement",
        alive as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_gateway_heartbeats_total",
        "worker heartbeats received (probe successes included)",
        state.fleet.heartbeats.load(Ordering::Relaxed) as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_gateway_rebalances_total",
        "mid-run shard re-splits after a worker death",
        state.rebalances.load(Ordering::Relaxed) as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_gateway_runs_submitted_total",
        "runs accepted at POST /v1/runs",
        state.submitted.load(Ordering::Relaxed) as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_gateway_runs_rejected_total",
        "runs refused by admission control (HTTP 429)",
        state.rejected.load(Ordering::Relaxed) as f64,
    );
    // per-state tallies are gauges over *retained* records (they
    // shrink under the finished-record cap)
    prom_metric(&mut out, "gauge", "bfast_gateway_runs_inflight", "runs not yet finished", inflight as f64);
    prom_metric(&mut out, "gauge", "bfast_gateway_runs_done", "retained completed runs", done as f64);
    prom_metric(&mut out, "gauge", "bfast_gateway_runs_failed", "retained failed runs", failed as f64);
    prom_metric(
        &mut out,
        "gauge",
        "bfast_gateway_runs_cancelled",
        "retained cancelled runs",
        cancelled as f64,
    );
    prom_metric(
        &mut out,
        "gauge",
        "bfast_gateway_sessions",
        "monitor sessions routed through this gateway",
        state.sessions.lock().unwrap().len() as f64,
    );
    let cache = state.cache.stats();
    prom_metric(
        &mut out,
        "counter",
        "bfast_cache_hits_total",
        "submissions answered from the result cache",
        cache.hits as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_cache_misses_total",
        "cache lookups that fell through to a fleet run",
        cache.misses as f64,
    );
    prom_metric(
        &mut out,
        "counter",
        "bfast_cache_evictions_total",
        "cached results evicted to stay under capacity",
        cache.evictions as f64,
    );
    prom_metric(
        &mut out,
        "gauge",
        "bfast_cache_bytes",
        "bytes of serialised results held by the cache",
        cache.bytes as f64,
    );
    state.run_latency.render(
        &mut out,
        "bfast_gateway_run_latency_seconds",
        "seconds from run submission to a terminal state",
    );
    let fleet = state.fleet.snapshot();
    prom_header(
        &mut out,
        "gauge",
        "bfast_gateway_worker_weight",
        "effective placement weight per worker",
    );
    for w in &fleet {
        let _ = writeln!(
            out,
            "bfast_gateway_worker_weight{{worker=\"{}\"}} {:.3}",
            w.addr, w.weight
        );
    }
    prom_header(
        &mut out,
        "gauge",
        "bfast_gateway_worker_chunks_per_s",
        "observed throughput EMA per worker",
    );
    for w in &fleet {
        let _ = writeln!(
            out,
            "bfast_gateway_worker_chunks_per_s{{worker=\"{}\"}} {:.3}",
            w.addr, w.rate
        );
    }
    prom_header(
        &mut out,
        "gauge",
        "bfast_gateway_run_phase_seconds",
        "engine phase seconds accumulated across completed runs",
    );
    out.push_str(
        &state
            .phases
            .lock()
            .unwrap()
            .to_prometheus("bfast_gateway_run_phase_seconds"),
    );
    Response::text(200, &out)
}

// -- worker endpoints ----------------------------------------------------

/// `POST /v1/workers` `{"addr": "host:port", "weight"?: w}` —
/// registration and heartbeat are the same idempotent call.
fn worker_register(req: &Request, state: &GatewayState) -> Response {
    let parsed = || -> Result<(String, Option<f64>)> {
        let v = crate::json::parse(
            std::str::from_utf8(&req.body).context("non-UTF-8 JSON body")?,
        )?;
        let addr = v.get("addr")?.as_str()?.trim().to_string();
        ensure!(!addr.is_empty(), "addr must be a non-empty host:port");
        let weight = match v.try_get("weight") {
            Some(w) => {
                let w = w.as_f64()?;
                ensure!(w.is_finite() && w > 0.0, "weight must be finite and positive");
                Some(w)
            }
            None => None,
        };
        Ok((addr, weight))
    };
    match parsed() {
        Ok((addr, weight)) => {
            state.fleet.beat(&addr, weight);
            let (workers, alive) = state.fleet.counts();
            Response::json(
                200,
                &Value::obj(vec![
                    ("addr", Value::Str(addr)),
                    ("status", Value::Str("ok".into())),
                    ("workers", Value::Num(workers as f64)),
                    ("workers_alive", Value::Num(alive as f64)),
                ]),
            )
        }
        Err(e) => Response::json_error(400, &format!("{e:#}")),
    }
}

fn worker_info_json(w: &WorkerInfo) -> Value {
    Value::obj(vec![
        ("addr", Value::Str(w.addr.clone())),
        ("status", Value::Str(w.status().into())),
        ("alive", Value::Bool(w.alive)),
        ("down", Value::Bool(w.down)),
        ("static", Value::Bool(w.is_static)),
        ("weight", Value::Num(w.weight)),
        ("rate_chunks_per_s", Value::Num(w.rate)),
        ("beats", Value::Num(w.beats as f64)),
        ("last_beat_s", Value::Num(w.last_beat.as_secs_f64())),
    ])
}

fn worker_list(state: &GatewayState) -> Response {
    let arr = state.fleet.snapshot().iter().map(worker_info_json).collect();
    Response::json(200, &Value::obj(vec![("workers", Value::Arr(arr))]))
}

fn worker_remove(addr: &str, state: &GatewayState) -> Response {
    if state.fleet.remove(addr) {
        Response::json(
            200,
            &Value::obj(vec![
                ("addr", Value::Str(addr.to_string())),
                ("status", Value::Str("removed".into())),
            ]),
        )
    } else {
        Response::json_error(404, &format!("no worker {addr:?}"))
    }
}

// -- run endpoints (the serve facade, fleet-backed) ----------------------

fn submit_run(req: &Request, state: &Arc<GatewayState>) -> Response {
    if state.shutdown.load(Ordering::SeqCst) {
        return Response::json_error(503, "gateway is shutting down");
    }
    let mut analysis = match crate::serve::analysis_request_from(req, state.cfg.max_body) {
        Ok(a) => a,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    // the gateway is a front door: honour a caller-supplied request id
    // (JSON field, then X-Request-Id header), mint one otherwise
    if analysis.request_id.is_none() {
        analysis.request_id = req.header("x-request-id").map(str::to_string);
    }
    let request_id = analysis
        .request_id
        .clone()
        .unwrap_or_else(trace::new_request_id);
    // content-addressed front door, consulted before placement *and*
    // before admission control: a hit is answered entirely
    // gateway-side — no run thread, no worker traffic, no inflight
    // slot — with a record born Done
    let digest = analysis.request_digest().ok();
    if let Some(d) = digest.as_deref() {
        if let Some(body) = state.cache.get(d) {
            if let Ok(res) = AnalysisResult::from_json_str(&body) {
                return insert_cached_job(state, &request_id, d, res);
            }
        }
    }
    // admission control: a run fans out across the whole fleet, so the
    // inflight cap plays the role the worker queue capacity plays on a
    // single serve (same 429 + Retry-After contract)
    if state.inflight() >= state.cfg.max_inflight.max(1) {
        state.rejected.fetch_add(1, Ordering::Relaxed);
        return Response::json(
            429,
            &http::error_envelope(
                429,
                &format!(
                    "gateway at max inflight runs ({}); retry later",
                    state.cfg.max_inflight.max(1)
                ),
                &[("retry_after_s", Value::Num(RETRY_AFTER_S as f64))],
            ),
        )
        .with_header("Retry-After", &RETRY_AFTER_S.to_string());
    }
    let handle = JobHandle::new();
    let id = {
        let mut jobs = state.jobs.lock().unwrap();
        let id = jobs.next;
        jobs.next += 1;
        jobs.map.insert(
            id,
            GwJob {
                id,
                state: JobState::Queued,
                handle: handle.clone(),
                request_id: request_id.clone(),
                digest,
                cached: false,
                recorder: Recorder::new(&request_id),
                placements: Arc::new(Mutex::new(Vec::new())),
                submitted_at: Instant::now(),
                pixels: None,
                result: None,
                shards: Vec::new(),
                finished_at: None,
            },
        );
        id
    };
    state.submitted.fetch_add(1, Ordering::Relaxed);
    trace::log!(
        Info,
        "gateway",
        "run_submitted",
        "job" => id,
        "request_id" => &request_id,
    );
    let run_state = Arc::clone(state);
    let t = std::thread::spawn(move || run_job(&run_state, id, analysis, handle));
    state.run_threads.lock().unwrap().push(t);
    Response::json(
        202,
        &Value::obj(vec![
            ("job", Value::Num(id as f64)),
            ("status", Value::Str("queued".into())),
            ("request_id", Value::Str(request_id)),
        ]),
    )
}

/// Record and answer a result-cache hit: a `GwJob` born `Done` with
/// the cached result attached. Nothing fans out — the fleet never
/// hears about this run.
fn insert_cached_job(
    state: &GatewayState,
    request_id: &str,
    digest: &str,
    result: AnalysisResult,
) -> Response {
    let handle = JobHandle::new();
    handle.set_progress(result.chunks, result.chunks);
    let now = Instant::now();
    let id = {
        let mut jobs = state.jobs.lock().unwrap();
        let id = jobs.next;
        jobs.next += 1;
        let pixels = Some(result.map.len());
        jobs.map.insert(
            id,
            GwJob {
                id,
                state: JobState::Done,
                handle,
                request_id: request_id.to_string(),
                digest: Some(digest.to_string()),
                cached: true,
                recorder: Recorder::new(request_id),
                placements: Arc::new(Mutex::new(Vec::new())),
                submitted_at: now,
                pixels,
                result: Some(result),
                shards: Vec::new(),
                finished_at: Some(now),
            },
        );
        // same count-capped retention run_job applies after a compute
        let finished: Vec<u64> = jobs
            .map
            .iter()
            .filter(|(_, j)| j.state.is_finished())
            .map(|(&i, _)| i)
            .collect();
        if finished.len() > state.cfg.finished_cap.max(1) {
            for i in &finished[..finished.len() - state.cfg.finished_cap.max(1)] {
                jobs.map.remove(i);
            }
        }
        id
    };
    state.submitted.fetch_add(1, Ordering::Relaxed);
    trace::log!(
        Info,
        "gateway",
        "run_cache_hit",
        "job" => id,
        "request_id" => request_id,
        "digest" => digest,
    );
    Response::json(
        202,
        &Value::obj(vec![
            ("job", Value::Num(id as f64)),
            ("status", Value::Str("done".into())),
            ("cached", Value::Bool(true)),
            ("request_id", Value::Str(request_id.to_string())),
        ]),
    )
}

fn job_json(job: &GwJob) -> Value {
    let mut fields = vec![
        ("job", Value::Num(job.id as f64)),
        ("status", Value::Str(job.state.label().into())),
        ("request_id", Value::Str(job.request_id.clone())),
        ("progress", Value::Num(job.progress())),
    ];
    if let Some(px) = job.pixels {
        fields.push(("pixels", Value::Num(px as f64)));
    }
    if job.cached {
        fields.push(("cached", Value::Bool(true)));
    }
    let (chunks_done, chunks_total) = job.handle.progress();
    match &job.state {
        JobState::Running | JobState::Cancelled => {
            fields.push(("chunks_done", Value::Num(chunks_done as f64)));
            fields.push(("chunks_total", Value::Num(chunks_total as f64)));
        }
        JobState::Failed { error } => fields.push(("error", Value::Str(error.clone()))),
        _ => {}
    }
    if let Some(res) = &job.result {
        fields.push(("breaks", Value::Num(res.map.break_count() as f64)));
        fields.push(("chunks", Value::Num(res.chunks as f64)));
        fields.push(("artifact", Value::Str(res.artifact.clone())));
        fields.push(("engine", Value::Str(res.engine.clone())));
        fields.push(("lambda", Value::Num(res.params.lambda)));
        fields.push(("wall_s", Value::Num(res.wall.as_secs_f64())));
    }
    if !job.shards.is_empty() {
        let arr = job
            .shards
            .iter()
            .map(|s| {
                Value::obj(vec![
                    ("shard", Value::Num(s.shard as f64)),
                    ("pixel_start", Value::Num(s.pixel_range.0 as f64)),
                    ("pixel_end", Value::Num(s.pixel_range.1 as f64)),
                    ("worker", Value::Str(s.worker.clone())),
                    ("attempts", Value::Num(s.attempts as f64)),
                    ("chunks", Value::Num(s.chunks as f64)),
                    ("wall_s", Value::Num(s.wall.as_secs_f64())),
                ])
            })
            .collect();
        fields.push(("shards", Value::Arr(arr)));
    }
    Value::obj(fields)
}

fn list_runs(state: &GatewayState) -> Response {
    let jobs = state.jobs.lock().unwrap();
    let arr = jobs
        .map
        .values()
        .map(|j| {
            Value::obj(vec![
                ("job", Value::Num(j.id as f64)),
                ("status", Value::Str(j.state.label().into())),
                ("progress", Value::Num(j.progress())),
            ])
        })
        .collect();
    Response::json(200, &Value::obj(vec![("jobs", Value::Arr(arr))]))
}

fn parse_id(seg: &str) -> Result<u64> {
    seg.parse().map_err(|_| err!("job id {seg:?} must be an integer"))
}

fn run_status(id_seg: &str, state: &GatewayState) -> Response {
    let id = match parse_id(id_seg) {
        Ok(id) => id,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    match state.jobs.lock().unwrap().map.get(&id) {
        Some(job) => Response::json(200, &job_json(job)),
        None => Response::json_error(404, &format!("no job {id}")),
    }
}

fn cancel_run(id_seg: &str, state: &GatewayState) -> Response {
    let id = match parse_id(id_seg) {
        Ok(id) => id,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    let jobs = state.jobs.lock().unwrap();
    match jobs.map.get(&id) {
        None => Response::json_error(404, &format!("no job {id}")),
        Some(job) if job.state.is_finished() => {
            Response::json_error(409, &format!("job {id} already finished"))
        }
        Some(job) => {
            // cooperative: the run thread observes the handle at its
            // next poll tick and DELETE-fans-out to every live shard
            job.handle.cancel();
            Response::json(
                200,
                &Value::obj(vec![
                    ("job", Value::Num(id as f64)),
                    ("status", Value::Str("cancelling".into())),
                ]),
            )
        }
    }
}

fn run_map(req: &Request, id_seg: &str, state: &GatewayState) -> Response {
    let id = match parse_id(id_seg) {
        Ok(id) => id,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    let jobs = state.jobs.lock().unwrap();
    match jobs.map.get(&id) {
        None => Response::json_error(404, &format!("no job {id}")),
        Some(job) => match (&job.state, &job.result) {
            (JobState::Done, Some(res)) => {
                crate::serve::map_response(req, &res.map, res.width, res.height)
            }
            (JobState::Failed { error }, _) => {
                Response::json_error(409, &format!("job {id} failed: {error}"))
            }
            (JobState::Cancelled, _) => {
                Response::json_error(409, &format!("job {id} was cancelled"))
            }
            _ => Response::json_error(409, &format!("job {id} is not finished")),
        },
    }
}

/// Same conditional-GET contract as the worker's result endpoint: the
/// request digest is the strong `ETag`, `If-None-Match` re-fetches
/// answer `304`, and gzip is applied when the caller accepts it.
fn run_result(req: &Request, id_seg: &str, state: &GatewayState) -> Response {
    let id = match parse_id(id_seg) {
        Ok(id) => id,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    let jobs = state.jobs.lock().unwrap();
    let resp = match jobs.map.get(&id) {
        None => Response::json_error(404, &format!("no job {id}")),
        Some(job) => match (&job.state, &job.result) {
            (JobState::Done, Some(res)) => {
                let etag = job.digest.as_ref().map(|d| format!("\"{d}\""));
                let matched = etag.as_ref().is_some_and(|etag| {
                    req.header("if-none-match")
                        .is_some_and(|v| crate::serve::etag_matches(v, etag))
                });
                match (matched, etag) {
                    (true, Some(etag)) => Response::text(304, "").with_header("ETag", &etag),
                    (_, Some(etag)) => {
                        Response::json(200, &res.to_json()).with_header("ETag", &etag)
                    }
                    _ => Response::json(200, &res.to_json()),
                }
            }
            (JobState::Failed { error }, _) => {
                Response::json_error(409, &format!("job {id} failed: {error}"))
            }
            (JobState::Cancelled, _) => {
                Response::json_error(409, &format!("job {id} was cancelled"))
            }
            _ => Response::json_error(409, &format!("job {id} is not finished")),
        },
    };
    drop(jobs);
    resp.gzip_if_accepted(req)
}

/// `GET /v1/cache` — gateway result-cache counters and occupancy.
fn cache_stats(state: &GatewayState) -> Response {
    let s = state.cache.stats();
    Response::json(
        200,
        &Value::obj(vec![
            ("enabled", Value::Bool(state.cache.enabled())),
            ("capacity", Value::Num(s.capacity as f64)),
            ("entries", Value::Num(s.entries as f64)),
            ("bytes", Value::Num(s.bytes as f64)),
            ("hits", Value::Num(s.hits as f64)),
            ("misses", Value::Num(s.misses as f64)),
            ("evictions", Value::Num(s.evictions as f64)),
        ]),
    )
}

/// `DELETE /v1/cache` — drop every cached result (counters survive).
fn cache_clear(state: &GatewayState) -> Response {
    let cleared = state.cache.clear();
    Response::json(200, &Value::obj(vec![("cleared", Value::Num(cleared as f64))]))
}

// -- the distributed trace endpoint --------------------------------------

/// Span-id offset between merged processes: worker `k` (0-based) has
/// its span ids shifted by `(k + 1) * SPAN_ID_STRIDE`, keeping every
/// id unique in the merged trace while gateway ids stay untouched.
/// Far above any real recorder's id count (rings cap at tens of
/// thousands of spans).
const SPAN_ID_STRIDE: u64 = 1_000_000;

/// `GET /v1/runs/{id}/cmdstream` — not servable at the gateway: a
/// fanned-out run executes as N per-worker shard jobs, so there is no
/// single recorded stream describing it. Answers 409 for known jobs
/// (pointing at the worker-level endpoint) and 404 otherwise.
fn run_cmdstream(id_seg: &str, state: &GatewayState) -> Response {
    let id = match parse_id(id_seg) {
        Ok(id) => id,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    if !state.jobs.lock().unwrap().map.contains_key(&id) {
        return Response::json_error(404, &format!("no job {id}"));
    }
    Response::json_error(
        409,
        &format!(
            "job {id} was fanned out across workers and has no single recorded \
             command stream; submit to one worker with outputs.record (or \
             ?record=1) and fetch its /v1/runs/{{id}}/cmdstream"
        ),
    )
}

/// `GET /v1/runs/{id}/trace` — one Chrome trace for the whole
/// distributed run: the gateway's own span tree (pid 1) merged with
/// every placed worker job's trace (pid 2…N, fetched live from the
/// workers), worker roots re-parented under the gateway shard span
/// that placed them. Workers that cannot be reached (still down) are
/// skipped and counted in `otherData.workers_unreachable`.
fn run_trace(id_seg: &str, state: &GatewayState) -> Response {
    let id = match parse_id(id_seg) {
        Ok(id) => id,
        Err(e) => return Response::json_error(400, &format!("{e:#}")),
    };
    let (recorder, request_id, placements) = {
        let jobs = state.jobs.lock().unwrap();
        let Some(job) = jobs.map.get(&id) else {
            return Response::json_error(404, &format!("no job {id}"));
        };
        (job.recorder.clone(), job.request_id.clone(), Arc::clone(&job.placements))
    };
    let Some(rec) = recorder else {
        return Response::json_error(
            409,
            &format!("job {id} has no trace (tracing disabled at submission)"),
        );
    };
    let mut events = trace::chrome_events(&rec.records(), 1, "bfast gateway");
    let placements = placements.lock().unwrap().clone();
    let mut unreachable = 0u64;
    for (k, p) in placements.iter().enumerate() {
        let pid = k as u64 + 2;
        let offset = (k as u64 + 1) * SPAN_ID_STRIDE;
        match fetch_worker_trace(&p.worker, p.job, state.cfg.io_timeout) {
            Ok(worker_trace) => {
                merge_worker_events(&mut events, &worker_trace, pid, offset, p.span_id);
                events.push(Value::obj(vec![
                    ("ph", Value::Str("M".into())),
                    ("name", Value::Str("process_name".into())),
                    ("pid", Value::Num(pid as f64)),
                    ("tid", Value::Num(0.0)),
                    (
                        "args",
                        Value::obj(vec![(
                            "name",
                            Value::Str(format!("worker {} (job {})", p.worker, p.job)),
                        )]),
                    ),
                ]));
            }
            Err(e) => {
                unreachable += 1;
                trace::log!(
                    Warn,
                    "gateway",
                    "trace_fetch_failed",
                    "worker" => &p.worker,
                    "worker_job" => p.job,
                    "request_id" => &request_id,
                    "error" => format!("{e:#}"),
                );
            }
        }
    }
    Response::json(
        200,
        &Value::obj(vec![
            ("traceEvents", Value::Arr(events)),
            ("displayTimeUnit", Value::Str("ms".into())),
            (
                "otherData",
                Value::obj(vec![
                    ("request_id", Value::Str(request_id)),
                    ("dropped_spans", Value::Num(rec.dropped() as f64)),
                    ("workers_merged", Value::Num((placements.len() as u64 - unreachable) as f64)),
                    ("workers_unreachable", Value::Num(unreachable as f64)),
                ]),
            ),
        ]),
    )
}

/// Fetch one worker job's Chrome trace (`GET /v1/runs/{job}/trace`).
fn fetch_worker_trace(worker: &str, job: u64, io: Duration) -> Result<Value> {
    let mut c = Client::connect_timeout(worker, io)?;
    let (status, body) = c.request("GET", &format!("/v1/runs/{job}/trace"), "", &[])?;
    ensure!(status == 200, "worker answered {status}: {}", http::error_message(&body));
    crate::json::parse(std::str::from_utf8(&body).context("non-UTF-8 trace body")?)
}

/// Fold one worker's `traceEvents` into the merged stream: re-stamp
/// the pid, shift `span_id`/`parent_id` by `offset`, and re-parent the
/// worker's root spans (parent 0) under the gateway shard span that
/// placed the job. Worker-side metadata events are skipped (the caller
/// pushes its own process-name event per worker).
fn merge_worker_events(
    events: &mut Vec<Value>,
    worker_trace: &Value,
    pid: u64,
    offset: u64,
    shard_span: u64,
) {
    let Some(Value::Arr(worker_events)) = worker_trace.try_get("traceEvents") else {
        return;
    };
    for ev in worker_events {
        let Value::Obj(fields) = ev else { continue };
        if fields.iter().any(|(k, v)| k == "ph" && matches!(v, Value::Str(s) if s == "M")) {
            continue;
        }
        let mut fields = fields.clone();
        for (k, v) in fields.iter_mut() {
            match k.as_str() {
                "pid" => *v = Value::Num(pid as f64),
                "args" => {
                    if let Value::Obj(args) = v {
                        for (ak, av) in args.iter_mut() {
                            let id = match av {
                                Value::Num(n) => *n as u64,
                                _ => continue,
                            };
                            if ak == "span_id" {
                                *av = Value::Num((id + offset) as f64);
                            } else if ak == "parent_id" {
                                *av = Value::Num(if id == 0 {
                                    shard_span as f64
                                } else {
                                    (id + offset) as f64
                                });
                            }
                        }
                    }
                }
                _ => {}
            }
        }
        events.push(Value::Obj(fields));
    }
}

// -- session proxying ----------------------------------------------------

fn list_sessions(state: &GatewayState) -> Response {
    let arr = state
        .sessions
        .lock()
        .unwrap()
        .keys()
        .cloned()
        .map(Value::Str)
        .collect();
    Response::json(200, &Value::obj(vec![("sessions", Value::Arr(arr))]))
}

/// Create routes to the least-loaded live worker; the gateway records
/// the owner on success and forwards every later request there —
/// session state (the fitted history) lives on exactly one worker.
fn create_session(req: &Request, name: &str, state: &GatewayState) -> Response {
    let owner = state.sessions.lock().unwrap().get(name).cloned();
    let target = match owner {
        // existing name: let the owner answer (it will 409)
        Some(owner) => owner,
        None => {
            let placement = state.fleet.placement();
            if placement.is_empty() {
                return Response::json_error(
                    503,
                    "no live workers to host the session — register workers first",
                );
            }
            let owners = state.sessions.lock().unwrap();
            placement
                .iter()
                .map(|(w, _)| w)
                .min_by_key(|w| owners.values().filter(|o| o == w).count())
                .cloned()
                .unwrap()
        }
    };
    match forward(&target, req, state.cfg.io_timeout) {
        Ok(resp) => {
            if resp.status == 201 {
                state
                    .sessions
                    .lock()
                    .unwrap()
                    .insert(name.to_string(), target);
            }
            resp
        }
        Err(e) => {
            state.fleet.mark_down(&target);
            Response::json_error(502, &format!("worker {target}: {e:#}"))
        }
    }
}

fn proxy_session(req: &Request, name: &str, state: &GatewayState) -> Response {
    let Some(owner) = state.sessions.lock().unwrap().get(name).cloned() else {
        return Response::json_error(404, &format!("no session named {name:?}"));
    };
    if !state.fleet.is_alive(&owner) {
        return Response::json_error(
            503,
            &format!("session {name:?} lives on worker {owner}, which is not alive"),
        );
    }
    match forward(&owner, req, state.cfg.io_timeout) {
        Ok(resp) => resp,
        Err(e) => {
            state.fleet.mark_down(&owner);
            Response::json_error(502, &format!("worker {owner}: {e:#}"))
        }
    }
}

/// Forward one request verbatim (method, path, query, content type,
/// body) and relay the worker's response.
fn forward(worker: &str, req: &Request, io: Duration) -> Result<Response> {
    let mut path = req.path.clone();
    if !req.query.is_empty() {
        let qs: Vec<String> = req
            .query
            .iter()
            .map(|(k, v)| format!("{}={}", enc(k), enc(v)))
            .collect();
        path = format!("{path}?{}", qs.join("&"));
    }
    let mut c = Client::connect_timeout(worker, io)?;
    let (status, headers, body) =
        c.request_parts(&req.method, &path, req.content_type(), &req.body)?;
    let ctype = headers
        .iter()
        .find(|(k, _)| k == "content-type")
        .map(|(_, v)| v.as_str())
        .unwrap_or("application/octet-stream");
    Ok(Response::bytes(status, ctype, body))
}

/// Minimal percent-encoder for re-serialising decoded query values.
fn enc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for b in s.bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'-' | b'_' | b'.' | b'~' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

// -- the CLI front door --------------------------------------------------

/// The `bfast gateway` flag surface.
pub fn gateway_command() -> Command {
    Command::new("gateway", "resident fleet coordinator: one /v1 facade over many workers")
        .opt("addr", "127.0.0.1:7979", "listen address (host:port)")
        .opt("workers", "", "static worker addresses to seed (host:port,...)")
        .opt("http-threads", "0", "HTTP worker threads (0 = auto)")
        .opt("max-body-mb", "256", "largest accepted request body (MiB)")
        .opt("poll-ms", "25", "per-shard worker poll interval (ms)")
        .opt("io-timeout-ms", "10000", "per-I/O timeout on worker sockets (ms)")
        .opt("heartbeat-timeout-ms", "5000", "beats older than this mark a worker stale (ms)")
        .opt("sweep-ms", "1000", "health probe + throughput scrape interval (ms)")
        .opt("submit-attempts", "8", "bounded 429-backoff tries per shard submit")
        .opt("max-resplits", "4", "re-split budget per pixel range on worker death")
        .opt("max-inflight", "8", "concurrent runs admitted before 429")
        .opt("finished-cap", "256", "finished run records retained")
        .opt("cache-cap-mb", "64", "result cache capacity (MiB; 0 disables caching)")
        .opt("log-level", "info", "log verbosity: error|warn|info|debug|trace")
        .opt("log-format", "json", "log line format: json|text")
        .opt("trace", "on", "flight recorder (span capture): on|off")
}

/// Parse `bfast gateway` flags into a [`GatewayConfig`].
pub fn gateway_config_from_matches(m: &Matches) -> Result<GatewayConfig> {
    let workers: Vec<String> = m
        .str("workers")?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    Ok(GatewayConfig {
        addr: m.str("addr")?.to_string(),
        workers,
        http_threads: m.usize("http-threads")?,
        max_body: m.usize("max-body-mb")? << 20,
        poll: Duration::from_millis(m.u64("poll-ms")?),
        io_timeout: Duration::from_millis(m.u64("io-timeout-ms")?),
        heartbeat_timeout: Duration::from_millis(m.u64("heartbeat-timeout-ms")?),
        sweep: Duration::from_millis(m.u64("sweep-ms")?),
        submit_attempts: m.usize("submit-attempts")?,
        max_resplits: m.usize("max-resplits")?,
        max_inflight: m.usize("max-inflight")?,
        finished_cap: m.usize("finished-cap")?,
        cache_cap: m.usize("cache-cap-mb")? << 20,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_lifecycle_beat_stale_down_recover() {
        let fleet = Fleet::new(Duration::from_millis(80));
        fleet.beat("a:1", None);
        fleet.beat("b:2", Some(3.0));
        assert_eq!(fleet.counts(), (2, 2));
        assert!(fleet.is_alive("a:1"));
        // placement is address-ordered with effective weights
        let p = fleet.placement();
        assert_eq!(p[0].0, "a:1");
        assert_eq!(p[1], ("b:2".to_string(), 3.0));
        // down beats staleness: an explicit mark removes it now
        fleet.mark_down("a:1");
        assert!(!fleet.is_alive("a:1"));
        assert_eq!(fleet.placement().len(), 1);
        // ...and a fresh beat resurrects it
        fleet.beat("a:1", None);
        assert!(fleet.is_alive("a:1"));
        // stale: no beat within the timeout
        std::thread::sleep(Duration::from_millis(120));
        assert!(!fleet.is_alive("a:1"));
        assert_eq!(fleet.counts(), (2, 0));
        let snap = fleet.snapshot();
        assert_eq!(snap[0].status(), "stale");
    }

    #[test]
    fn fleet_rate_ema_from_scrapes() {
        let fleet = Fleet::new(Duration::from_secs(60));
        fleet.beat("w:1", None);
        let t0 = Instant::now();
        fleet.observe_chunks("w:1", 100, t0);
        // first delta: 100 chunks in 1s → rate adopts 100
        fleet.observe_chunks("w:1", 200, t0 + Duration::from_secs(1));
        let r1 = fleet.snapshot()[0].rate;
        assert!((r1 - 100.0).abs() < 1e-9, "{r1}");
        // idle scrape (no delta) must NOT decay the rate
        fleet.observe_chunks("w:1", 200, t0 + Duration::from_secs(2));
        assert_eq!(fleet.snapshot()[0].rate, r1);
        // counter went backwards (restart) → re-anchor, keep rate
        fleet.observe_chunks("w:1", 10, t0 + Duration::from_secs(3));
        assert_eq!(fleet.snapshot()[0].rate, r1);
        // faster delta pulls the EMA up
        fleet.observe_chunks("w:1", 310, t0 + Duration::from_secs(4));
        let r2 = fleet.snapshot()[0].rate;
        assert!(r2 > r1, "{r2} should exceed {r1}");
    }

    #[test]
    fn scrape_counter_finds_the_line() {
        let page = b"bfast_uptime_seconds 1.5\nbfast_chunks_done_total 42\nbfast_jobs_done 1\n";
        assert_eq!(scrape_counter(page, "bfast_chunks_done_total"), Some(42));
        assert_eq!(scrape_counter(page, "bfast_nope"), None);
    }

    #[test]
    fn enc_escapes_reserved() {
        assert_eq!(enc("abc-123_.~"), "abc-123_.~");
        assert_eq!(enc("a b&c=d"), "a%20b%26c%3Dd");
    }

    #[test]
    fn gateway_flags_parse() {
        let args: Vec<String> = [
            "--addr", "127.0.0.1:0", "--workers", "a:1, b:2", "--poll-ms", "5",
            "--heartbeat-timeout-ms", "250", "--sweep-ms", "50", "--max-resplits", "2",
            "--max-inflight", "3",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let m = gateway_command().parse(&args).unwrap();
        let cfg = gateway_config_from_matches(&m).unwrap();
        assert_eq!(cfg.workers, vec!["a:1", "b:2"]);
        assert_eq!(cfg.poll, Duration::from_millis(5));
        assert_eq!(cfg.heartbeat_timeout, Duration::from_millis(250));
        assert_eq!(cfg.sweep, Duration::from_millis(50));
        assert_eq!(cfg.max_resplits, 2);
        assert_eq!(cfg.max_inflight, 3);
        assert_eq!(cfg.max_body, 256 << 20);
        assert_eq!(cfg.cache_cap, 64 << 20);
    }

    #[test]
    fn cache_cap_flag_scales_and_disables() {
        let args: Vec<String> =
            ["--cache-cap-mb", "0"].iter().map(|s| s.to_string()).collect();
        let m = gateway_command().parse(&args).unwrap();
        assert_eq!(gateway_config_from_matches(&m).unwrap().cache_cap, 0);
    }
}
