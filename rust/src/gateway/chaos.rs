//! Deterministic fault injection for the fleet tests: a TCP forwarder
//! that sits between a coordinator and one worker and misbehaves *on
//! command*.
//!
//! The gateway's recovery paths — dead worker, slow worker, half-open
//! connection, black-holed poll — are all triggered by network
//! behaviour, which ordinary tests can only provoke by racing real
//! processes. [`ChaosProxy`] makes the network itself scriptable: a
//! test registers the proxy's address as the worker, lets traffic flow
//! ([`Mode::Forward`]), and then flips the mode at a chosen moment
//! (e.g. once the worker reports chunk progress) to murder the link
//! deterministically:
//!
//! * [`Mode::Forward`] — transparent byte pump, both directions.
//! * [`Mode::Delay`] — forward, but only after holding each new
//!   connection for a fixed latency (slow ≠ dead).
//! * [`Mode::Blackhole`] — accept and read, never answer: the
//!   harshest failure, detectable only by timeout.
//! * [`Mode::Drop`] — accept and immediately close: a fast, clean
//!   connection refusal as seen by a keep-alive client.
//!
//! [`ChaosProxy::kill_connections`] additionally severs every
//! *existing* connection (a generation counter each pump thread
//! watches), so a test can let a submit succeed and then cut the
//! socket mid-poll — the classic half-open failure.
//!
//! The proxy is test infrastructure, but it lives in-tree (not under
//! `#[cfg(test)]`) so both integration suites (`tests/gateway.rs`,
//! `tests/chaos.rs`) and any operator who wants to rehearse fleet
//! failure drills can use it.

use crate::error::{Context, Result};
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How the proxy treats each **new** connection (sampled at accept).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Transparent forwarding.
    Forward,
    /// Hold each new connection this long before forwarding.
    Delay(Duration),
    /// Accept, read and discard, never reply.
    Blackhole,
    /// Accept and close immediately.
    Drop,
}

struct ProxyState {
    upstream: String,
    mode: Mutex<Mode>,
    /// Bumped by [`ChaosProxy::kill_connections`]; pump threads exit
    /// when the generation moves past the one they were born into.
    generation: AtomicU64,
    shutdown: AtomicBool,
    connections: AtomicUsize,
}

/// A scriptable TCP forwarder — see the module docs.
pub struct ChaosProxy {
    addr: SocketAddr,
    state: Arc<ProxyState>,
    accept: std::thread::JoinHandle<()>,
}

/// Pump tick: how often a blocked read re-checks shutdown/generation.
const TICK: Duration = Duration::from_millis(25);

impl ChaosProxy {
    /// Bind an ephemeral local port forwarding to `upstream` (in
    /// [`Mode::Forward`]) and start accepting.
    pub fn start(upstream: &str) -> Result<ChaosProxy> {
        let listener = TcpListener::bind("127.0.0.1:0").context("binding chaos proxy")?;
        let addr = listener.local_addr()?;
        let state = Arc::new(ProxyState {
            upstream: upstream.to_string(),
            mode: Mutex::new(Mode::Forward),
            generation: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            connections: AtomicUsize::new(0),
        });
        let accept_state = Arc::clone(&state);
        let accept = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if accept_state.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                accept_state.connections.fetch_add(1, Ordering::Relaxed);
                let st = Arc::clone(&accept_state);
                // one (pair of) thread(s) per connection: test-scale
                // traffic, no pool needed
                std::thread::spawn(move || handle(stream, &st));
            }
        });
        Ok(ChaosProxy { addr, state, accept })
    }

    /// The address tests hand out as "the worker".
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Switch behaviour for **new** connections (existing pumps keep
    /// flowing — pair with [`ChaosProxy::kill_connections`] to also
    /// sever what's already open).
    pub fn set_mode(&self, mode: Mode) {
        *self.state.mode.lock().unwrap() = mode;
    }

    /// Sever every currently-open proxied connection (the pump threads
    /// notice within one tick and shut both ends down).
    pub fn kill_connections(&self) {
        self.state.generation.fetch_add(1, Ordering::SeqCst);
    }

    /// Total connections accepted so far.
    pub fn connections(&self) -> usize {
        self.state.connections.load(Ordering::Relaxed)
    }

    /// Stop accepting, sever everything, and join the accept thread.
    pub fn stop(self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
        self.state.generation.fetch_add(1, Ordering::SeqCst);
        // poke the accept loop out of incoming()
        let _ = TcpStream::connect(self.addr);
        let _ = self.accept.join();
    }
}

fn handle(client: TcpStream, state: &Arc<ProxyState>) {
    let born = state.generation.load(Ordering::SeqCst);
    let mode = *state.mode.lock().unwrap();
    match mode {
        Mode::Drop => {
            let _ = client.shutdown(Shutdown::Both);
        }
        Mode::Blackhole => blackhole(client, state, born),
        Mode::Delay(latency) => {
            // hold in ticks so stop()/kill don't have to outwait a
            // long configured latency
            let mut waited = Duration::ZERO;
            while waited < latency {
                if severed(state, born) {
                    let _ = client.shutdown(Shutdown::Both);
                    return;
                }
                let step = TICK.min(latency - waited);
                std::thread::sleep(step);
                waited += step;
            }
            forward(client, state, born);
        }
        Mode::Forward => forward(client, state, born),
    }
}

fn severed(state: &ProxyState, born: u64) -> bool {
    state.shutdown.load(Ordering::SeqCst) || state.generation.load(Ordering::SeqCst) != born
}

/// Read and discard forever (until severed or the client gives up) —
/// the client's request "arrives" but no reply ever comes.
fn blackhole(client: TcpStream, state: &Arc<ProxyState>, born: u64) {
    let _ = client.set_read_timeout(Some(TICK));
    let mut sink = [0u8; 8192];
    let mut stream = client;
    loop {
        if severed(state, born) {
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
        match stream.read(&mut sink) {
            Ok(0) => return, // client closed
            Ok(_) => {}      // swallow
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => return,
        }
    }
}

/// Transparent bidirectional pump: two threads, each copying one
/// direction in short-timeout ticks so a kill lands within ~one tick.
fn forward(client: TcpStream, state: &Arc<ProxyState>, born: u64) {
    let upstream = match TcpStream::connect(&state.upstream) {
        Ok(s) => s,
        Err(_) => {
            let _ = client.shutdown(Shutdown::Both);
            return;
        }
    };
    let _ = client.set_nodelay(true);
    let _ = upstream.set_nodelay(true);
    let (Ok(c2), Ok(u2)) = (client.try_clone(), upstream.try_clone()) else {
        return;
    };
    let st = Arc::clone(state);
    let a = std::thread::spawn(move || pump(client, u2, &st, born));
    pump(upstream, c2, state, born);
    let _ = a.join();
}

fn pump(mut from: TcpStream, mut to: TcpStream, state: &ProxyState, born: u64) {
    let _ = from.set_read_timeout(Some(TICK));
    let mut buf = [0u8; 8192];
    loop {
        if severed(state, born) {
            break;
        }
        match from.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                if to.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => break,
        }
    }
    // sever both ends: the peer's pump unblocks on EOF/error
    let _ = from.shutdown(Shutdown::Both);
    let _ = to.shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};

    /// A one-line echo upstream: reads a line, writes it back.
    fn echo_server() -> (SocketAddr, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(stream) = conn else { break };
                std::thread::spawn(move || {
                    let mut reader = BufReader::new(stream);
                    let mut line = String::new();
                    while reader.read_line(&mut line).map(|n| n > 0).unwrap_or(false) {
                        if reader.get_ref().write_all(line.as_bytes()).is_err() {
                            break;
                        }
                        line.clear();
                    }
                });
            }
        });
        (addr, h)
    }

    fn roundtrip_line(addr: SocketAddr) -> std::io::Result<String> {
        let mut s = TcpStream::connect(addr)?;
        s.set_read_timeout(Some(Duration::from_secs(2)))?;
        s.write_all(b"ping\n")?;
        let mut reader = BufReader::new(s);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "closed without reply",
            ));
        }
        Ok(line)
    }

    #[test]
    fn forwards_then_drops_then_blackholes() {
        let (up, _h) = echo_server();
        let proxy = ChaosProxy::start(&up.to_string()).unwrap();

        assert_eq!(roundtrip_line(proxy.addr()).unwrap(), "ping\n");
        assert!(proxy.connections() >= 1);

        proxy.set_mode(Mode::Drop);
        assert!(roundtrip_line(proxy.addr()).is_err(), "Drop must refuse service");

        proxy.set_mode(Mode::Blackhole);
        let t0 = std::time::Instant::now();
        let mut s = TcpStream::connect(proxy.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        s.write_all(b"ping\n").unwrap(); // accepted...
        let mut byte = [0u8; 1];
        assert!(s.read(&mut byte).is_err(), "Blackhole must never answer");
        assert!(t0.elapsed() >= Duration::from_millis(150), "failed only by timeout");

        proxy.set_mode(Mode::Forward);
        assert_eq!(roundtrip_line(proxy.addr()).unwrap(), "ping\n");
        proxy.stop();
    }

    #[test]
    fn delay_holds_but_delivers_and_kill_severs() {
        let (up, _h) = echo_server();
        let proxy = ChaosProxy::start(&up.to_string()).unwrap();

        proxy.set_mode(Mode::Delay(Duration::from_millis(120)));
        let t0 = std::time::Instant::now();
        assert_eq!(roundtrip_line(proxy.addr()).unwrap(), "ping\n");
        assert!(t0.elapsed() >= Duration::from_millis(100), "delay not applied");

        // an established Forward connection dies when killed
        proxy.set_mode(Mode::Forward);
        let mut s = TcpStream::connect(proxy.addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        s.write_all(b"ping\n").unwrap();
        let mut reader = BufReader::new(s);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "ping\n");
        proxy.kill_connections();
        line.clear();
        // severed: EOF (Ok(0 bytes) → empty line) or a reset error
        let dead = match reader.read_line(&mut line) {
            Ok(n) => n == 0,
            Err(_) => true,
        };
        assert!(dead, "kill_connections must sever the live socket");
        proxy.stop();
    }
}
