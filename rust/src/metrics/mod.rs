//! Phase timing & aggregation — the instrumentation behind the
//! paper's per-phase figures (Figs. 3–6).
//!
//! Both the CPU implementations and the device coordinator report
//! their work as named phases ("create model", "transfer", …); a
//! [`PhaseTimes`] accumulates durations across chunks and renders the
//! breakdown tables the benches print.

use std::time::{Duration, Instant};

/// Accumulated duration per named phase (insertion-ordered).
#[derive(Clone, Debug, Default)]
pub struct PhaseTimes {
    entries: Vec<(String, Duration)>,
}

impl PhaseTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `d` to phase `name` (created on first use).
    pub fn add(&mut self, name: &str, d: Duration) {
        if let Some((_, acc)) = self.entries.iter_mut().find(|(n, _)| n == name) {
            *acc += d;
        } else {
            self.entries.push((name.to_string(), d));
        }
    }

    /// Time `f` and charge it to `name`; returns f's output.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed());
        out
    }

    pub fn get(&self, name: &str) -> Option<Duration> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, d)| *d)
    }

    pub fn total(&self) -> Duration {
        self.entries.iter().map(|(_, d)| *d).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, Duration)> {
        self.entries.iter().map(|(n, d)| (n.as_str(), *d))
    }

    /// Merge another accumulation into this one.
    pub fn merge(&mut self, other: &PhaseTimes) {
        for (n, d) in other.iter() {
            self.add(n, d);
        }
    }

    /// JSON object form for the v1 result envelope: one key per phase
    /// in insertion order, durations as **integer nanoseconds** so the
    /// round-trip through [`PhaseTimes::from_json`] is exact (seconds
    /// as f64 would re-round through `Duration::from_secs_f64`).
    pub fn to_json(&self) -> crate::json::Value {
        crate::json::Value::Obj(
            self.entries
                .iter()
                .map(|(n, d)| (n.clone(), crate::json::Value::Num(d.as_nanos() as f64)))
                .collect(),
        )
    }

    pub fn from_json(v: &crate::json::Value) -> crate::error::Result<Self> {
        let crate::json::Value::Obj(pairs) = v else {
            crate::bail!("phase times must be an object of {{name: nanos}}");
        };
        use crate::error::Context as _;
        let mut out = PhaseTimes::new();
        for (name, ns) in pairs {
            let ns = ns.as_f64().with_context(|| format!("phase {name:?}"))?;
            crate::ensure!(
                ns.is_finite() && ns >= 0.0,
                "phase {name:?} has invalid duration {ns}"
            );
            out.add(name, Duration::from_nanos(ns as u64));
        }
        Ok(out)
    }

    /// Render the phases as Prometheus text-format gauge lines, one
    /// per phase: `name{phase="create model"} 1.234567` (seconds).
    /// Consumed by the serving layer's `/metrics` endpoint.
    pub fn to_prometheus(&self, name: &str) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        for (n, d) in self.iter() {
            let label = n.replace('\\', "\\\\").replace('"', "\\\"");
            let _ = writeln!(s, "{name}{{phase=\"{label}\"}} {:.6}", d.as_secs_f64());
        }
        s
    }

    /// Render the per-phase table (seconds + share of total).
    pub fn table(&self, title: &str) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let total = self.total().as_secs_f64();
        let _ = writeln!(s, "{title}");
        for (n, d) in self.iter() {
            let secs = d.as_secs_f64();
            let pct = if total > 0.0 { 100.0 * secs / total } else { 0.0 };
            let _ = writeln!(s, "  {n:<24} {secs:>10.4}s  {pct:>5.1}%");
        }
        let _ = writeln!(s, "  {:<24} {total:>10.4}s", "TOTAL");
        s
    }
}

/// One step of an exponential moving average: `prev + alpha * (sample
/// - prev)`. A `prev` of exactly 0.0 means "no observation yet" and
/// adopts the sample outright — so the first real measurement isn't
/// dragged toward zero by the uninitialised state. (Gateway worker
/// throughput tracking; rates are strictly positive when observed.)
pub fn ema(prev: f64, sample: f64, alpha: f64) -> f64 {
    if prev == 0.0 {
        sample
    } else {
        prev + alpha * (sample - prev)
    }
}

/// Median / MAD over repeated wall-clock samples (bench harness use).
pub fn median(xs: &mut [f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_adopts_first_sample_then_smooths() {
        assert_eq!(ema(0.0, 8.0, 0.5), 8.0);
        assert_eq!(ema(8.0, 4.0, 0.5), 6.0);
        assert_eq!(ema(6.0, 6.0, 0.25), 6.0);
        // alpha=1 tracks the sample exactly
        assert_eq!(ema(3.0, 9.0, 1.0), 9.0);
    }

    #[test]
    fn accumulates_and_orders() {
        let mut p = PhaseTimes::new();
        p.add("b", Duration::from_millis(10));
        p.add("a", Duration::from_millis(5));
        p.add("b", Duration::from_millis(10));
        let names: Vec<_> = p.iter().map(|(n, _)| n.to_string()).collect();
        assert_eq!(names, vec!["b", "a"]); // insertion order
        assert_eq!(p.get("b").unwrap(), Duration::from_millis(20));
        assert_eq!(p.total(), Duration::from_millis(25));
    }

    #[test]
    fn time_charges_phase() {
        let mut p = PhaseTimes::new();
        let v = p.time("work", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(p.get("work").unwrap() >= Duration::from_millis(4));
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimes::new();
        a.add("x", Duration::from_millis(1));
        let mut b = PhaseTimes::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        a.merge(&b);
        assert_eq!(a.get("x").unwrap(), Duration::from_millis(3));
        assert_eq!(a.get("y").unwrap(), Duration::from_millis(3));
    }

    #[test]
    fn table_renders_shares() {
        let mut p = PhaseTimes::new();
        p.add("alpha", Duration::from_millis(75));
        p.add("beta", Duration::from_millis(25));
        let t = p.table("phases");
        assert!(t.contains("alpha"));
        assert!(t.contains("75.0%"));
        assert!(t.contains("TOTAL"));
    }

    #[test]
    fn prometheus_lines_are_labelled_and_escaped() {
        let mut p = PhaseTimes::new();
        p.add("create model", Duration::from_millis(1500));
        p.add("weird \"phase\"", Duration::from_millis(250));
        let text = p.to_prometheus("bfast_run_phase_seconds");
        assert!(text.contains("bfast_run_phase_seconds{phase=\"create model\"} 1.500000"));
        assert!(text.contains("phase=\"weird \\\"phase\\\"\""));
        assert_eq!(text.lines().count(), 2);
    }

    #[test]
    fn json_roundtrip_is_exact_and_ordered() {
        let mut p = PhaseTimes::new();
        p.add("create model", Duration::from_nanos(1_234_567_891));
        p.add("transfer", Duration::from_nanos(7));
        let text = p.to_json().to_string_compact();
        let back = PhaseTimes::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.get("create model"), Some(Duration::from_nanos(1_234_567_891)));
        assert_eq!(back.get("transfer"), Some(Duration::from_nanos(7)));
        let names: Vec<_> = back.iter().map(|(n, _)| n.to_string()).collect();
        assert_eq!(names, vec!["create model", "transfer"]);
        // serialize → parse → serialize is a fixed point
        assert_eq!(back.to_json().to_string_compact(), text);
        // malformed inputs rejected
        assert!(PhaseTimes::from_json(&crate::json::parse("[1]").unwrap()).is_err());
        assert!(
            PhaseTimes::from_json(&crate::json::parse("{\"x\": -1}").unwrap()).is_err()
        );
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
    }
}
