//! Phase timing & aggregation — the instrumentation behind the
//! paper's per-phase figures (Figs. 3–6).
//!
//! Both the CPU implementations and the device coordinator report
//! their work as named phases ("create model", "transfer", …); a
//! [`PhaseTimes`] accumulates durations across chunks and renders the
//! breakdown tables the benches print.

use std::time::{Duration, Instant};

/// Accumulated duration per named phase (insertion-ordered).
#[derive(Clone, Debug, Default)]
pub struct PhaseTimes {
    entries: Vec<(String, Duration)>,
}

impl PhaseTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `d` to phase `name` (created on first use).
    pub fn add(&mut self, name: &str, d: Duration) {
        if let Some((_, acc)) = self.entries.iter_mut().find(|(n, _)| n == name) {
            *acc += d;
        } else {
            self.entries.push((name.to_string(), d));
        }
    }

    /// Time `f` and charge it to `name`; returns f's output. This is
    /// the one phase-timing hook every backend routes through, so it
    /// also opens the per-phase trace span: when the executing thread
    /// is inside a traced run (a chunk span is *current*), the phase
    /// lands in the flight recorder as its child — otherwise
    /// [`crate::trace::phase_scope`] is a no-op behind one atomic
    /// load.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let _span = crate::trace::phase_scope(name);
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed());
        out
    }

    pub fn get(&self, name: &str) -> Option<Duration> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, d)| *d)
    }

    pub fn total(&self) -> Duration {
        self.entries.iter().map(|(_, d)| *d).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, Duration)> {
        self.entries.iter().map(|(n, d)| (n.as_str(), *d))
    }

    /// Merge another accumulation into this one.
    pub fn merge(&mut self, other: &PhaseTimes) {
        for (n, d) in other.iter() {
            self.add(n, d);
        }
    }

    /// JSON object form for the v1 result envelope: one key per phase
    /// in insertion order, durations as **integer nanoseconds** so the
    /// round-trip through [`PhaseTimes::from_json`] is exact (seconds
    /// as f64 would re-round through `Duration::from_secs_f64`).
    pub fn to_json(&self) -> crate::json::Value {
        crate::json::Value::Obj(
            self.entries
                .iter()
                .map(|(n, d)| (n.clone(), crate::json::Value::Num(d.as_nanos() as f64)))
                .collect(),
        )
    }

    pub fn from_json(v: &crate::json::Value) -> crate::error::Result<Self> {
        let crate::json::Value::Obj(pairs) = v else {
            crate::bail!("phase times must be an object of {{name: nanos}}");
        };
        use crate::error::Context as _;
        let mut out = PhaseTimes::new();
        for (name, ns) in pairs {
            let ns = ns.as_f64().with_context(|| format!("phase {name:?}"))?;
            crate::ensure!(
                ns.is_finite() && ns >= 0.0,
                "phase {name:?} has invalid duration {ns}"
            );
            out.add(name, Duration::from_nanos(ns as u64));
        }
        Ok(out)
    }

    /// Render the phases as Prometheus text-format gauge lines, one
    /// per phase: `name{phase="create model"} 1.234567` (seconds).
    /// Consumed by the serving layer's `/metrics` endpoint.
    pub fn to_prometheus(&self, name: &str) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        for (n, d) in self.iter() {
            let label = n.replace('\\', "\\\\").replace('"', "\\\"");
            let _ = writeln!(s, "{name}{{phase=\"{label}\"}} {:.6}", d.as_secs_f64());
        }
        s
    }

    /// Render the per-phase table (seconds + share of total).
    pub fn table(&self, title: &str) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let total = self.total().as_secs_f64();
        let _ = writeln!(s, "{title}");
        for (n, d) in self.iter() {
            let secs = d.as_secs_f64();
            let pct = if total > 0.0 { 100.0 * secs / total } else { 0.0 };
            let _ = writeln!(s, "  {n:<24} {secs:>10.4}s  {pct:>5.1}%");
        }
        let _ = writeln!(s, "  {:<24} {total:>10.4}s", "TOTAL");
        s
    }
}

/// One step of an exponential moving average: `prev + alpha * (sample
/// - prev)`. A `prev` of exactly 0.0 means "no observation yet" and
/// adopts the sample outright — so the first real measurement isn't
/// dragged toward zero by the uninitialised state. (Gateway worker
/// throughput tracking; rates are strictly positive when observed.)
pub fn ema(prev: f64, sample: f64, alpha: f64) -> f64 {
    if prev == 0.0 {
        sample
    } else {
        prev + alpha * (sample - prev)
    }
}

// -- Prometheus exposition ----------------------------------------------

/// A fixed-bucket Prometheus histogram: thread-safe `observe`, text
/// exposition with cumulative `le` buckets plus `_sum`/`_count`. The
/// serving layers use it for queue-wait and end-to-end run latency.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<std::sync::atomic::AtomicU64>,
    /// Sum in nanoseconds (fits ~584 years of observed latency).
    sum_ns: std::sync::atomic::AtomicU64,
}

impl Histogram {
    /// Bucket upper bounds in seconds, ascending; an implicit `+Inf`
    /// bucket is always appended.
    pub fn new(bounds: &[f64]) -> Histogram {
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        let n = bounds.len() + 1; // +Inf
        Histogram {
            bounds: bounds.to_vec(),
            counts: (0..n).map(|_| std::sync::atomic::AtomicU64::new(0)).collect(),
            sum_ns: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Bounds suiting queue-wait style latencies (1 ms – 60 s).
    pub fn queue_wait() -> Histogram {
        Histogram::new(&[0.001, 0.005, 0.025, 0.1, 0.5, 1.0, 5.0, 15.0, 60.0])
    }

    /// Bounds suiting end-to-end run latencies (10 ms – 10 min).
    pub fn run_latency() -> Histogram {
        Histogram::new(&[0.01, 0.05, 0.25, 1.0, 5.0, 15.0, 60.0, 180.0, 600.0])
    }

    pub fn observe(&self, seconds: f64) {
        use std::sync::atomic::Ordering::Relaxed;
        let seconds = if seconds.is_finite() && seconds >= 0.0 { seconds } else { 0.0 };
        let idx = self
            .bounds
            .iter()
            .position(|&b| seconds <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx].fetch_add(1, Relaxed);
        self.sum_ns.fetch_add((seconds * 1e9) as u64, Relaxed);
    }

    pub fn count(&self) -> u64 {
        use std::sync::atomic::Ordering::Relaxed;
        self.counts.iter().map(|c| c.load(Relaxed)).sum()
    }

    /// Append the full exposition for this histogram (`# HELP`,
    /// `# TYPE`, cumulative buckets, `_sum`, `_count`) to `out`.
    pub fn render(&self, out: &mut String, name: &str, help: &str) {
        use std::fmt::Write;
        use std::sync::atomic::Ordering::Relaxed;
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut cum = 0u64;
        for (i, b) in self.bounds.iter().enumerate() {
            cum += self.counts[i].load(Relaxed);
            let _ = writeln!(out, "{name}_bucket{{le=\"{b}\"}} {cum}");
        }
        cum += self.counts[self.bounds.len()].load(Relaxed);
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        let sum = self.sum_ns.load(Relaxed) as f64 / 1e9;
        let _ = writeln!(out, "{name}_sum {sum:.6}");
        let _ = writeln!(out, "{name}_count {cum}");
    }
}

/// Append one `# HELP`/`# TYPE`-prefixed single-sample family to a
/// Prometheus exposition. `ty` is `"counter"` or `"gauge"`.
pub fn prom_metric(out: &mut String, ty: &str, name: &str, help: &str, value: f64) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {ty}");
    let _ = writeln!(out, "{name} {value}");
}

/// Append only the `# HELP`/`# TYPE` header for a family whose
/// samples the caller writes itself (labelled series).
pub fn prom_header(out: &mut String, ty: &str, name: &str, help: &str) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {ty}");
}

/// Append the `bfast_build_info` gauge: constant 1 with the version /
/// git revision / build profile as labels (the standard
/// `*_build_info` idiom). The git revision comes from the optional
/// `BFAST_GIT_REV` compile-time env var.
pub fn prom_build_info(out: &mut String) {
    use std::fmt::Write;
    prom_header(out, "gauge", "bfast_build_info", "build metadata (constant 1)");
    let _ = writeln!(
        out,
        "bfast_build_info{{version=\"{}\",git_rev=\"{}\",profile=\"{}\"}} 1",
        env!("CARGO_PKG_VERSION"),
        option_env!("BFAST_GIT_REV").unwrap_or("unknown"),
        build_profile(),
    );
}

/// `"debug"` or `"release"`, from how this binary was compiled.
pub fn build_profile() -> &'static str {
    if cfg!(debug_assertions) {
        "debug"
    } else {
        "release"
    }
}

/// Median / MAD over repeated wall-clock samples (bench harness use).
pub fn median(xs: &mut [f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_adopts_first_sample_then_smooths() {
        assert_eq!(ema(0.0, 8.0, 0.5), 8.0);
        assert_eq!(ema(8.0, 4.0, 0.5), 6.0);
        assert_eq!(ema(6.0, 6.0, 0.25), 6.0);
        // alpha=1 tracks the sample exactly
        assert_eq!(ema(3.0, 9.0, 1.0), 9.0);
    }

    #[test]
    fn accumulates_and_orders() {
        let mut p = PhaseTimes::new();
        p.add("b", Duration::from_millis(10));
        p.add("a", Duration::from_millis(5));
        p.add("b", Duration::from_millis(10));
        let names: Vec<_> = p.iter().map(|(n, _)| n.to_string()).collect();
        assert_eq!(names, vec!["b", "a"]); // insertion order
        assert_eq!(p.get("b").unwrap(), Duration::from_millis(20));
        assert_eq!(p.total(), Duration::from_millis(25));
    }

    #[test]
    fn time_charges_phase() {
        let mut p = PhaseTimes::new();
        let v = p.time("work", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(p.get("work").unwrap() >= Duration::from_millis(4));
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimes::new();
        a.add("x", Duration::from_millis(1));
        let mut b = PhaseTimes::new();
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        a.merge(&b);
        assert_eq!(a.get("x").unwrap(), Duration::from_millis(3));
        assert_eq!(a.get("y").unwrap(), Duration::from_millis(3));
    }

    #[test]
    fn table_renders_shares() {
        let mut p = PhaseTimes::new();
        p.add("alpha", Duration::from_millis(75));
        p.add("beta", Duration::from_millis(25));
        let t = p.table("phases");
        assert!(t.contains("alpha"));
        assert!(t.contains("75.0%"));
        assert!(t.contains("TOTAL"));
    }

    #[test]
    fn prometheus_lines_are_labelled_and_escaped() {
        let mut p = PhaseTimes::new();
        p.add("create model", Duration::from_millis(1500));
        p.add("weird \"phase\"", Duration::from_millis(250));
        let text = p.to_prometheus("bfast_run_phase_seconds");
        assert!(text.contains("bfast_run_phase_seconds{phase=\"create model\"} 1.500000"));
        assert!(text.contains("phase=\"weird \\\"phase\\\"\""));
        assert_eq!(text.lines().count(), 2);
    }

    #[test]
    fn json_roundtrip_is_exact_and_ordered() {
        let mut p = PhaseTimes::new();
        p.add("create model", Duration::from_nanos(1_234_567_891));
        p.add("transfer", Duration::from_nanos(7));
        let text = p.to_json().to_string_compact();
        let back = PhaseTimes::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.get("create model"), Some(Duration::from_nanos(1_234_567_891)));
        assert_eq!(back.get("transfer"), Some(Duration::from_nanos(7)));
        let names: Vec<_> = back.iter().map(|(n, _)| n.to_string()).collect();
        assert_eq!(names, vec!["create model", "transfer"]);
        // serialize → parse → serialize is a fixed point
        assert_eq!(back.to_json().to_string_compact(), text);
        // malformed inputs rejected
        assert!(PhaseTimes::from_json(&crate::json::parse("[1]").unwrap()).is_err());
        assert!(
            PhaseTimes::from_json(&crate::json::parse("{\"x\": -1}").unwrap()).is_err()
        );
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_monotonic() {
        let h = Histogram::new(&[0.1, 1.0, 10.0]);
        for s in [0.05, 0.05, 0.5, 2.0, 100.0] {
            h.observe(s);
        }
        assert_eq!(h.count(), 5);
        let mut text = String::new();
        h.render(&mut text, "t_seconds", "test");
        assert!(text.contains("# HELP t_seconds test"));
        assert!(text.contains("# TYPE t_seconds histogram"));
        assert!(text.contains("t_seconds_bucket{le=\"0.1\"} 2"));
        assert!(text.contains("t_seconds_bucket{le=\"1\"} 3"));
        assert!(text.contains("t_seconds_bucket{le=\"10\"} 4"));
        assert!(text.contains("t_seconds_bucket{le=\"+Inf\"} 5"));
        assert!(text.contains("t_seconds_count 5"));
        // cumulative counts never decrease down the bucket list
        let counts: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("t_seconds_bucket"))
            .map(|l| l.split_whitespace().last().unwrap().parse().unwrap())
            .collect();
        assert!(counts.windows(2).all(|w| w[0] <= w[1]));
        // garbage observations are clamped, not panicking
        h.observe(f64::NAN);
        h.observe(-3.0);
        assert_eq!(h.count(), 7);
    }

    #[test]
    fn prom_helpers_emit_help_type_then_sample() {
        let mut s = String::new();
        prom_metric(&mut s, "counter", "x_total", "things", 3.0);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "# HELP x_total things");
        assert_eq!(lines[1], "# TYPE x_total counter");
        assert_eq!(lines[2], "x_total 3");
        let mut b = String::new();
        prom_build_info(&mut b);
        assert!(b.contains("# TYPE bfast_build_info gauge"));
        assert!(b.contains(concat!("version=\"", env!("CARGO_PKG_VERSION"), "\"")));
        assert!(b.contains("} 1"));
    }
}
