//! The **flight recorder**: request-scoped tracing spans plus leveled
//! structured logging, threaded through every layer with zero
//! dependencies.
//!
//! Two halves, one module:
//!
//! * **Spans** — a [`Recorder`] is minted per run (keyed by its
//!   request id) and collects a tree of [`SpanRecord`]s —
//!   **run → shard → chunk → phase** — with integer-ns start/end
//!   stamps on a shared epoch clock ([`now_ns`]). [`Span`] is an RAII
//!   guard: creating one makes it the thread's *current* span (so
//!   children parent automatically), dropping it stamps the end time
//!   and hands the record to a per-thread buffer that drains into the
//!   recorder's bounded ring (drop-oldest beyond
//!   [`Recorder::capacity`]). Cross-thread parenting goes through
//!   [`SpanHandle`] (capture on the submitting thread, adopt on the
//!   executor thread) — this is how the coordinator's scoped executor
//!   thread hangs chunk spans under the serve scheduler's run span.
//!   The whole tree exports as Chrome trace-event JSON
//!   ([`Recorder::to_chrome_trace`]) — loadable in Perfetto / DevTools
//!   — and the gateway merges its workers' exports into one
//!   distributed trace (`GET /v1/runs/{id}/trace`).
//! * **Logs** — [`log!`] emits one structured record per line to
//!   stderr: JSON (`{"ts_ns":..,"level":"info","target":"gateway",
//!   "event":"worker_down",...}`) or `key=value` text, selected
//!   process-wide by [`set_log_format`] (the `--log-format` flag on
//!   serve/gateway). Records below [`set_log_level`] are skipped
//!   before any formatting work.
//!
//! Tracing is **on by default** and can be disabled process-wide
//! ([`set_enabled`], the `--trace off` flag): every span constructor
//! is a no-op behind one relaxed atomic load, so the fused-engine hot
//! path (which routes every phase through
//! [`crate::metrics::PhaseTimes::time`] → [`phase_scope`]) pays
//! nothing measurable when the recorder is off — pinned by the bench
//! trajectory gate.

use crate::json::Value;
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::Instant;

// -- clock ---------------------------------------------------------------

/// Monotonic nanoseconds on the unix epoch: the process captures one
/// `(SystemTime, Instant)` anchor, then every stamp is epoch base +
/// monotonic elapsed. Monotonic within a process, comparable across
/// processes to clock-sync accuracy — which is what lets one gateway
/// trace interleave spans from several worker processes on a shared
/// timeline.
pub fn now_ns() -> u64 {
    static ANCHOR: OnceLock<(u64, Instant)> = OnceLock::new();
    let (epoch_ns, at) = ANCHOR.get_or_init(|| {
        let epoch = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        (epoch, Instant::now())
    });
    epoch_ns + at.elapsed().as_nanos() as u64
}

/// A process-unique-ish request id: epoch-ns entropy mixed with a
/// process-wide counter through splitmix64, rendered as 16 hex chars.
/// Minted at every front door that receives a request without one.
pub fn new_request_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let mut x = now_ns() ^ ((std::process::id() as u64) << 32);
    x = x.wrapping_add(SEQ.fetch_add(1, Ordering::Relaxed).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    // splitmix64 finaliser
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    format!("{x:016x}")
}

// -- process-wide switches ----------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(true);
static LOG_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static LOG_JSON: AtomicBool = AtomicBool::new(true);

/// Enable/disable span recording process-wide (`--trace on|off`).
/// Disabled, every span constructor returns `None` behind a single
/// relaxed load.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Severity of one log record, `Error` most severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }

    /// Parse a `--log-level` value.
    pub fn parse(s: &str) -> crate::Result<Level> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            other => crate::error::bail!(
                "unknown log level {other:?} (error|warn|info|debug|trace)"
            ),
        })
    }
}

/// Drop log records below `level` (`--log-level`).
pub fn set_log_level(level: Level) {
    LOG_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Is a record at `level` currently emitted? (The [`log!`] macro
/// checks this before doing any formatting work.)
pub fn level_enabled(level: Level) -> bool {
    level as u8 <= LOG_LEVEL.load(Ordering::Relaxed)
}

/// Select the log line format: `"json"` (one object per line — the
/// default, grep-able in CI) or `"text"` (`key=value` pairs).
pub fn set_log_format(format: &str) -> crate::Result<()> {
    match format {
        "json" => LOG_JSON.store(true, Ordering::Relaxed),
        "text" => LOG_JSON.store(false, Ordering::Relaxed),
        other => crate::error::bail!("unknown log format {other:?} (json|text)"),
    }
    Ok(())
}

// -- structured logging --------------------------------------------------

/// A typed field value for [`log!`] records.
#[derive(Clone, Debug)]
pub enum FieldValue {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl From<&str> for FieldValue {
    fn from(s: &str) -> Self {
        FieldValue::Str(s.to_string())
    }
}
impl From<String> for FieldValue {
    fn from(s: String) -> Self {
        FieldValue::Str(s)
    }
}
impl From<&String> for FieldValue {
    fn from(s: &String) -> Self {
        FieldValue::Str(s.clone())
    }
}
impl From<u64> for FieldValue {
    fn from(n: u64) -> Self {
        FieldValue::Num(n as f64)
    }
}
impl From<usize> for FieldValue {
    fn from(n: usize) -> Self {
        FieldValue::Num(n as f64)
    }
}
impl From<u32> for FieldValue {
    fn from(n: u32) -> Self {
        FieldValue::Num(n as f64)
    }
}
impl From<i64> for FieldValue {
    fn from(n: i64) -> Self {
        FieldValue::Num(n as f64)
    }
}
impl From<f64> for FieldValue {
    fn from(n: f64) -> Self {
        FieldValue::Num(n)
    }
}
impl From<bool> for FieldValue {
    fn from(b: bool) -> Self {
        FieldValue::Bool(b)
    }
}

impl FieldValue {
    fn to_value(&self) -> Value {
        match self {
            FieldValue::Str(s) => Value::Str(s.clone()),
            FieldValue::Num(n) => Value::Num(*n),
            FieldValue::Bool(b) => Value::Bool(*b),
        }
    }

    fn to_text(&self) -> String {
        match self {
            FieldValue::Str(s) if s.contains(' ') || s.is_empty() => format!("{s:?}"),
            FieldValue::Str(s) => s.clone(),
            FieldValue::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n}")
                }
            }
            FieldValue::Bool(b) => b.to_string(),
        }
    }
}

/// Emit one structured log record (called through [`log!`], which
/// performs the level check first). One line per record, written to
/// stderr in a single `eprintln!` so concurrent threads never
/// interleave mid-line.
pub fn log_record(level: Level, target: &str, event: &str, fields: &[(&str, FieldValue)]) {
    if LOG_JSON.load(Ordering::Relaxed) {
        let mut pairs: Vec<(String, Value)> = vec![
            ("ts_ns".into(), Value::Num(now_ns() as f64)),
            ("level".into(), Value::Str(level.as_str().into())),
            ("target".into(), Value::Str(target.into())),
            ("event".into(), Value::Str(event.into())),
        ];
        for (k, v) in fields {
            pairs.push((k.to_string(), v.to_value()));
        }
        eprintln!("{}", Value::Obj(pairs).to_string_compact());
    } else {
        let mut line = format!(
            "[{}] {:<5} {target} {event}",
            now_ns(),
            level.as_str().to_ascii_uppercase()
        );
        for (k, v) in fields {
            line.push(' ');
            line.push_str(k);
            line.push('=');
            line.push_str(&v.to_text());
        }
        eprintln!("{line}");
    }
}

/// Structured logging: `log!(Info, "serve", "job_done", "job" => id,
/// "request_id" => rid)`. The level test happens before any argument
/// evaluation beyond the match, so disabled levels cost one atomic
/// load.
#[macro_export]
macro_rules! trace_log {
    ($lvl:ident, $target:expr, $event:expr $(, $k:literal => $v:expr)* $(,)?) => {{
        if $crate::trace::level_enabled($crate::trace::Level::$lvl) {
            $crate::trace::log_record(
                $crate::trace::Level::$lvl,
                $target,
                $event,
                &[ $( ($k, $crate::trace::FieldValue::from($v)) ),* ],
            );
        }
    }};
}

pub use crate::trace_log as log;

// -- span records ---------------------------------------------------------

/// One finished span, as stored in a recorder's ring.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub id: u64,
    /// 0 = a root span.
    pub parent: u64,
    pub name: String,
    /// Epoch nanoseconds ([`now_ns`]).
    pub start_ns: u64,
    pub end_ns: u64,
    /// Small process-local thread index (stable per thread).
    pub tid: u64,
    pub attrs: Vec<(String, String)>,
}

struct Ring {
    records: Vec<SpanRecord>,
    start: usize, // ring head when full
    dropped: u64,
}

struct RecorderInner {
    request_id: String,
    capacity: usize,
    next_id: AtomicU64,
    ring: Mutex<Ring>,
}

/// The per-run span sink: a bounded ring of [`SpanRecord`]s keyed by
/// one request id. Cloning shares the sink (the serve queue keeps one
/// clone in the job record while the scheduler thread records into
/// another).
#[derive(Clone)]
pub struct Recorder(Arc<RecorderInner>);

/// Default ring capacity: enough for tens of thousands of chunk×phase
/// spans before drop-oldest kicks in.
pub const DEFAULT_CAPACITY: usize = 65_536;

impl Recorder {
    /// A new recorder for one run, or `None` when tracing is disabled
    /// process-wide — callers thread the `Option` through untouched.
    pub fn new(request_id: &str) -> Option<Recorder> {
        if !enabled() {
            return None;
        }
        Some(Self::with_capacity(request_id, DEFAULT_CAPACITY))
    }

    pub fn with_capacity(request_id: &str, capacity: usize) -> Recorder {
        Recorder(Arc::new(RecorderInner {
            request_id: request_id.to_string(),
            capacity: capacity.max(16),
            next_id: AtomicU64::new(1),
            ring: Mutex::new(Ring { records: Vec::new(), start: 0, dropped: 0 }),
        }))
    }

    pub fn request_id(&self) -> &str {
        &self.0.request_id
    }

    pub fn capacity(&self) -> usize {
        self.0.capacity
    }

    fn alloc_id(&self) -> u64 {
        self.0.next_id.fetch_add(1, Ordering::Relaxed)
    }

    fn push_batch(&self, batch: &mut Vec<SpanRecord>) {
        let mut ring = self.0.ring.lock().unwrap();
        for rec in batch.drain(..) {
            if ring.records.len() < self.0.capacity {
                ring.records.push(rec);
            } else {
                let at = ring.start;
                ring.records[at] = rec;
                ring.start = (ring.start + 1) % self.0.capacity;
                ring.dropped += 1;
            }
        }
    }

    /// Spans dropped because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.0.ring.lock().unwrap().dropped
    }

    /// Snapshot the finished spans, oldest first. Flushes the calling
    /// thread's pending buffer first; spans finished on *other*
    /// threads that have not flushed yet (fewer than one batch) may
    /// lag until those threads end or flush.
    pub fn records(&self) -> Vec<SpanRecord> {
        flush_thread();
        let ring = self.0.ring.lock().unwrap();
        let mut out = Vec::with_capacity(ring.records.len());
        out.extend_from_slice(&ring.records[ring.start..]);
        out.extend_from_slice(&ring.records[..ring.start]);
        out
    }

    /// Open a span with an explicit parent (0 = root). Prefer
    /// [`Recorder::span`] / [`span_under`] which resolve the parent
    /// for you.
    pub fn span_with_parent(&self, name: &str, parent: u64) -> Span {
        Span {
            rec: self.clone(),
            id: self.alloc_id(),
            parent,
            name: name.to_string(),
            start_ns: now_ns(),
            attrs: Vec::new(),
        }
        .made_current()
    }

    /// Open a span parented under the calling thread's current span
    /// when that span belongs to this recorder (root otherwise).
    pub fn span(&self, name: &str) -> Span {
        let parent = current_for(self).unwrap_or(0);
        self.span_with_parent(name, parent)
    }

    /// Export the ring as Chrome trace-event JSON (the
    /// `chrome://tracing` / Perfetto format): one complete-`"X"` event
    /// per span with μs timestamps, span/parent ids in `args`, plus a
    /// process-name metadata event. `pid` distinguishes processes in a
    /// merged distributed trace (the gateway is 1, workers 2…N).
    pub fn to_chrome_trace(&self, pid: u64, process_name: &str) -> Value {
        let events = chrome_events(&self.records(), pid, process_name);
        Value::obj(vec![
            ("traceEvents", Value::Arr(events)),
            ("displayTimeUnit", Value::Str("ms".into())),
            (
                "otherData",
                Value::obj(vec![
                    ("request_id", Value::Str(self.request_id().into())),
                    ("dropped_spans", Value::Num(self.dropped() as f64)),
                ]),
            ),
        ])
    }
}

/// Lower span records to Chrome trace events (shared by the recorder
/// export and the gateway's multi-process merge, which re-stamps ids
/// before calling this).
pub fn chrome_events(records: &[SpanRecord], pid: u64, process_name: &str) -> Vec<Value> {
    let mut events = Vec::with_capacity(records.len() + 1);
    events.push(Value::obj(vec![
        ("ph", Value::Str("M".into())),
        ("name", Value::Str("process_name".into())),
        ("pid", Value::Num(pid as f64)),
        ("tid", Value::Num(0.0)),
        ("args", Value::obj(vec![("name", Value::Str(process_name.into()))])),
    ]));
    for r in records {
        let mut args = vec![
            ("span_id".to_string(), Value::Num(r.id as f64)),
            ("parent_id".to_string(), Value::Num(r.parent as f64)),
        ];
        for (k, v) in &r.attrs {
            args.push((k.clone(), Value::Str(v.clone())));
        }
        events.push(Value::obj(vec![
            ("ph", Value::Str("X".into())),
            ("name", Value::Str(r.name.clone())),
            ("cat", Value::Str("bfast".into())),
            ("ts", Value::Num(r.start_ns as f64 / 1000.0)),
            ("dur", Value::Num(r.end_ns.saturating_sub(r.start_ns) as f64 / 1000.0)),
            ("pid", Value::Num(pid as f64)),
            ("tid", Value::Num(r.tid as f64)),
            ("args", Value::Obj(args)),
        ]));
    }
    events
}

// -- the RAII span guard --------------------------------------------------

/// An open span: stamps its end time and records itself when dropped.
/// While alive it is the calling thread's *current* span, so nested
/// spans (and [`phase_scope`] calls from the engines) parent under it
/// automatically. Keep the guard on the thread that opened it.
pub struct Span {
    rec: Recorder,
    id: u64,
    parent: u64,
    name: String,
    start_ns: u64,
    attrs: Vec<(String, String)>,
}

impl Span {
    fn made_current(self) -> Span {
        CURRENT.with(|c| {
            c.borrow_mut().push((Arc::downgrade(&self.rec.0), self.id));
        });
        self
    }

    /// Attach a key=value attribute (exported into the Chrome event's
    /// `args`).
    pub fn attr(&mut self, key: &str, value: impl ToString) {
        self.attrs.push((key.to_string(), value.to_string()));
    }

    /// Builder form of [`Span::attr`].
    pub fn with_attr(mut self, key: &str, value: impl ToString) -> Span {
        self.attr(key, value);
        self
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// A cloneable, `Send` reference for parenting spans opened on
    /// other threads under this one.
    pub fn handle(&self) -> SpanHandle {
        SpanHandle { rec: self.rec.clone(), id: self.id }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        CURRENT.with(|c| {
            let mut stack = c.borrow_mut();
            if let Some(at) = stack.iter().rposition(|(_, id)| *id == self.id) {
                stack.remove(at);
            }
        });
        let record = SpanRecord {
            id: self.id,
            parent: self.parent,
            name: std::mem::take(&mut self.name),
            start_ns: self.start_ns,
            end_ns: now_ns(),
            tid: thread_index(),
            attrs: std::mem::take(&mut self.attrs),
        };
        let root = self.parent == 0;
        BATCH.with(|b| {
            let mut batch = b.borrow_mut();
            batch.push(&self.rec, record);
            // flush eagerly when a root span closes: the run is over
            // and the exporter reads the ring next
            if root {
                batch.flush();
            }
        });
    }
}

/// A `Send + Clone` reference to an open (or finished) span, used to
/// parent work that happens on other threads — e.g. the coordinator
/// captures the run span's handle before `thread::scope` and opens
/// chunk spans under it on the executor thread.
#[derive(Clone)]
pub struct SpanHandle {
    rec: Recorder,
    id: u64,
}

impl SpanHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn recorder(&self) -> &Recorder {
        &self.rec
    }

    /// Open a child span under this handle on the calling thread.
    pub fn child(&self, name: &str) -> Span {
        self.rec.span_with_parent(name, self.id)
    }
}

/// Open a span under an optional handle — the `Option`-threading form
/// the coordinator uses (`None` = tracing off, no-op).
pub fn span_under(parent: &Option<SpanHandle>, name: &str) -> Option<Span> {
    parent.as_ref().map(|h| h.child(name))
}

// -- thread-local state ---------------------------------------------------

const BATCH_FLUSH: usize = 64;

/// Per-thread pending records for one recorder; switching recorders
/// (or reaching [`BATCH_FLUSH`], or thread exit) drains into the ring.
struct Batch {
    rec: Option<Recorder>,
    pending: Vec<SpanRecord>,
}

impl Batch {
    fn push(&mut self, rec: &Recorder, record: SpanRecord) {
        let same = self
            .rec
            .as_ref()
            .is_some_and(|r| Arc::ptr_eq(&r.0, &rec.0));
        if !same {
            self.flush();
            self.rec = Some(rec.clone());
        }
        self.pending.push(record);
        if self.pending.len() >= BATCH_FLUSH {
            self.flush();
        }
    }

    fn flush(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        if let Some(rec) = &self.rec {
            rec.push_batch(&mut self.pending);
        } else {
            self.pending.clear();
        }
    }
}

impl Drop for Batch {
    fn drop(&mut self) {
        self.flush(); // scoped executor threads drain on exit
    }
}

thread_local! {
    static BATCH: RefCell<Batch> = RefCell::new(Batch { rec: None, pending: Vec::new() });
    /// Stack of (recorder, span id) — innermost current span last.
    static CURRENT: RefCell<Vec<(Weak<RecorderInner>, u64)>> = const { RefCell::new(Vec::new()) };
}

/// Drain the calling thread's pending span buffer into its ring.
pub fn flush_thread() {
    BATCH.with(|b| b.borrow_mut().flush());
}

/// Small stable per-thread index for trace `tid`s (thread 1, 2, …
/// in first-span order within the process).
fn thread_index() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

/// The calling thread's current span id when it belongs to `rec`.
fn current_for(rec: &Recorder) -> Option<u64> {
    CURRENT.with(|c| {
        let stack = c.borrow();
        let (weak, id) = stack.last()?;
        let alive = weak.upgrade()?;
        Arc::ptr_eq(&alive, &rec.0).then_some(*id)
    })
}

/// A handle to the calling thread's current span, if any — capture
/// before handing work to another thread.
pub fn current_handle() -> Option<SpanHandle> {
    if !enabled() {
        return None;
    }
    CURRENT.with(|c| {
        let stack = c.borrow();
        let (weak, id) = stack.last()?;
        let rec = weak.upgrade()?;
        Some(SpanHandle { rec: Recorder(rec), id: *id })
    })
}

/// Open a phase span under the calling thread's current span — the
/// single hook [`crate::metrics::PhaseTimes::time`] routes every
/// backend's phase timings through. No current span (bare engine
/// runs, tracing off) → `None` at the cost of one atomic load and a
/// TLS peek.
pub fn phase_scope(name: &str) -> Option<Span> {
    if !enabled() {
        return None;
    }
    let handle = current_handle()?;
    Some(handle.child(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_ids_are_unique_hex() {
        let a = new_request_id();
        let b = new_request_id();
        assert_ne!(a, b);
        assert_eq!(a.len(), 16);
        assert!(a.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn span_tree_records_parenting() {
        let rec = Recorder::with_capacity("req-1", 128);
        {
            let root = rec.span("run").with_attr("job", 7);
            let root_id = root.id();
            {
                let chunk = rec.span("chunk");
                assert_eq!(chunk.parent, root_id);
                let phase = rec.span("phase");
                assert_eq!(phase.parent, chunk.id());
            }
            // after inner guards drop, the root is current again
            let sibling = rec.span("chunk2");
            assert_eq!(sibling.parent, root_id);
        }
        let records = rec.records();
        assert_eq!(records.len(), 4);
        let root = records.iter().find(|r| r.name == "run").unwrap();
        assert_eq!(root.parent, 0);
        assert!(root.attrs.iter().any(|(k, v)| k == "job" && v == "7"));
        for r in &records {
            assert!(r.end_ns >= r.start_ns);
        }
        let chunk = records.iter().find(|r| r.name == "chunk").unwrap();
        let phase = records.iter().find(|r| r.name == "phase").unwrap();
        assert_eq!(chunk.parent, root.id);
        assert_eq!(phase.parent, chunk.id);
    }

    #[test]
    fn cross_thread_parenting_via_handle() {
        let rec = Recorder::with_capacity("req-2", 128);
        let root = rec.span("run");
        let handle = root.handle();
        std::thread::spawn(move || {
            let _chunk = handle.child("chunk").with_attr("index", 0);
            // phase_scope on the worker thread parents under the chunk
            let phase = phase_scope("model");
            assert!(phase.is_some());
        })
        .join()
        .unwrap();
        drop(root);
        let records = rec.records();
        let root = records.iter().find(|r| r.name == "run").unwrap();
        let chunk = records.iter().find(|r| r.name == "chunk").unwrap();
        let phase = records.iter().find(|r| r.name == "model").unwrap();
        assert_eq!(chunk.parent, root.id);
        assert_eq!(phase.parent, chunk.id);
        assert_ne!(chunk.tid, root.tid);
    }

    #[test]
    fn ring_drops_oldest_beyond_capacity() {
        let rec = Recorder::with_capacity("req-3", 16);
        for i in 0..40 {
            let _s = rec.span(&format!("s{i}"));
        }
        let records = rec.records();
        assert_eq!(records.len(), 16);
        assert_eq!(rec.dropped(), 24);
        // oldest dropped, newest kept, order preserved
        assert_eq!(records.last().unwrap().name, "s39");
        assert_eq!(records.first().unwrap().name, "s24");
    }

    #[test]
    fn chrome_export_shape() {
        let rec = Recorder::with_capacity("req-4", 32);
        {
            let _root = rec.span("run");
            let _child = rec.span("chunk");
        }
        let v = rec.to_chrome_trace(1, "serve 127.0.0.1:7878");
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3); // metadata + 2 spans
        assert_eq!(events[0].get("ph").unwrap().as_str().unwrap(), "M");
        let span = &events[1];
        assert_eq!(span.get("ph").unwrap().as_str().unwrap(), "X");
        assert!(span.get("ts").unwrap().as_f64().unwrap() > 0.0);
        assert!(span.get("args").unwrap().get("span_id").is_ok());
        assert_eq!(
            v.get("otherData").unwrap().get("request_id").unwrap().as_str().unwrap(),
            "req-4"
        );
        // the export is valid JSON that re-parses
        let text = v.to_string_compact();
        assert!(crate::json::parse(&text).is_ok());
    }

    #[test]
    fn phase_scope_is_noop_without_a_current_span() {
        assert!(phase_scope("model").is_none());
    }

    #[test]
    fn levels_parse_and_order() {
        assert!(Level::parse("WARN").unwrap() == Level::Warn);
        assert!(Level::parse("nope").is_err());
        assert!(Level::Error < Level::Trace);
    }
}
