//! Wall-clock benchmark harness (replaces `criterion` for the offline
//! build; `cargo bench` targets use `harness = false` and call into
//! this).
//!
//! Methodology: `warmup` unmeasured runs, then `samples` measured
//! runs; report median and MAD (robust to scheduler noise). Sample
//! counts adapt to a target time budget so big-m cases don't explode
//! the bench wall time.

use crate::metrics;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    pub samples: usize,
}

impl Measurement {
    pub fn secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    pub warmup: usize,
    pub max_samples: usize,
    pub min_samples: usize,
    /// Stop sampling when this much time was spent measuring.
    pub budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: 1,
            max_samples: 7,
            min_samples: 3,
            budget: Duration::from_secs(20),
        }
    }
}

impl Bench {
    /// Quick profile for expensive end-to-end cases.
    pub fn quick() -> Self {
        Self { warmup: 1, max_samples: 3, min_samples: 2, budget: Duration::from_secs(10) }
    }

    /// Measure `f` (its return value is black-boxed).
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut secs = Vec::with_capacity(self.max_samples);
        let started = Instant::now();
        while secs.len() < self.max_samples {
            let t0 = Instant::now();
            black_box(f());
            secs.push(t0.elapsed().as_secs_f64());
            if secs.len() >= self.min_samples && started.elapsed() > self.budget {
                break;
            }
        }
        let med = metrics::median(&mut secs.clone());
        let mut devs: Vec<f64> = secs.iter().map(|s| (s - med).abs()).collect();
        let mad = metrics::median(&mut devs);
        Measurement {
            median: Duration::from_secs_f64(med),
            mad: Duration::from_secs_f64(mad),
            samples: secs.len(),
        }
    }
}

/// Opaque value sink (stable `black_box` is available since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Standard bench banner so all figure benches print uniformly.
pub fn banner(fig: &str, what: &str) {
    println!("\n=== {fig}: {what} ===");
    println!(
        "host threads={} | BFAST_BENCH_SCALE={}",
        crate::threadpool::default_threads(),
        bench_scale()
    );
}

/// Global scale factor for bench workloads (`BFAST_BENCH_SCALE`, default
/// 1.0 = paper-shaped but laptop-sized workloads; crank up to approach
/// the paper's m = 10⁶).
pub fn bench_scale() -> f64 {
    std::env::var("BFAST_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&v| v > 0.0)
        .unwrap_or(1.0)
}

/// Scaled pixel count helper.
pub fn scaled_m(base: usize) -> usize {
    ((base as f64 * bench_scale()) as usize).max(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_roughly() {
        let b = Bench { warmup: 0, max_samples: 3, min_samples: 3, budget: Duration::from_secs(5) };
        let m = b.run(|| std::thread::sleep(Duration::from_millis(10)));
        assert!(m.median >= Duration::from_millis(9), "{m:?}");
        assert!(m.median < Duration::from_millis(100), "{m:?}");
        assert_eq!(m.samples, 3);
    }

    #[test]
    fn budget_stops_early() {
        let b = Bench {
            warmup: 0,
            max_samples: 100,
            min_samples: 2,
            budget: Duration::from_millis(30),
        };
        let m = b.run(|| std::thread::sleep(Duration::from_millis(20)));
        assert!(m.samples < 100, "{m:?}");
    }

    #[test]
    fn scale_default_is_one() {
        std::env::remove_var("BFAST_BENCH_SCALE");
        assert_eq!(bench_scale(), 1.0);
        assert_eq!(scaled_m(1000), 1000);
    }
}
