//! Wall-clock benchmark harness (replaces `criterion` for the offline
//! build; `cargo bench` targets use `harness = false` and call into
//! this).
//!
//! Methodology: `warmup` unmeasured runs, then `samples` measured
//! runs; report median and MAD (robust to scheduler noise). Sample
//! counts adapt to a target time budget so big-m cases don't explode
//! the bench wall time.

use crate::metrics;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub median: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    pub samples: usize,
}

impl Measurement {
    pub fn secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    pub warmup: usize,
    pub max_samples: usize,
    pub min_samples: usize,
    /// Stop sampling when this much time was spent measuring.
    pub budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: 1,
            max_samples: 7,
            min_samples: 3,
            budget: Duration::from_secs(20),
        }
    }
}

impl Bench {
    /// Quick profile for expensive end-to-end cases.
    pub fn quick() -> Self {
        Self { warmup: 1, max_samples: 3, min_samples: 2, budget: Duration::from_secs(10) }
    }

    /// Apply `BFAST_BENCH_WARMUP` / `BFAST_BENCH_TRIALS` env overrides
    /// (the `bfast bench` harness pins both for reproducible runs).
    /// A trial override sets `min_samples == max_samples`, so the
    /// measured sample count is exact — the time budget cannot stop a
    /// pinned run short.
    pub fn from_env(mut self) -> Self {
        if let Some(w) = parse_env_usize("BFAST_BENCH_WARMUP") {
            self.warmup = w;
        }
        if let Some(t) = parse_env_usize("BFAST_BENCH_TRIALS") {
            let t = t.max(1);
            self.max_samples = t;
            self.min_samples = t;
            self.budget = Duration::from_secs(u64::MAX / 4);
        }
        self
    }

    /// Measure `f` (its return value is black-boxed).
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut secs = Vec::with_capacity(self.max_samples);
        let started = Instant::now();
        while secs.len() < self.max_samples {
            let t0 = Instant::now();
            black_box(f());
            secs.push(t0.elapsed().as_secs_f64());
            if secs.len() >= self.min_samples && started.elapsed() > self.budget {
                break;
            }
        }
        let med = metrics::median(&mut secs.clone());
        let mut devs: Vec<f64> = secs.iter().map(|s| (s - med).abs()).collect();
        let mad = metrics::median(&mut devs);
        Measurement {
            median: Duration::from_secs_f64(med),
            mad: Duration::from_secs_f64(mad),
            samples: secs.len(),
        }
    }
}

/// Opaque value sink (stable `black_box` is available since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Standard bench banner so all figure benches print uniformly.
pub fn banner(fig: &str, what: &str) {
    println!("\n=== {fig}: {what} ===");
    println!(
        "host threads={} | BFAST_BENCH_SCALE={} | profile={} | rev={}",
        crate::threadpool::default_threads(),
        bench_scale(),
        crate::bench::cargo_profile(),
        crate::bench::git_rev(),
    );
}

/// Parse one positive-usize env override; garbage/absent = `None`.
fn parse_env_usize(name: &str) -> Option<usize> {
    std::env::var(name).ok().and_then(|s| s.trim().parse().ok())
}

/// Global scale factor for bench workloads (`BFAST_BENCH_SCALE`, default
/// 1.0 = paper-shaped but laptop-sized workloads; crank up to approach
/// the paper's m = 10⁶).
///
/// Read **once** per process and latched in a `OnceLock`: every
/// consumer — across harness trials, bench targets and threads — sees
/// the same value even if the environment mutates mid-run (the old
/// per-call read let a `set_var`/`remove_var` race tear the scale
/// between a bench's warmup and its samples).
pub fn bench_scale() -> f64 {
    static SCALE: OnceLock<f64> = OnceLock::new();
    *SCALE.get_or_init(|| parse_scale(std::env::var("BFAST_BENCH_SCALE").ok().as_deref()))
}

/// Pure parse of a `BFAST_BENCH_SCALE` value (split out so the
/// semantics stay unit-testable despite the process-global latch).
fn parse_scale(raw: Option<&str>) -> f64 {
    raw.and_then(|s| s.trim().parse::<f64>().ok())
        .filter(|v| v.is_finite() && *v > 0.0)
        .unwrap_or(1.0)
}

/// Scaled pixel count helper.
pub fn scaled_m(base: usize) -> usize {
    ((base as f64 * bench_scale()) as usize).max(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_roughly() {
        let b = Bench { warmup: 0, max_samples: 3, min_samples: 3, budget: Duration::from_secs(5) };
        let m = b.run(|| std::thread::sleep(Duration::from_millis(10)));
        assert!(m.median >= Duration::from_millis(9), "{m:?}");
        assert!(m.median < Duration::from_millis(100), "{m:?}");
        assert_eq!(m.samples, 3);
    }

    #[test]
    fn budget_stops_early() {
        let b = Bench {
            warmup: 0,
            max_samples: 100,
            min_samples: 2,
            budget: Duration::from_millis(30),
        };
        let m = b.run(|| std::thread::sleep(Duration::from_millis(20)));
        assert!(m.samples < 100, "{m:?}");
    }

    #[test]
    fn parse_scale_handles_defaults_and_garbage() {
        assert_eq!(parse_scale(None), 1.0);
        assert_eq!(parse_scale(Some("")), 1.0);
        assert_eq!(parse_scale(Some("bogus")), 1.0);
        assert_eq!(parse_scale(Some("0")), 1.0);
        assert_eq!(parse_scale(Some("-2")), 1.0);
        assert_eq!(parse_scale(Some("inf")), 1.0);
        assert_eq!(parse_scale(Some("NaN")), 1.0);
        assert_eq!(parse_scale(Some("0.25")), 0.25);
        assert_eq!(parse_scale(Some(" 2 ")), 2.0);
    }

    #[test]
    fn scale_is_read_once_per_process() {
        // Latch whatever the process started with, then mutate the
        // env: the latched value must not move (the race this fixes).
        let first = bench_scale();
        std::env::set_var("BFAST_BENCH_SCALE", "1e9");
        assert_eq!(bench_scale(), first);
        std::env::remove_var("BFAST_BENCH_SCALE");
        assert_eq!(bench_scale(), first);
        assert_eq!(scaled_m(1000), ((1000.0 * first) as usize).max(16));
    }

    #[test]
    fn from_env_overrides_trials_and_warmup() {
        // run serially with env mutation contained to this test
        std::env::set_var("BFAST_BENCH_WARMUP", "0");
        std::env::set_var("BFAST_BENCH_TRIALS", "2");
        let b = Bench::quick().from_env();
        assert_eq!(b.warmup, 0);
        assert_eq!(b.max_samples, 2);
        assert_eq!(b.min_samples, 2);
        std::env::remove_var("BFAST_BENCH_WARMUP");
        std::env::remove_var("BFAST_BENCH_TRIALS");
        let m = b.run(|| std::thread::sleep(Duration::from_millis(1)));
        assert_eq!(m.samples, 2, "pinned trial count is exact");
        let c = Bench::quick().from_env();
        assert_eq!(c.warmup, Bench::quick().warmup, "no env = no override");
    }
}
