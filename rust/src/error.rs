//! Crate-local error substrate (replaces `anyhow` for the offline
//! build — the last external dependency of the default feature set).
//!
//! [`BfastError`] is a rendered message plus a stack of context
//! frames. The surface mirrors the subset of `anyhow` the crate used:
//!
//! * `Result<T>` — crate-wide result alias;
//! * [`bail!`] / [`ensure!`] / [`err!`] — early-return, assertion and
//!   ad-hoc error construction macros (`err!` is the `anyhow!`
//!   analogue);
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   and `Option`.
//!
//! Display semantics match `anyhow`: `{}` prints the outermost
//! message, `{:#}` prints the full chain outermost-first joined with
//! `": "`.

use std::fmt;

/// Crate-wide result type.
pub type Result<T, E = BfastError> = std::result::Result<T, E>;

pub use crate::{bail, ensure, err};

/// The crate error: a root cause plus zero or more context frames
/// (innermost first in `frames`; the *last* frame is outermost).
pub struct BfastError {
    root: String,
    frames: Vec<String>,
}

impl BfastError {
    /// Build an error from a rendered message.
    pub fn msg(message: impl Into<String>) -> Self {
        Self { root: message.into(), frames: Vec::new() }
    }

    /// Attach an outer context frame (most recent = outermost).
    pub fn push_context(mut self, ctx: impl fmt::Display) -> Self {
        self.frames.push(ctx.to_string());
        self
    }

    /// The root-cause message (innermost).
    pub fn root_cause(&self) -> &str {
        &self.root
    }

    /// Context frames, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().rev().map(String::as_str).chain(std::iter::once(self.root.as_str()))
    }
}

impl fmt::Display for BfastError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain: outer: ... : root
            let mut first = true;
            for part in self.chain() {
                if !first {
                    f.write_str(": ")?;
                }
                f.write_str(part)?;
                first = false;
            }
            Ok(())
        } else {
            // `{}` — outermost message only
            f.write_str(self.frames.last().map(String::as_str).unwrap_or(&self.root))
        }
    }
}

impl fmt::Debug for BfastError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Outermost message, then the cause chain (anyhow-style), so
        // `unwrap()` panics carry the whole story.
        write!(f, "{}", self.frames.last().map(String::as_str).unwrap_or(&self.root))?;
        let mut rest: Vec<&str> = self.chain().skip(1).collect();
        if !rest.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, part) in rest.drain(..).enumerate() {
                write!(f, "\n    {i}: {part}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts into a BfastError by rendering its message.
// (BfastError deliberately does NOT implement std::error::Error, which
// is what keeps this blanket impl coherent — the same trick anyhow
// uses.)
impl<E: std::error::Error> From<E> for BfastError {
    fn from(e: E) -> Self {
        BfastError::msg(e.to_string())
    }
}

/// Context attachment for `Result` and `Option` (anyhow-compatible
/// call sites: `.context("...")` / `.with_context(|| format!(...))`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<BfastError>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| BfastError::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| BfastError::msg(f().to_string()))
    }
}

/// Construct a [`BfastError`] from a format string (the `anyhow!`
/// analogue).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::BfastError::msg(format!($($arg)*))
    };
}

/// Return early with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::BfastError::msg(format!($($arg)*)).into())
    };
}

/// Return early with a formatted error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let e = std::fs::read("/definitely/not/a/path").unwrap_err();
        Err(e.into())
    }

    #[test]
    fn display_outermost_alternate_full_chain() {
        let e = BfastError::msg("root").push_context("mid").push_context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by") && dbg.contains("root"));
    }

    #[test]
    fn std_errors_convert_and_take_context() {
        let e = fails_io().context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").starts_with("reading config: "));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        let v: Option<u32> = Some(3);
        assert_eq!(v.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative input -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big: 101");
        let e = err!("ad hoc {}", 7);
        assert_eq!(e.to_string(), "ad hoc 7");
    }

    #[test]
    fn bare_ensure_reports_condition() {
        fn f() -> Result<()> {
            let x = 1;
            ensure!(x == 2);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("x == 2"));
    }
}
