//! Incremental monitoring sessions — the near-real-time workload the
//! paper's speed makes practical.
//!
//! A fresh [`crate::coordinator::BfastRunner::run`] refits the history
//! OLS model and replays the full MOSUM for every pixel on every
//! invocation. Operationally, though, a new satellite layer arrives
//! every 8–16 days and only the monitor period grows: the history fit
//! is fixed. A [`MonitorSession`] runs the one-time **history pass**
//! (β̂, σ̂ and, where requested, a ROC-trimmed stable history) through
//! the same staged chunk plan the coordinator uses, then caches the
//! per-pixel rolling state —
//!
//! * β̂ (p × m, f32) and σ̂√n (f64) from the history fit,
//! * the last-`h` residual window (the MOSUM ring),
//! * the rolling accumulator `acc`, running `momax` and the
//!   first-break index,
//! * the forward-fill value for gap handling (paper footnote 2) —
//!
//! so [`MonitorSession::ingest`] advances every pixel in **O(m·p)**
//! with no refit. The history pass and the backfill rebuild of
//! late-reporting pixels *are* `cpu::FusedCpuBfast` — the session
//! calls [`crate::cpu::FusedCpuBfast::run_with_state`] and adopts the
//! engine's final rolling state verbatim, so there is one definition
//! of the scene arithmetic and after ingesting layers `n+1..=N` the
//! session's break map is **bit-identical** to a fresh coordinated
//! run at N, at every prefix. The equivalence is pinned by
//! `tests/monitor.rs`.
//!
//! Sessions persist to a state directory (`session.json` +
//! `state_*.bten` tensors) and resume exactly; see the README's
//! monitoring-workflow section and the `bfast monitor` CLI.

use crate::cpu::FusedCpuBfast;
use crate::design;
use crate::error::{ensure, Context, Result};
use crate::fill;
use crate::history::RocScanner;
use crate::json::{self, Value};
use crate::mosum;
use crate::params::BfastParams;
use crate::raster::{BreakMap, TimeStack};
use crate::runtime::bten::{read_bten, write_bten, Tensor};
use crate::threadpool::{self, SyncSlice};
use std::path::Path;

/// State-file schema version (bump on layout changes).
const STATE_VERSION: f64 = 1.0;

/// Session tuning. `m_chunk` grains each ingest across the
/// threadpool (the history pass runs through the fused engine, which
/// blocks internally); `fill_missing` mirrors
/// [`crate::coordinator::RunnerConfig::fill_missing`] and must match
/// the runs the session is compared against.
#[derive(Clone, Debug)]
pub struct MonitorConfig {
    /// Pixels per ingest work range (the coordinator's chunk width).
    pub m_chunk: usize,
    /// Worker threads for the history pass and per-layer updates.
    pub threads: usize,
    /// Forward/backward-fill NaN observations (paper footnote 2).
    pub fill_missing: bool,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        Self {
            m_chunk: crate::runtime::emulated::DEFAULT_M_CHUNK,
            threads: threadpool::default_threads(),
            fill_missing: true,
        }
    }
}

/// What one ingested layer changed.
#[derive(Clone, Debug)]
pub struct IngestDelta {
    /// 0-based row index of the ingested layer in the grown stack.
    pub layer: usize,
    /// Acquisition time (after the chunk contract's f32 rounding).
    pub t: f64,
    /// 0-based monitor index of the layer (t = n + 1 + monitor_index).
    pub monitor_index: usize,
    /// Pixels that became broken with this layer's ingest. Usually
    /// their first crossing is at `monitor_index`; a late-reporting
    /// pixel whose rebuilt (backfilled) history crosses earlier is
    /// still reported here, on the layer that revealed it.
    pub new_breaks: Vec<usize>,
    /// Total broken pixels after this layer.
    pub total_breaks: usize,
}

impl IngestDelta {
    /// JSON form for the serving API (`POST /v1/sessions/{name}/ingest`).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("layer", Value::Num(self.layer as f64)),
            ("t", Value::Num(self.t)),
            ("monitor_index", Value::Num(self.monitor_index as f64)),
            ("new_breaks", Value::arr_usize(&self.new_breaks)),
            ("total_breaks", Value::Num(self.total_breaks as f64)),
        ])
    }
}

/// Result of a scene-wide ROC (reverse-ordered CUSUM) pre-pass.
#[derive(Clone, Debug)]
pub struct RocSelection {
    /// Per-pixel 0-based index where the stable history begins.
    pub starts: Vec<usize>,
    /// The start chosen at the requested quantile (shared by the
    /// batched fit — the paper's pipeline uses one n per scene).
    pub chosen: usize,
}

/// An incremental BFAST(monitor) session. See module docs.
pub struct MonitorSession {
    /// Analysis parameters with the chunk contract's f32 rounding
    /// applied to `freq`/`lambda`; `n_total` tracks the layers seen.
    params: BfastParams,
    cfg: MonitorConfig,
    m: usize,
    width: Option<usize>,
    height: Option<usize>,
    /// f32-rounded acquisition times of every layer seen.
    axis: Vec<f64>,
    /// Xᵀ rows (n_seen × p, f32) — grows one row per ingest.
    xt: Vec<f32>,
    /// β̂ (p × m, f32).
    beta: Vec<f32>,
    /// σ̂√n per pixel (Eq. 3 denominator).
    sigma_denom: Vec<f64>,
    /// Rolling MOSUM accumulator per pixel.
    acc: Vec<f64>,
    /// Last-h residual rows (h × m, f32); row r lives at slot r % h.
    ring: Vec<f32>,
    /// Running max |MO_t| per pixel.
    momax: Vec<f32>,
    /// First-crossing monitor index per pixel, -1 when unbroken.
    first: Vec<i32>,
    /// Last valid (non-NaN) raw observation per pixel; NaN when the
    /// pixel has never reported (forward-fill state).
    last_valid: Vec<f32>,
}

impl MonitorSession {
    /// Run the one-time history pass over an initial archive and open
    /// the session. `stack` must hold `params.n_total` layers with at
    /// least one monitor layer (`n_total > n_hist`); the resulting
    /// state is exactly what a fresh coordinated run produces at this
    /// prefix.
    pub fn start(stack: &TimeStack, params: &BfastParams, cfg: MonitorConfig) -> Result<Self> {
        params.validate()?;
        ensure!(cfg.m_chunk >= 1, "m_chunk must be >= 1");
        ensure!(
            stack.n_times() == params.n_total,
            "stack has {} layers, params expect N={}",
            stack.n_times(),
            params.n_total
        );
        // The chunk contract ships freq/lambda/t as f32 — apply the
        // same rounding so the session agrees with the pipeline.
        let params = BfastParams::with_lambda(
            params.n_total,
            params.n_hist,
            params.h,
            params.k,
            (params.freq as f32) as f64,
            params.alpha,
            (params.lambda as f32) as f64,
        )?;
        let axis: Vec<f64> = stack.time_axis.iter().map(|&v| (v as f32) as f64).collect();
        ensure!(
            axis.windows(2).all(|w| w[1] > w[0]),
            "monitor session: time axis collapses under f32 rounding"
        );
        // the history pseudo-inverse lives inside the fused engine
        // (prime / rebuild construct it on demand); the session only
        // keeps the prediction rows Xᵀ for the O(p) ingest step
        let x = design::design_matrix(&axis, params.freq, params.k);
        let xt = x.transpose().to_f32();

        let m = stack.n_pixels();
        let mut session = Self {
            m,
            width: stack.width,
            height: stack.height,
            axis,
            xt,
            beta: vec![0.0; params.p() * m],
            sigma_denom: vec![0.0; m],
            acc: vec![0.0; m],
            ring: vec![0.0; params.h * m],
            momax: vec![0.0; m],
            first: vec![-1; m],
            last_valid: vec![f32::NAN; m],
            params,
            cfg,
        };
        session.prime(stack)?;
        Ok(session)
    }

    /// The one-time history pass: record the forward-fill state from
    /// the raw archive, gap-fill a scene copy, then run the fused
    /// engine once and adopt its final rolling state — the engine is
    /// the single definition of the arithmetic, so prime cannot drift
    /// from a fresh run.
    fn prime(&mut self, stack: &TimeStack) -> Result<()> {
        let n0 = self.params.n_total;
        let m = self.m;
        let raw = stack.data();
        self.last_valid = threadpool::parallel_map(m, self.cfg.threads, |px| {
            for t in (0..n0).rev() {
                let v = raw[t * m + px];
                if !v.is_nan() {
                    return v;
                }
            }
            f32::NAN
        });
        let mut data = raw.to_vec();
        if self.cfg.fill_missing {
            fill::fill_columns(&mut data, n0, m);
        }
        let filled = TimeStack::from_vec(n0, m, data)?;
        let engine =
            FusedCpuBfast::new(self.params.clone(), &self.axis)?.with_threads(self.cfg.threads);
        let (map, _times, state) = engine.run_with_state(&filled)?;
        self.beta = state.beta;
        self.sigma_denom = state.sigma_denom;
        self.acc = state.acc;
        self.ring = state.ring;
        self.momax = map.momax;
        self.first = map.first;
        Ok(())
    }

    /// Ingest one acquisition layer at time `t`, advancing every pixel
    /// in O(p) without refitting. Returns what changed.
    pub fn ingest(&mut self, t: f64, layer: &[f32]) -> Result<IngestDelta> {
        ensure!(
            layer.len() == self.m,
            "layer has {} values, session monitors {} pixels",
            layer.len(),
            self.m
        );
        let t_r = (t as f32) as f64;
        let last = *self.axis.last().expect("session holds >= n+1 layers");
        ensure!(
            t_r > last,
            "layer time {t} does not extend the series (last = {last}, f32-rounded)"
        );
        // extend the design one row
        let x1 = design::design_matrix(&[t_r], self.params.freq, self.params.k);
        let p = self.params.p();
        for i in 0..p {
            self.xt.push(x1[(i, 0)] as f32);
        }
        self.axis.push(t_r);
        self.params.n_total = self.axis.len();

        let r = self.axis.len() - 1; // new 0-based row index
        let (n, h, m) = (self.params.n_hist, self.params.h, self.m);
        let ti = r - n;
        let slot = r % h;
        let bound = mosum::boundary_at(&self.params, ti) as f32;
        let fill_missing = self.cfg.fill_missing;
        let plan_grain = self.cfg.m_chunk;
        let threads = self.cfg.threads;
        // Snapshot which pixels were already broken: a late-reporting
        // pixel's rebuilt history can cross at an *earlier* monitor
        // index than ti, and must still surface in this layer's delta.
        let was_broken: Vec<bool> = self.first.iter().map(|&f| f >= 0).collect();

        // Pixels whose first valid value ever arrives with this layer:
        // a fresh run would have backfilled their whole prefix with it.
        // Rebuild them through the engine itself — one batched run over
        // a constant-column stack — and adopt its state, exactly as
        // prime does (column independence of the GEMM keeps each pixel
        // bit-identical to a scene-wide fresh run).
        if fill_missing {
            let fresh: Vec<usize> = layer
                .iter()
                .enumerate()
                .filter(|&(px, &raw)| !raw.is_nan() && self.last_valid[px].is_nan())
                .map(|(px, _)| px)
                .collect();
            if !fresh.is_empty() {
                let f = fresh.len();
                let mut data = vec![0.0f32; (r + 1) * f];
                for (c, &px) in fresh.iter().enumerate() {
                    for row in 0..r + 1 {
                        data[row * f + c] = layer[px];
                    }
                }
                let series = TimeStack::from_vec(r + 1, f, data)?;
                let engine = FusedCpuBfast::new(self.params.clone(), &self.axis)?
                    .with_threads(self.cfg.threads);
                let (map, _times, st) = engine.run_with_state(&series)?;
                for (c, &px) in fresh.iter().enumerate() {
                    for j in 0..p {
                        self.beta[j * m + px] = st.beta[j * f + c];
                    }
                    self.sigma_denom[px] = st.sigma_denom[c];
                    self.acc[px] = st.acc[c];
                    self.momax[px] = map.momax[c];
                    self.first[px] = map.first[c];
                    for slot in 0..h {
                        self.ring[slot * m + px] = st.ring[slot * f + c];
                    }
                }
            }
        }

        {
            let xrow = &self.xt[r * p..(r + 1) * p];
            let beta_v = SyncSlice::new(&mut self.beta);
            let sigma_v = SyncSlice::new(&mut self.sigma_denom);
            let acc_v = SyncSlice::new(&mut self.acc);
            let ring_v = SyncSlice::new(&mut self.ring);
            let momax_v = SyncSlice::new(&mut self.momax);
            let first_v = SyncSlice::new(&mut self.first);
            let lv_v = SyncSlice::new(&mut self.last_valid);

            threadpool::parallel_ranges(m, plan_grain, threads, |s, e| {
                for px in s..e {
                    let raw = layer[px];
                    let lv = unsafe { lv_v.read(px) };
                    let v = if raw.is_nan() {
                        if fill_missing {
                            lv // forward fill (NaN while the pixel is blank)
                        } else {
                            raw
                        }
                    } else {
                        if fill_missing && lv.is_nan() {
                            // first valid value ever — already rebuilt
                            // through the engine above; only the fill
                            // state still needs recording
                            unsafe { lv_v.write(px, raw) };
                            continue;
                        }
                        unsafe { lv_v.write(px, raw) };
                        raw
                    };
                    // prediction for the new row (GEMM-order dot)
                    let mut yh = 0.0f32;
                    for (j, &av) in xrow.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        yh += av * unsafe { beta_v.read(j * m + px) };
                    }
                    let resid = v - yh;
                    let old = unsafe { ring_v.read(slot * m + px) };
                    let mut acc = unsafe { acc_v.read(px) };
                    let mo =
                        mosum::rolling_step(&mut acc, unsafe { sigma_v.read(px) }, resid, old);
                    unsafe { acc_v.write(px, acc) };
                    let a = mo.abs();
                    if a > unsafe { momax_v.read(px) } {
                        unsafe { momax_v.write(px, a) };
                    }
                    if unsafe { first_v.read(px) } < 0 && a > bound {
                        unsafe { first_v.write(px, ti as i32) };
                    }
                    unsafe { ring_v.write(slot * m + px, resid) };
                }
            });
        }

        let new_breaks: Vec<usize> = self
            .first
            .iter()
            .enumerate()
            .filter(|&(px, &f)| f >= 0 && !was_broken[px])
            .map(|(px, _)| px)
            .collect();
        Ok(IngestDelta {
            layer: r,
            t: t_r,
            monitor_index: ti,
            new_breaks,
            total_breaks: self.break_count(),
        })
    }

    /// Ingest every layer of `stack` whose time extends the session
    /// (layers at or before the last seen time are skipped — re-feeding
    /// a grown archive is the expected CLI workflow).
    pub fn ingest_stack(&mut self, stack: &TimeStack) -> Result<Vec<IngestDelta>> {
        ensure!(
            stack.n_pixels() == self.m,
            "stack has {} pixels, session monitors {}",
            stack.n_pixels(),
            self.m
        );
        let last = *self.axis.last().expect("session holds layers");
        let mut deltas = Vec::new();
        for (tidx, &t) in stack.time_axis.iter().enumerate() {
            if ((t as f32) as f64) <= last {
                continue;
            }
            deltas.push(self.ingest(t, stack.layer(tidx))?);
        }
        Ok(deltas)
    }

    // -- accessors -------------------------------------------------------

    /// Analysis parameters (f32-rounded freq/λ; `n_total` = layers seen).
    pub fn params(&self) -> &BfastParams {
        &self.params
    }

    /// Layers consumed so far (history + monitor).
    pub fn n_seen(&self) -> usize {
        self.axis.len()
    }

    pub fn n_pixels(&self) -> usize {
        self.m
    }

    /// Scene geometry, when the initial stack carried one.
    pub fn geometry(&self) -> (Option<usize>, Option<usize>) {
        (self.width, self.height)
    }

    /// f32-rounded acquisition times of every layer seen.
    pub fn time_axis(&self) -> &[f64] {
        &self.axis
    }

    /// Broken pixels so far.
    pub fn break_count(&self) -> usize {
        self.first.iter().filter(|&&f| f >= 0).count()
    }

    /// The current break map — bit-identical to a fresh coordinated
    /// run over the same (grown) archive.
    pub fn break_map(&self) -> BreakMap {
        BreakMap {
            breaks: self.first.iter().map(|&f| (f >= 0) as i32).collect(),
            first: self.first.clone(),
            momax: self.momax.clone(),
        }
    }

    // -- persistence -----------------------------------------------------

    /// Save the session to a state directory (`session.json` +
    /// `state_*.bten`). Resuming via [`MonitorSession::load`] restores
    /// the exact state: ingest after a round-trip is bit-identical to
    /// an uninterrupted session.
    ///
    /// The write is staged: everything lands in a `<dir>.tmp` sibling
    /// first and the directories are swapped at the end, so a crash
    /// mid-save can never leave a mixed-generation state directory
    /// (whose tensors mostly have n-independent shapes and would load
    /// without complaint).
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<()> {
        // normalise away trailing separators so the staging siblings
        // ("<dir>.tmp"/"<dir>.old") never land *inside* the target
        let dir: std::path::PathBuf = dir.as_ref().components().collect();
        let dir = dir.as_path();
        let sibling = |suffix: &str| {
            let mut s = dir.as_os_str().to_os_string();
            s.push(suffix);
            std::path::PathBuf::from(s)
        };
        let tmp = sibling(".tmp");
        let old = sibling(".old");
        if tmp.exists() {
            std::fs::remove_dir_all(&tmp)
                .with_context(|| format!("clearing stale {}", tmp.display()))?;
        }
        if old.exists() {
            std::fs::remove_dir_all(&old)
                .with_context(|| format!("clearing stale {}", old.display()))?;
        }
        self.write_state_files(&tmp)?;
        if dir.exists() {
            std::fs::rename(dir, &old)
                .with_context(|| format!("retiring previous state {}", dir.display()))?;
        }
        std::fs::rename(&tmp, dir)
            .with_context(|| format!("activating new state {}", dir.display()))?;
        std::fs::remove_dir_all(&old).ok(); // best-effort cleanup
        Ok(())
    }

    fn write_state_files(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating state dir {}", dir.display()))?;
        let p = self.params.p();
        let (n, h) = (self.params.n_hist, self.params.h);
        let mut meta = vec![
            ("version", Value::Num(STATE_VERSION)),
            ("n_seen", Value::Num(self.axis.len() as f64)),
            ("n_hist", Value::Num(n as f64)),
            ("h", Value::Num(h as f64)),
            ("k", Value::Num(self.params.k as f64)),
            ("freq", Value::Num(self.params.freq)),
            ("alpha", Value::Num(self.params.alpha)),
            ("lambda", Value::Num(self.params.lambda)),
            ("m", Value::Num(self.m as f64)),
            ("m_chunk", Value::Num(self.cfg.m_chunk as f64)),
            ("fill_missing", Value::Bool(self.cfg.fill_missing)),
        ];
        if let (Some(w), Some(hh)) = (self.width, self.height) {
            meta.push(("width", Value::Num(w as f64)));
            meta.push(("height", Value::Num(hh as f64)));
        }
        std::fs::write(dir.join("session.json"), Value::obj(meta).to_string_pretty())
            .with_context(|| format!("writing {}", dir.join("session.json").display()))?;
        let wr = |name: &str, t: &Tensor| write_bten(dir.join(name), t);
        wr(
            "state_axis.bten",
            &Tensor::F64 { shape: vec![self.axis.len()], data: self.axis.clone() },
        )?;
        wr("state_beta.bten", &Tensor::F32 { shape: vec![p, self.m], data: self.beta.clone() })?;
        wr(
            "state_sigma.bten",
            &Tensor::F64 { shape: vec![self.m], data: self.sigma_denom.clone() },
        )?;
        wr("state_acc.bten", &Tensor::F64 { shape: vec![self.m], data: self.acc.clone() })?;
        wr("state_ring.bten", &Tensor::F32 { shape: vec![h, self.m], data: self.ring.clone() })?;
        wr("state_momax.bten", &Tensor::F32 { shape: vec![self.m], data: self.momax.clone() })?;
        wr("state_first.bten", &Tensor::I32 { shape: vec![self.m], data: self.first.clone() })?;
        wr(
            "state_last_valid.bten",
            &Tensor::F32 { shape: vec![self.m], data: self.last_valid.clone() },
        )?;
        Ok(())
    }

    /// Resume a session from a state directory written by
    /// [`MonitorSession::save`]. `threads` tunes this process only;
    /// the analysis state is taken verbatim from disk (the design-side
    /// matrices are rebuilt deterministically from the saved axis).
    pub fn load(dir: impl AsRef<Path>, threads: usize) -> Result<Self> {
        let dir = dir.as_ref();
        let meta = json::parse_file(dir.join("session.json"))
            .with_context(|| format!("loading session from {}", dir.display()))?;
        let version = meta.get("version")?.as_f64()?;
        ensure!(version == STATE_VERSION, "unsupported session state version {version}");
        let n_seen = meta.get("n_seen")?.as_usize()?;
        let m = meta.get("m")?.as_usize()?;
        let params = BfastParams::with_lambda(
            n_seen,
            meta.get("n_hist")?.as_usize()?,
            meta.get("h")?.as_usize()?,
            meta.get("k")?.as_usize()?,
            meta.get("freq")?.as_f64()?,
            meta.get("alpha")?.as_f64()?,
            meta.get("lambda")?.as_f64()?,
        )?;
        let cfg = MonitorConfig {
            m_chunk: meta.get("m_chunk")?.as_usize()?.max(1),
            threads: threads.max(1),
            fill_missing: meta.get("fill_missing")?.as_bool()?,
        };
        let (width, height) = match (meta.try_get("width"), meta.try_get("height")) {
            (Some(w), Some(h)) => (Some(w.as_usize()?), Some(h.as_usize()?)),
            _ => (None, None),
        };
        let rd = |name: &str, want: &[usize]| -> Result<Tensor> {
            let t = read_bten(dir.join(name))?;
            ensure!(
                t.shape() == want,
                "{name}: state tensor is {:?}, session expects {:?}",
                t.shape(),
                want
            );
            Ok(t)
        };
        let p = params.p();
        let h = params.h;
        let axis = rd("state_axis.bten", &[n_seen])?.as_f64()?.to_vec();
        ensure!(
            axis.windows(2).all(|w| w[1] > w[0]),
            "saved time axis is not strictly increasing"
        );
        let beta = rd("state_beta.bten", &[p, m])?.as_f32()?.to_vec();
        let sigma_denom = rd("state_sigma.bten", &[m])?.as_f64()?.to_vec();
        let acc = rd("state_acc.bten", &[m])?.as_f64()?.to_vec();
        let ring = rd("state_ring.bten", &[h, m])?.as_f32()?.to_vec();
        let momax = rd("state_momax.bten", &[m])?.as_f32()?.to_vec();
        let first = rd("state_first.bten", &[m])?.as_i32()?.to_vec();
        let last_valid = rd("state_last_valid.bten", &[m])?.as_f32()?.to_vec();
        // design-side state is a pure function of (axis, freq, k); the
        // history pseudo-inverse is not kept (the engine rebuilds it
        // when a backfill rebuild needs one)
        let x = design::design_matrix(&axis, params.freq, params.k);
        let xt = x.transpose().to_f32();
        Ok(Self {
            params,
            cfg,
            m,
            width,
            height,
            axis,
            xt,
            beta,
            sigma_denom,
            acc,
            ring,
            momax,
            first,
            last_valid,
        })
    }
}

/// Scene-wide ROC pre-pass: scan every pixel's candidate history with
/// the reverse-ordered CUSUM and pick the stable-history start at the
/// given quantile of the per-pixel starts (1.0 = the most conservative
/// start that satisfies every pixel). Gaps are filled within the
/// history window first. The scan is advisory: apply it with
/// [`apply_roc`] before starting a session.
pub fn roc_select(
    stack: &TimeStack,
    params: &BfastParams,
    quantile: f64,
    threads: usize,
) -> Result<RocSelection> {
    params.validate()?;
    ensure!(
        stack.n_times() >= params.n_hist,
        "stack has {} layers, history needs {}",
        stack.n_times(),
        params.n_hist
    );
    ensure!((0.0..=1.0).contains(&quantile), "quantile must be in [0, 1], got {quantile}");
    let n = params.n_hist;
    let xh = design::design_matrix(&stack.time_axis[..n], params.freq, params.k);
    let scanner = RocScanner::new(&xh, params.alpha)?;
    let m = stack.n_pixels();
    let starts = threadpool::parallel_map(m, threads.max(1), |px| {
        let mut hist: Vec<f32> = (0..n).map(|t| stack.layer(t)[px]).collect();
        fill::fill_series(&mut hist);
        let y: Vec<f64> = hist.iter().map(|&v| v as f64).collect();
        // length always matches the scanner; NaN histories scan to 0
        scanner.scan(&y).unwrap_or(0)
    });
    let chosen = if m == 0 {
        0
    } else {
        let mut sorted = starts.clone();
        sorted.sort_unstable();
        sorted[((quantile * (m - 1) as f64).round() as usize).min(m - 1)]
    };
    Ok(RocSelection { starts, chosen })
}

/// Apply a ROC selection: drop the unstable leading layers and shrink
/// the history accordingly (λ is re-derived from α for the new h/n).
/// Errors when the trimmed history can no longer support the analysis
/// (h or p exceed the stable span).
pub fn apply_roc(
    stack: &TimeStack,
    params: &BfastParams,
    start: usize,
) -> Result<(TimeStack, BfastParams)> {
    if start == 0 {
        return Ok((stack.clone(), params.clone()));
    }
    ensure!(
        start < params.n_hist,
        "ROC start {start} consumes the whole {}-layer history",
        params.n_hist
    );
    let n_new = params.n_hist - start;
    ensure!(
        params.h <= n_new,
        "ROC-trimmed history ({n_new} layers) is shorter than the MOSUM bandwidth h={}; \
         re-run with a smaller h",
        params.h
    );
    ensure!(
        n_new > params.p(),
        "ROC-trimmed history ({n_new} layers) cannot fit p={} regressors",
        params.p()
    );
    let trimmed = stack.slice_layers(start)?;
    let new_params = BfastParams::new(
        params.n_total - start,
        n_new,
        params.h,
        params.k,
        params.freq,
        params.alpha,
    )
    .context("ROC-trimmed analysis parameters")?;
    Ok((trimmed, new_params))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::ArtificialDataset;

    fn params() -> BfastParams {
        BfastParams::with_lambda(48, 36, 12, 1, 12.0, 0.05, 3.0).unwrap()
    }

    fn scene(m: usize, seed: u64) -> crate::synth::artificial::GeneratedData {
        ArtificialDataset::new(params(), m, seed).generate()
    }

    #[test]
    fn start_validates_shapes() {
        let p = params();
        let data = scene(16, 1);
        let short = data.stack.prefix(40).unwrap();
        assert!(MonitorSession::start(&short, &p, MonitorConfig::default()).is_err());
        let bad_cfg = MonitorConfig { m_chunk: 0, ..Default::default() };
        assert!(MonitorSession::start(&data.stack, &p, bad_cfg).is_err());
    }

    #[test]
    fn ingest_validates_inputs() {
        let p = params();
        let data = scene(8, 2);
        let init = data.stack.prefix(40).unwrap();
        let p40 = BfastParams::with_lambda(40, 36, 12, 1, 12.0, 0.05, 3.0).unwrap();
        let mut s = MonitorSession::start(&init, &p40, MonitorConfig::default()).unwrap();
        assert!(s.ingest(41.0, &[0.0; 3]).is_err()); // wrong arity
        assert!(s.ingest(40.0, &[0.0; 8]).is_err()); // does not extend
        let d = s.ingest(41.0, data.stack.layer(40)).unwrap();
        assert_eq!(d.layer, 40);
        assert_eq!(d.monitor_index, 4);
        assert_eq!(s.n_seen(), 41);
    }

    #[test]
    fn ingest_stack_skips_seen_layers() {
        let p = params();
        let data = scene(12, 3);
        let init = data.stack.prefix(40).unwrap();
        let p40 = BfastParams::with_lambda(40, 36, 12, 1, 12.0, 0.05, 3.0).unwrap();
        let mut s = MonitorSession::start(&init, &p40, MonitorConfig::default()).unwrap();
        let deltas = s.ingest_stack(&data.stack).unwrap();
        assert_eq!(deltas.len(), 8); // 48 layers, 40 already seen
        assert_eq!(s.n_seen(), 48);
        // feeding the same archive again is a no-op
        assert!(s.ingest_stack(&data.stack).unwrap().is_empty());
    }

    #[test]
    fn save_load_roundtrip_restores_state() {
        let p = params();
        let data = scene(32, 4);
        let s = MonitorSession::start(&data.stack, &p, MonitorConfig::default()).unwrap();
        let dir = std::env::temp_dir().join(format!("bfast_mon_{}", std::process::id()));
        s.save(&dir).unwrap();
        let back = MonitorSession::load(&dir, 2).unwrap();
        assert_eq!(back.n_seen(), s.n_seen());
        assert_eq!(back.n_pixels(), s.n_pixels());
        assert_eq!(back.axis, s.axis);
        assert_eq!(back.beta, s.beta);
        assert_eq!(back.sigma_denom, s.sigma_denom);
        assert_eq!(back.acc, s.acc);
        assert_eq!(back.ring, s.ring);
        assert_eq!(back.momax, s.momax);
        assert_eq!(back.first, s.first);
        assert_eq!(back.xt, s.xt);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn roc_select_trims_unstable_history() {
        // level shift inside the candidate history → positive start
        let p = BfastParams::with_lambda(140, 120, 24, 1, 12.0, 0.05, 3.0).unwrap();
        let mut stack = TimeStack::zeros(140, 4);
        let mut nrm = crate::prng::Normal::from_seed(5);
        for px in 0..4 {
            for t in 0..140 {
                let base = if t < 40 { 2.0 } else { 0.0 };
                let v = base
                    + 0.1 * (2.0 * std::f64::consts::PI * t as f64 / 12.0).sin()
                    + 0.03 * nrm.sample();
                stack.data_mut()[t * 4 + px] = v as f32;
            }
        }
        let sel = roc_select(&stack, &p, 1.0, 2).unwrap();
        assert_eq!(sel.starts.len(), 4);
        assert!(sel.chosen > 20 && sel.chosen < 70, "chosen {}", sel.chosen);
        let (trimmed, np) = apply_roc(&stack, &p, sel.chosen).unwrap();
        assert_eq!(trimmed.n_times(), 140 - sel.chosen);
        assert_eq!(np.n_hist, 120 - sel.chosen);
        assert_eq!(np.h, 24);
        // a selection that leaves too little history errors out
        assert!(apply_roc(&stack, &p, 119).is_err());
        assert!(apply_roc(&stack, &p, 120).is_err());
    }

    #[test]
    fn stable_scene_roc_keeps_everything() {
        // no injected break anywhere — the candidate history is stable
        let p = params();
        let data = ArtificialDataset::new(p.clone(), 6, 6).with_noise(0.01, 0.0).generate();
        let sel = roc_select(&data.stack, &p, 1.0, 2).unwrap();
        assert_eq!(sel.chosen, 0);
        let (same, np) = apply_roc(&data.stack, &p, 0).unwrap();
        assert_eq!(same.n_times(), data.stack.n_times());
        assert_eq!(np.n_hist, p.n_hist);
    }
}
