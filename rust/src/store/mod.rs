//! The **content-addressed store**: canonical digests, a bounded
//! result cache, and a zero-dep compressed wire.
//!
//! The paper's whole argument is that break detection at scale is
//! bottlenecked by data volume — yet until this layer every scene
//! travelled as 4/3×-inflated base64 JSON and every request recomputed
//! from scratch, even when the identical scene + parameters had just
//! been analysed. This subsystem gives the serving stack the two
//! levers distributed ingest systems reach for first:
//!
//! * **Content addressing** ([`hash`]) — an in-tree SHA-256
//!   (known-answer-vector tested) plus a streaming [`HashingReader`]
//!   that digests scene bytes *as they are ingested*. Every scene gets
//!   a canonical `scene_digest` (the hash of its canonical `.bsq` byte
//!   stream — identical whether the scene arrived as raw octets, a
//!   gzip upload, or inline JSON), and every request a derived
//!   `request_digest` over the scene digest + the result-relevant
//!   parameters ([`crate::api::AnalysisRequest::request_digest`]).
//!   Engine choice, chunking knobs and output options are *excluded*:
//!   break maps are backend-invariant by construction, so requests
//!   that differ only there are the same computation.
//! * **Result caching** ([`cache`]) — [`ResultCache`] maps a request
//!   digest to the serialized [`crate::api::AnalysisResult`] envelope,
//!   LRU by bytes under a configurable capacity, with hit/miss/evict
//!   counters surfaced on `/metrics`. Both `bfast serve` and the
//!   gateway consult it at the front door of `POST /v1/runs`: a hit
//!   answers immediately with a finished job record marked `cached`
//!   (bit-identical to a recompute — the envelope serialization is a
//!   fixed point), and a gateway-level hit places **zero** worker
//!   traffic.
//! * **Compressed wire** ([`compress`]) — an in-tree DEFLATE (full
//!   inflate: stored/fixed/dynamic blocks; fixed-huffman + stored
//!   deflate) with gzip/zlib framing behind [`AnyDecoder`], which
//!   sniffs magic bytes on scene upload bodies (gzip, zlib, raw
//!   `.bsq`/`.bten` passthrough). The HTTP substrate decodes
//!   `Content-Encoding: gzip` request bodies centrally and serves
//!   compressed result envelopes to `Accept-Encoding: gzip` callers.

pub mod cache;
pub mod compress;
pub mod hash;

pub use cache::{CacheStats, ResultCache};
pub use compress::{gzip_compress, gzip_decompress, AnyDecoder, Encoding};
pub use hash::{HashingReader, Sha256};
