//! In-tree SHA-256 (FIPS 180-4) with a streaming [`HashingReader`].
//!
//! The store keys everything on content digests, and the repo is
//! zero-dependency by design, so the hash lives here: a plain,
//! allocation-free SHA-256 pinned against the NIST known-answer
//! vectors. [`HashingReader`] wraps any [`Read`] and digests bytes as
//! they stream past, so an ingest path (file read, upload body) gets
//! its `scene_digest` without a second pass over the data — and the
//! digest is invariant to how the reads were chunked (pinned by test:
//! 1-byte reads and 64 KiB reads produce the same hex).

use std::io::Read;

/// Round constants: first 32 bits of the fractional parts of the cube
/// roots of the first 64 primes.
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
    0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
    0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
    0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
    0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
    0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
    0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
    0xc67178f2,
];

/// Initial hash state: first 32 bits of the fractional parts of the
/// square roots of the first 8 primes.
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
    0x5be0cd19,
];

/// Streaming SHA-256: `update` any number of times, then `finalize`.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    /// Partial input block awaiting its 64-byte boundary.
    buf: [u8; 64],
    buffered: usize,
    /// Total message length in bytes (the padding trailer needs it).
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Self { state: H0, buf: [0; 64], buffered: 0, total: 0 }
    }

    /// Absorb `data` (streaming; call as often as needed).
    pub fn update(&mut self, data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        let mut rest = data;
        if self.buffered > 0 {
            let take = rest.len().min(64 - self.buffered);
            self.buf[self.buffered..self.buffered + take].copy_from_slice(&rest[..take]);
            self.buffered += take;
            rest = &rest[take..];
            if self.buffered == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buffered = 0;
            }
        }
        let mut chunks = rest.chunks_exact(64);
        for block in &mut chunks {
            self.compress(block.try_into().unwrap());
        }
        let tail = chunks.remainder();
        self.buf[..tail.len()].copy_from_slice(tail);
        self.buffered = tail.len();
    }

    /// Pad, process the trailer, and return the 32-byte digest.
    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buffered != 56 {
            self.update(&[0]);
        }
        // bypass update() for the length word: total must not move
        let mut block = self.buf;
        block[56..].copy_from_slice(&bit_len.to_be_bytes());
        self.compress(&block);
        let mut out = [0u8; 32];
        for (chunk, word) in out.chunks_exact_mut(4).zip(self.state) {
            chunk.copy_from_slice(&word.to_be_bytes());
        }
        out
    }

    /// The digest as lowercase hex.
    pub fn finalize_hex(self) -> String {
        hex(&self.finalize())
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes(chunk.try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (s, v) in self.state.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *s = s.wrapping_add(v);
        }
    }
}

/// One-shot convenience: the lowercase-hex SHA-256 of `data`.
pub fn sha256_hex(data: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize_hex()
}

/// Lowercase hex of arbitrary bytes.
pub fn hex(bytes: &[u8]) -> String {
    const DIGITS: &[u8; 16] = b"0123456789abcdef";
    let mut out = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        out.push(DIGITS[(b >> 4) as usize] as char);
        out.push(DIGITS[(b & 0xf) as usize] as char);
    }
    out
}

/// A [`Read`] adapter that digests everything read through it — the
/// ingest paths get a content digest with no second pass and no
/// buffering policy of their own (the digest is chunking-invariant).
pub struct HashingReader<R> {
    inner: R,
    hasher: Sha256,
    bytes: u64,
}

impl<R> HashingReader<R> {
    pub fn new(inner: R) -> Self {
        Self { inner, hasher: Sha256::new(), bytes: 0 }
    }

    /// Bytes read through this wrapper so far.
    pub fn bytes_read(&self) -> u64 {
        self.bytes
    }

    /// The digest of everything read so far, as lowercase hex.
    pub fn digest_hex(self) -> String {
        self.hasher.finalize_hex()
    }
}

impl<R: Read> Read for HashingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.hasher.update(&buf[..n]);
        self.bytes += n as u64;
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// NIST FIPS 180-4 known-answer vectors (plus the classic
    /// million-'a' extension vector).
    #[test]
    fn nist_known_answer_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        let million_a = vec![b'a'; 1_000_000];
        assert_eq!(
            sha256_hex(&million_a),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn streaming_matches_one_shot_at_every_split() {
        // cover the block-boundary cases: splits straddling 64 bytes
        let data: Vec<u8> = (0..257u16).map(|i| (i % 251) as u8).collect();
        let want = sha256_hex(&data);
        for split in [0, 1, 55, 56, 63, 64, 65, 128, 200, 256, 257] {
            let mut h = Sha256::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finalize_hex(), want, "split at {split}");
        }
    }

    #[test]
    fn hashing_reader_is_chunk_invariant() {
        // same stream read with 1-byte and 64 KiB buffers must digest
        // identically — the reader imposes no framing of its own
        let data: Vec<u8> = (0..200_000u32).map(|i| (i * 2654435761) as u8).collect();
        let want = sha256_hex(&data);

        let mut tiny = HashingReader::new(&data[..]);
        let mut buf = [0u8; 1];
        while tiny.read(&mut buf).unwrap() > 0 {}
        assert_eq!(tiny.bytes_read(), data.len() as u64);
        assert_eq!(tiny.digest_hex(), want);

        let mut big = HashingReader::new(&data[..]);
        let mut buf = vec![0u8; 64 << 10];
        while big.read(&mut buf).unwrap() > 0 {}
        assert_eq!(big.digest_hex(), want);
    }
}
