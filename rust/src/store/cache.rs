//! [`ResultCache`] — a bounded, content-addressed map from request
//! digest to the serialized [`crate::api::AnalysisResult`] envelope.
//!
//! The cache stores the envelope *bytes*, not the decoded result: the
//! envelope serialization is a fixed point (serialize → parse →
//! serialize reproduces the identical bytes, pinned by the result
//! tests), so a hit is bit-identical to a recompute by construction.
//! Eviction is LRU by **bytes** — result envelopes vary by orders of
//! magnitude with scene size, so an entry-count bound would be
//! meaningless. Capacity 0 disables the cache entirely (every lookup
//! misses nothing and stores nothing — the `--cache-cap-mb 0`
//! invalidation contract).
//!
//! Counters (hits/misses/evictions + resident bytes) feed the
//! `bfast_cache_*` metric families on both serve and gateway.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One public snapshot of the cache (the `GET /v1/cache` body and the
/// `bfast cache stats` table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    pub bytes: usize,
    pub capacity: usize,
}

struct Entry {
    body: Arc<str>,
    /// Recency stamp: bumped on every hit; the smallest stamp is the
    /// least-recently-used entry.
    stamp: u64,
}

struct Inner {
    map: HashMap<String, Entry>,
    bytes: usize,
    clock: u64,
}

/// Digest → envelope cache, LRU by bytes. Shared behind an [`Arc`]
/// between the HTTP front door (lookups) and the completion paths
/// (fills).
pub struct ResultCache {
    capacity: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ResultCache {
    /// A cache bounded at `capacity` bytes of envelope payload
    /// (0 = disabled: never stores, never hits).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            inner: Mutex::new(Inner { map: HashMap::new(), bytes: 0, clock: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Look up a request digest; a hit refreshes the entry's recency.
    /// Disabled caches answer `None` without counting a miss.
    pub fn get(&self, digest: &str) -> Option<Arc<str>> {
        if !self.enabled() {
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        match inner.map.get_mut(digest) {
            Some(e) => {
                e.stamp = clock;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.body))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) an envelope under its request digest,
    /// evicting least-recently-used entries until it fits. Envelopes
    /// larger than the whole capacity are not cached at all.
    pub fn put(&self, digest: &str, body: Arc<str>) {
        if !self.enabled() || body.len() > self.capacity {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        if let Some(old) = inner.map.remove(digest) {
            inner.bytes -= old.body.len();
        }
        while inner.bytes + body.len() > self.capacity {
            let Some(lru) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone())
            else {
                break;
            };
            let evicted = inner.map.remove(&lru).unwrap();
            inner.bytes -= evicted.body.len();
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        inner.clock += 1;
        let stamp = inner.clock;
        inner.bytes += body.len();
        inner.map.insert(digest.to_string(), Entry { body, stamp });
    }

    /// Drop every entry (counters are cumulative and survive — a clear
    /// is an operational action, not a counter reset).
    pub fn clear(&self) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let dropped = inner.map.len();
        inner.map.clear();
        inner.bytes = 0;
        dropped
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: inner.map.len(),
            bytes: inner.bytes,
            capacity: self.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn body(tag: &str, len: usize) -> Arc<str> {
        let mut s = tag.to_string();
        while s.len() < len {
            s.push('x');
        }
        Arc::from(s.as_str())
    }

    #[test]
    fn hit_miss_and_counters() {
        let c = ResultCache::new(1024);
        assert!(c.get("a").is_none());
        c.put("a", body("a", 10));
        let got = c.get("a").unwrap();
        assert!(got.starts_with('a'));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries, s.bytes), (1, 1, 1, 10));
    }

    #[test]
    fn evicts_least_recently_used_by_bytes() {
        let c = ResultCache::new(100);
        c.put("a", body("a", 40));
        c.put("b", body("b", 40));
        // touch "a" so "b" is the LRU when "c" needs room
        assert!(c.get("a").is_some());
        c.put("c", body("c", 40));
        assert!(c.get("b").is_none(), "LRU entry must be evicted");
        assert!(c.get("a").is_some());
        assert!(c.get("c").is_some());
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.bytes, 80);
    }

    #[test]
    fn oversized_entries_and_disabled_cache() {
        let c = ResultCache::new(10);
        c.put("big", body("b", 11));
        assert_eq!(c.stats().entries, 0, "oversized entry must not displace the cache");

        let off = ResultCache::new(0);
        assert!(!off.enabled());
        off.put("a", body("a", 1));
        assert!(off.get("a").is_none());
        let s = off.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 0, 0));
    }

    #[test]
    fn refresh_replaces_in_place_and_clear_drops() {
        let c = ResultCache::new(100);
        c.put("a", body("a", 30));
        c.put("a", body("A", 50));
        let s = c.stats();
        assert_eq!((s.entries, s.bytes), (1, 50));
        assert_eq!(c.clear(), 1);
        let s = c.stats();
        assert_eq!((s.entries, s.bytes), (0, 0));
        // counters are cumulative across a clear
        assert!(c.get("a").is_none());
        assert_eq!(c.stats().misses, 1);
    }
}
