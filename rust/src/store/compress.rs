//! Zero-dependency DEFLATE (RFC 1951) with gzip (RFC 1952) and zlib
//! (RFC 1950) framing, plus [`AnyDecoder`] — the magic-byte sniffer
//! the upload paths put in front of scene bodies.
//!
//! The **inflate** side is complete (stored, fixed-Huffman and
//! dynamic-Huffman blocks), because we must accept what real tools
//! (`gzip`, `curl --data-binary @scene.bsq.gz`, zlib wrappers) emit.
//! The **deflate** side emits fixed-Huffman blocks over a greedy
//! hash-chain LZ77 (plus raw stored blocks) — deliberately simple:
//! `.bsq` scenes are f32 rasters whose win comes from back-reference
//! matching, not from per-block optimal Huffman trees, and the decoder
//! on the other end is usually our own.
//!
//! Every decode path takes an explicit output **limit** and fails
//! fast beyond it: a compressed request body is attacker-shaped input
//! and must not inflate past the server's `max_body` no matter what
//! its header claims.

use crate::error::{bail, ensure, err, Result};
use std::borrow::Cow;

// -- bit I/O (LSB-first, per RFC 1951) -----------------------------------

struct BitReader<'a> {
    data: &'a [u8],
    /// Next unread byte.
    pos: usize,
    /// Bit accumulator, LSB = next bit.
    acc: u32,
    bits: u32,
}

impl<'a> BitReader<'a> {
    fn new(data: &'a [u8]) -> Self {
        Self { data, pos: 0, acc: 0, bits: 0 }
    }

    fn take(&mut self, n: u32) -> Result<u32> {
        debug_assert!(n <= 16);
        while self.bits < n {
            let byte = *self
                .data
                .get(self.pos)
                .ok_or_else(|| err!("truncated deflate stream"))?;
            self.acc |= (byte as u32) << self.bits;
            self.bits += 8;
            self.pos += 1;
        }
        let out = self.acc & ((1u32 << n) - 1);
        self.acc >>= n;
        self.bits -= n;
        Ok(out)
    }

    /// Discard to the next byte boundary (stored-block preamble).
    fn align(&mut self) {
        self.acc = 0;
        self.bits = 0;
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        debug_assert_eq!(self.bits, 0);
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.data.len())
            .ok_or_else(|| err!("truncated deflate stream (stored block)"))?;
        let out = &self.data[self.pos..end];
        self.pos = end;
        Ok(out)
    }
}

struct BitWriter {
    out: Vec<u8>,
    acc: u32,
    bits: u32,
}

impl BitWriter {
    fn new() -> Self {
        Self { out: Vec::new(), acc: 0, bits: 0 }
    }

    /// Emit `n` bits LSB-first (extra bits, block headers).
    fn put(&mut self, value: u32, n: u32) {
        self.acc |= value << self.bits;
        self.bits += n;
        while self.bits >= 8 {
            self.out.push((self.acc & 0xff) as u8);
            self.acc >>= 8;
            self.bits -= 8;
        }
    }

    /// Emit a Huffman code: codes pack MSB-first into the LSB-first
    /// stream, so reverse the bits.
    fn put_code(&mut self, code: u32, n: u32) {
        let mut rev = 0u32;
        for i in 0..n {
            rev |= ((code >> i) & 1) << (n - 1 - i);
        }
        self.put(rev, n);
    }

    fn align(&mut self) {
        if self.bits > 0 {
            self.out.push((self.acc & 0xff) as u8);
            self.acc = 0;
            self.bits = 0;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        self.align();
        self.out
    }
}

// -- canonical Huffman decoding ------------------------------------------

/// A canonical Huffman code, decoded bit-serially from the
/// per-length symbol counts (the classic `puff` algorithm — compact
/// and obviously correct; throughput is bounded by socket I/O here,
/// not table lookups).
struct Huffman {
    /// `counts[len]` = number of symbols with code length `len`.
    counts: [u16; 16],
    /// Symbols sorted by (code length, symbol value).
    symbols: Vec<u16>,
}

impl Huffman {
    fn new(lengths: &[u8]) -> Result<Huffman> {
        let mut counts = [0u16; 16];
        for &len in lengths {
            ensure!(len <= 15, "huffman code length {len} out of range");
            counts[len as usize] += 1;
        }
        // reject over-subscribed codes (incomplete codes are allowed:
        // real streams carry single-code distance trees)
        let mut left = 1i32;
        for len in 1..=15 {
            left = (left << 1) - counts[len] as i32;
            ensure!(left >= 0, "over-subscribed huffman code");
        }
        let mut offsets = [0u16; 16];
        for len in 1..15 {
            offsets[len + 1] = offsets[len] + counts[len];
        }
        let mut symbols = vec![0u16; lengths.iter().filter(|&&l| l > 0).count()];
        for (sym, &len) in lengths.iter().enumerate() {
            if len > 0 {
                symbols[offsets[len as usize] as usize] = sym as u16;
                offsets[len as usize] += 1;
            }
        }
        Ok(Huffman { counts, symbols })
    }

    fn decode(&self, br: &mut BitReader) -> Result<u16> {
        let (mut code, mut first, mut index) = (0i32, 0i32, 0i32);
        for len in 1..=15 {
            code |= br.take(1)? as i32;
            let count = self.counts[len] as i32;
            if code - first < count {
                return Ok(self.symbols[(index + code - first) as usize]);
            }
            index += count;
            first = (first + count) << 1;
            code <<= 1;
        }
        bail!("invalid huffman code in deflate stream")
    }
}

// -- inflate -------------------------------------------------------------

const LEN_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];
/// Order in which code-length-code lengths appear in a dynamic header.
const CLC_ORDER: [usize; 19] = [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];

fn fixed_literal_lengths() -> Vec<u8> {
    let mut lens = vec![8u8; 288];
    lens[144..256].fill(9);
    lens[256..280].fill(7);
    lens
}

/// Decompress a raw DEFLATE stream. `limit` bounds the decoded size —
/// exceeding it is an error, not a truncation.
pub fn inflate(data: &[u8], limit: usize) -> Result<Vec<u8>> {
    let mut br = BitReader::new(data);
    let mut out = Vec::new();
    loop {
        let bfinal = br.take(1)?;
        match br.take(2)? {
            0 => {
                br.align();
                let head = br.bytes(4)?;
                let len = u16::from_le_bytes([head[0], head[1]]) as usize;
                let nlen = u16::from_le_bytes([head[2], head[3]]);
                ensure!(!(len as u16) == nlen, "stored block LEN/NLEN mismatch");
                ensure!(out.len() + len <= limit, "decompressed data exceeds {limit} bytes");
                out.extend_from_slice(br.bytes(len)?);
            }
            1 => {
                let lit = Huffman::new(&fixed_literal_lengths())?;
                let dist = Huffman::new(&[5u8; 30])?;
                inflate_block(&mut br, &lit, &dist, &mut out, limit)?;
            }
            2 => {
                let hlit = br.take(5)? as usize + 257;
                let hdist = br.take(5)? as usize + 1;
                let hclen = br.take(4)? as usize + 4;
                ensure!(hlit <= 286 && hdist <= 30, "dynamic header counts out of range");
                let mut clc_lens = [0u8; 19];
                for &pos in CLC_ORDER.iter().take(hclen) {
                    clc_lens[pos] = br.take(3)? as u8;
                }
                let clc = Huffman::new(&clc_lens)?;
                let mut lens = Vec::with_capacity(hlit + hdist);
                while lens.len() < hlit + hdist {
                    match clc.decode(&mut br)? {
                        sym @ 0..=15 => lens.push(sym as u8),
                        16 => {
                            let &last = lens
                                .last()
                                .ok_or_else(|| err!("code-length repeat with no prior length"))?;
                            let n = br.take(2)? as usize + 3;
                            lens.resize(lens.len() + n, last);
                        }
                        17 => {
                            let n = br.take(3)? as usize + 3;
                            lens.resize(lens.len() + n, 0);
                        }
                        18 => {
                            let n = br.take(7)? as usize + 11;
                            lens.resize(lens.len() + n, 0);
                        }
                        other => bail!("invalid code-length symbol {other}"),
                    }
                }
                ensure!(lens.len() == hlit + hdist, "code-length run overruns the header");
                ensure!(lens[256] > 0, "dynamic block has no end-of-block code");
                let lit = Huffman::new(&lens[..hlit])?;
                let dist = Huffman::new(&lens[hlit..])?;
                inflate_block(&mut br, &lit, &dist, &mut out, limit)?;
            }
            other => bail!("invalid deflate block type {other}"),
        }
        if bfinal == 1 {
            return Ok(out);
        }
    }
}

fn inflate_block(
    br: &mut BitReader,
    lit: &Huffman,
    dist: &Huffman,
    out: &mut Vec<u8>,
    limit: usize,
) -> Result<()> {
    loop {
        match lit.decode(br)? {
            sym @ 0..=255 => {
                ensure!(out.len() < limit, "decompressed data exceeds {limit} bytes");
                out.push(sym as u8);
            }
            256 => return Ok(()),
            sym @ 257..=285 => {
                let idx = sym as usize - 257;
                let len = LEN_BASE[idx] as usize + br.take(LEN_EXTRA[idx] as u32)? as usize;
                let dsym = dist.decode(br)? as usize;
                ensure!(dsym < 30, "invalid distance symbol {dsym}");
                let d = DIST_BASE[dsym] as usize + br.take(DIST_EXTRA[dsym] as u32)? as usize;
                ensure!(d <= out.len(), "back-reference before start of output");
                ensure!(out.len() + len <= limit, "decompressed data exceeds {limit} bytes");
                // overlapping copies are the point (run-length encoding)
                let start = out.len() - d;
                for i in 0..len {
                    let b = out[start + i];
                    out.push(b);
                }
            }
            other => bail!("invalid literal/length symbol {other}"),
        }
    }
}

// -- deflate (fixed-Huffman over greedy hash-chain LZ77) -----------------

const WINDOW: usize = 32 << 10;
const MIN_MATCH: usize = 3;
const MAX_MATCH: usize = 258;
const HASH_BITS: u32 = 15;
/// Chain links followed per position — bounds worst-case time while
/// keeping raster data's long runs compressible.
const MAX_CHAIN: usize = 64;

fn hash3(a: u8, b: u8, c: u8) -> usize {
    let v = (a as u32) << 16 | (b as u32) << 8 | c as u32;
    (v.wrapping_mul(2654435761) >> (32 - HASH_BITS)) as usize
}

/// Symbol index for a match length (3..=258) in the length alphabet.
fn length_symbol(len: usize) -> usize {
    debug_assert!((MIN_MATCH..=MAX_MATCH).contains(&len));
    match LEN_BASE.binary_search(&(len as u16)) {
        Ok(i) => i,
        Err(i) => i - 1,
    }
}

/// Symbol index for a distance (1..=32768) in the distance alphabet.
fn distance_symbol(d: usize) -> usize {
    debug_assert!((1..=WINDOW).contains(&d));
    match DIST_BASE.binary_search(&(d as u16)) {
        Ok(i) => i,
        Err(i) => i - 1,
    }
}

/// The fixed lit/len code for `sym` as `(code, bits)` (RFC 1951 §3.2.6).
fn fixed_lit_code(sym: usize) -> (u32, u32) {
    match sym {
        0..=143 => (0x30 + sym as u32, 8),
        144..=255 => (0x190 + (sym as u32 - 144), 9),
        256..=279 => (sym as u32 - 256, 7),
        _ => (0xc0 + (sym as u32 - 280), 8),
    }
}

fn emit_fixed_sym(bw: &mut BitWriter, sym: usize) {
    let (code, bits) = fixed_lit_code(sym);
    bw.put_code(code, bits);
}

/// Compress into one final fixed-Huffman DEFLATE block.
pub fn deflate(data: &[u8]) -> Vec<u8> {
    let mut bw = BitWriter::new();
    bw.put(1, 1); // BFINAL
    bw.put(1, 2); // fixed Huffman
    let mut head = vec![u32::MAX; 1 << HASH_BITS];
    let mut prev = vec![u32::MAX; data.len()];
    let mut i = 0usize;
    while i < data.len() {
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        if i + MIN_MATCH <= data.len() {
            let h = hash3(data[i], data[i + 1], data[i + 2]);
            let mut cand = head[h];
            let mut chain = 0;
            while cand != u32::MAX && chain < MAX_CHAIN {
                let c = cand as usize;
                let dist = i - c;
                if dist > WINDOW {
                    break;
                }
                let max = MAX_MATCH.min(data.len() - i);
                let mut len = 0;
                while len < max && data[c + len] == data[i + len] {
                    len += 1;
                }
                if len > best_len {
                    best_len = len;
                    best_dist = dist;
                    if len == MAX_MATCH {
                        break;
                    }
                }
                cand = prev[c];
                chain += 1;
            }
            prev[i] = head[h];
            head[h] = i as u32;
        }
        if best_len >= MIN_MATCH {
            let lsym = length_symbol(best_len);
            emit_fixed_sym(&mut bw, 257 + lsym);
            let lextra = LEN_EXTRA[lsym] as u32;
            if lextra > 0 {
                bw.put((best_len - LEN_BASE[lsym] as usize) as u32, lextra);
            }
            let dsym = distance_symbol(best_dist);
            bw.put_code(dsym as u32, 5);
            let dextra = DIST_EXTRA[dsym] as u32;
            if dextra > 0 {
                bw.put((best_dist - DIST_BASE[dsym] as usize) as u32, dextra);
            }
            // insert the skipped positions into the chains so later
            // matches can anchor inside this one
            for j in i + 1..(i + best_len).min(data.len().saturating_sub(MIN_MATCH - 1)) {
                let h = hash3(data[j], data[j + 1], data[j + 2]);
                prev[j] = head[h];
                head[h] = j as u32;
            }
            i += best_len;
        } else {
            emit_fixed_sym(&mut bw, data[i] as usize);
            i += 1;
        }
    }
    emit_fixed_sym(&mut bw, 256); // end of block
    bw.finish()
}

/// Compress into stored (uncompressed) blocks — the fallback framing
/// for incompressible payloads, and a test fixture for the stored
/// inflate path.
pub fn deflate_stored(data: &[u8]) -> Vec<u8> {
    let mut bw = BitWriter::new();
    if data.is_empty() {
        bw.put(1, 1);
        bw.put(0, 2);
        bw.align();
        bw.out.extend_from_slice(&[0, 0, 0xff, 0xff]);
        return bw.finish();
    }
    let chunks: Vec<&[u8]> = data.chunks(65_535).collect();
    for (i, chunk) in chunks.iter().enumerate() {
        bw.put(u32::from(i + 1 == chunks.len()), 1);
        bw.put(0, 2);
        bw.align();
        let len = chunk.len() as u16;
        bw.out.extend_from_slice(&len.to_le_bytes());
        bw.out.extend_from_slice(&(!len).to_le_bytes());
        bw.out.extend_from_slice(chunk);
    }
    bw.finish()
}

// -- checksums -----------------------------------------------------------

/// CRC-32 (IEEE, reflected) — the gzip trailer checksum.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xedb8_8320 & mask);
        }
    }
    !crc
}

/// Adler-32 — the zlib trailer checksum.
pub fn adler32(data: &[u8]) -> u32 {
    const MOD: u32 = 65_521;
    let (mut a, mut b) = (1u32, 0u32);
    for chunk in data.chunks(5550) {
        for &byte in chunk {
            a += byte as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

// -- gzip / zlib framing -------------------------------------------------

const GZIP_MAGIC: [u8; 2] = [0x1f, 0x8b];

/// Wrap [`deflate`] output in a minimal gzip member (no name, no
/// mtime, "unknown" OS).
pub fn gzip_compress(data: &[u8]) -> Vec<u8> {
    let mut out = vec![0x1f, 0x8b, 8, 0, 0, 0, 0, 0, 0, 0xff];
    out.extend_from_slice(&deflate(data));
    out.extend_from_slice(&crc32(data).to_le_bytes());
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out
}

/// Decompress one gzip member, verifying the CRC-32 and length
/// trailer. `limit` bounds the decoded size.
pub fn gzip_decompress(data: &[u8], limit: usize) -> Result<Vec<u8>> {
    ensure!(data.len() >= 18, "gzip data too short ({} bytes)", data.len());
    ensure!(data[..2] == GZIP_MAGIC, "not gzip data (bad magic)");
    ensure!(data[2] == 8, "unsupported gzip compression method {}", data[2]);
    let flags = data[3];
    ensure!(flags & 0xe0 == 0, "reserved gzip flag bits set");
    let mut pos = 10usize;
    if flags & 0x04 != 0 {
        // FEXTRA
        ensure!(data.len() >= pos + 2, "truncated gzip FEXTRA field");
        let xlen = u16::from_le_bytes([data[pos], data[pos + 1]]) as usize;
        pos += 2 + xlen;
    }
    for flag in [0x08u8, 0x10] {
        // FNAME, FCOMMENT: zero-terminated strings
        if flags & flag != 0 {
            let end = data[pos.min(data.len())..]
                .iter()
                .position(|&b| b == 0)
                .ok_or_else(|| err!("unterminated gzip header string"))?;
            pos += end + 1;
        }
    }
    if flags & 0x02 != 0 {
        pos += 2; // FHCRC
    }
    ensure!(data.len() >= pos + 8, "truncated gzip stream");
    let body = &data[pos..data.len() - 8];
    let out = inflate(body, limit)?;
    let trailer = &data[data.len() - 8..];
    let want_crc = u32::from_le_bytes(trailer[..4].try_into().unwrap());
    let want_len = u32::from_le_bytes(trailer[4..].try_into().unwrap());
    ensure!(crc32(&out) == want_crc, "gzip CRC mismatch (corrupt stream)");
    ensure!(out.len() as u32 == want_len, "gzip length trailer mismatch");
    Ok(out)
}

/// Wrap [`deflate`] output in a zlib stream (32K window, default
/// compression level bits).
pub fn zlib_compress(data: &[u8]) -> Vec<u8> {
    let mut out = vec![0x78, 0x9c];
    out.extend_from_slice(&deflate(data));
    out.extend_from_slice(&adler32(data).to_be_bytes());
    out
}

/// Decompress a zlib stream, verifying the Adler-32 trailer.
pub fn zlib_decompress(data: &[u8], limit: usize) -> Result<Vec<u8>> {
    ensure!(data.len() >= 6, "zlib data too short ({} bytes)", data.len());
    ensure!(is_zlib_header(data[0], data[1]), "not zlib data (bad header)");
    ensure!(data[1] & 0x20 == 0, "zlib preset dictionaries are not supported");
    let out = inflate(&data[2..data.len() - 4], limit)?;
    let want = u32::from_be_bytes(data[data.len() - 4..].try_into().unwrap());
    ensure!(adler32(&out) == want, "zlib Adler-32 mismatch (corrupt stream)");
    Ok(out)
}

fn is_zlib_header(cmf: u8, flg: u8) -> bool {
    cmf & 0x0f == 8 && ((cmf as u16) << 8 | flg as u16) % 31 == 0
}

// -- the sniffer ---------------------------------------------------------

/// What a payload's leading bytes say it is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Encoding {
    Gzip,
    Zlib,
    /// Raw payload (recognised `.bsq`/`.bten` magic, or anything that
    /// matches no compressed framing) — passed through untouched.
    Identity,
}

/// Magic-byte sniffer for scene upload bodies: callers hand it
/// whatever arrived on the wire and get canonical bytes back. Raw
/// `.bsq`/`.bten` payloads are recognised first so a scene can never
/// be misread as a compressed stream.
pub struct AnyDecoder;

impl AnyDecoder {
    pub fn sniff(data: &[u8]) -> Encoding {
        if data.starts_with(b"BSQ1") || data.starts_with(b"BTEN") {
            return Encoding::Identity;
        }
        if data.starts_with(&GZIP_MAGIC) {
            return Encoding::Gzip;
        }
        if data.len() >= 2 && is_zlib_header(data[0], data[1]) {
            return Encoding::Zlib;
        }
        Encoding::Identity
    }

    /// Decode to canonical bytes: compressed framings are expanded
    /// (bounded by `limit`), raw payloads are borrowed as-is.
    pub fn decode(data: &[u8], limit: usize) -> Result<Cow<'_, [u8]>> {
        match Self::sniff(data) {
            Encoding::Gzip => Ok(Cow::Owned(gzip_decompress(data, limit)?)),
            Encoding::Zlib => Ok(Cow::Owned(zlib_decompress(data, limit)?)),
            Encoding::Identity => Ok(Cow::Borrowed(data)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg32;

    fn sample_texts() -> Vec<Vec<u8>> {
        let mut rng = Pcg32::with_stream(0xc0ffee, 7);
        let mut noisy = vec![0u8; 10_000];
        for b in noisy.iter_mut() {
            *b = (rng.next_u32() & 0xff) as u8;
        }
        vec![
            Vec::new(),
            b"a".to_vec(),
            b"the quick brown fox jumps over the lazy dog".to_vec(),
            vec![0u8; 70_000],                       // long runs, multi-chunk stored
            b"abcabcabcabcabcabcabcabcabc".repeat(50), // periodic back-references
            noisy,                                    // incompressible
        ]
    }

    #[test]
    fn fixed_deflate_roundtrips() {
        for data in sample_texts() {
            let packed = deflate(&data);
            let back = inflate(&packed, data.len().max(1)).unwrap();
            assert_eq!(back, data, "fixed roundtrip failed for {} bytes", data.len());
        }
    }

    #[test]
    fn stored_deflate_roundtrips() {
        for data in sample_texts() {
            let packed = deflate_stored(&data);
            let back = inflate(&packed, data.len().max(1)).unwrap();
            assert_eq!(back, data, "stored roundtrip failed for {} bytes", data.len());
        }
    }

    /// Hand-built dynamic-Huffman stream: 255 literal codes of length
    /// 8 plus two of length 9 (a complete canonical code), a single
    /// 1-bit distance code, all-literal payload.
    fn dynamic_stream(payload: &[u8]) -> Vec<u8> {
        let mut bw = BitWriter::new();
        bw.put(1, 1); // BFINAL
        bw.put(2, 2); // dynamic
        bw.put(0, 5); // HLIT  = 257
        bw.put(0, 5); // HDIST = 1
        bw.put(14, 4); // HCLEN = 18
        // code-length-code lengths in CLC_ORDER (first 18 entries):
        // symbol 8 → 1 bit, symbols 9 and 1 → 2 bits
        let clc_lens = [0u32, 0, 0, 0, 1, 0, 2, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 2];
        for l in clc_lens {
            bw.put(l, 3);
        }
        // canonical CLC codes: len(8)=1 → 0; len(1)=2 → 10; len(9)=2 → 11
        for _ in 0..255 {
            bw.put_code(0b0, 1); // literal lengths 0..=254 are 8 bits
        }
        bw.put_code(0b11, 2); // literal 255 → 9 bits
        bw.put_code(0b11, 2); // symbol 256 (EOB) → 9 bits
        bw.put_code(0b10, 2); // the lone distance code → 1 bit
        // literal codes: sym k ≤ 254 → k (8 bits); 255 → 510, EOB → 511
        for &b in payload {
            if b < 255 {
                bw.put_code(b as u32, 8);
            } else {
                bw.put_code(510, 9);
            }
        }
        bw.put_code(511, 9); // end of block
        bw.finish()
    }

    #[test]
    fn dynamic_huffman_inflates() {
        let payload = b"dynamic huffman block with a \xff byte and repetition repetition";
        let stream = dynamic_stream(payload);
        assert_eq!(inflate(&stream, 4096).unwrap(), payload);
    }

    #[test]
    fn truncated_streams_error_out() {
        let data = b"truncation test payload with enough content to matter".repeat(10);
        for packer in [deflate as fn(&[u8]) -> Vec<u8>, deflate_stored] {
            let packed = packer(&data);
            for cut in [1, packed.len() / 2, packed.len() - 1] {
                let err = inflate(&packed[..cut], 1 << 20).unwrap_err().to_string();
                assert!(err.contains("truncated"), "cut at {cut}: {err}");
            }
        }
        // a truncated gzip member dies on framing before inflate runs
        let gz = gzip_compress(&data);
        assert!(gzip_decompress(&gz[..10], 1 << 20).is_err());
    }

    #[test]
    fn output_limit_is_enforced() {
        // 70_000 zeros compress tiny; a 1 KiB limit must refuse to
        // expand them (zip-bomb guard), on every block type
        let data = vec![0u8; 70_000];
        for packed in [deflate(&data), deflate_stored(&data)] {
            let err = inflate(&packed, 1024).unwrap_err().to_string();
            assert!(err.contains("exceeds 1024 bytes"), "{err}");
        }
    }

    #[test]
    fn gzip_roundtrip_and_corruption_detection() {
        let data = b"gzip framing test \x00\x01\x02 with binary".repeat(37);
        let gz = gzip_compress(&data);
        assert_eq!(AnyDecoder::sniff(&gz), Encoding::Gzip);
        assert_eq!(gzip_decompress(&gz, 1 << 20).unwrap(), data);
        // flip a payload bit → CRC must catch it
        let mut bad = gz.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        assert!(gzip_decompress(&bad, 1 << 20).is_err());
    }

    #[test]
    fn gzip_header_fields_are_skipped() {
        // FNAME + FEXTRA headers, as real tools write them
        let data = b"payload behind a decorated gzip header";
        let plain = gzip_compress(data);
        let mut decorated = vec![0x1f, 0x8b, 8, 0x08 | 0x04, 1, 2, 3, 4, 0, 0xff];
        decorated.extend_from_slice(&3u16.to_le_bytes()); // XLEN
        decorated.extend_from_slice(b"xtr"); // extra field
        decorated.extend_from_slice(b"scene.bsq\0"); // FNAME
        decorated.extend_from_slice(&plain[10..]); // deflate body + trailer
        assert_eq!(gzip_decompress(&decorated, 1 << 20).unwrap(), data);
    }

    #[test]
    fn zlib_roundtrip() {
        let data = b"zlib framing test".repeat(100);
        let z = zlib_compress(&data);
        assert_eq!(AnyDecoder::sniff(&z), Encoding::Zlib);
        assert_eq!(zlib_decompress(&z, 1 << 20).unwrap(), data);
        let mut bad = z.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x01;
        assert!(zlib_decompress(&bad, 1 << 20).is_err());
    }

    #[test]
    fn sniffer_passes_raw_scene_formats_through() {
        let bsq = b"BSQ1\x10\x00\x00\x00{}rest-of-scene";
        assert_eq!(AnyDecoder::sniff(bsq), Encoding::Identity);
        match AnyDecoder::decode(bsq, 1 << 20).unwrap() {
            Cow::Borrowed(b) => assert_eq!(b, bsq),
            Cow::Owned(_) => panic!("raw scene must be borrowed, not copied"),
        }
        assert_eq!(AnyDecoder::sniff(b"BTEN...."), Encoding::Identity);
        assert_eq!(AnyDecoder::sniff(b"{\"v\":1}"), Encoding::Identity);
        // a gzip body decodes transparently
        let gz = gzip_compress(b"BSQ1 payload");
        assert_eq!(AnyDecoder::decode(&gz, 1 << 20).unwrap().as_ref(), b"BSQ1 payload");
    }

    #[test]
    fn checksums_match_reference_values() {
        // IEEE CRC-32 and Adler-32 of "123456789" (the classic check
        // values: cbf43926 / 091e01de)
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(adler32(b"123456789"), 0x091e_01de);
        assert_eq!(crc32(b""), 0);
        assert_eq!(adler32(b""), 1);
    }
}
