//! The MOSUM process, boundary function, and break scan for a single
//! time series (paper Eq. 3–4 / Alg. 1 steps 5–13).
//!
//! These are the per-pixel building blocks shared by every CPU-side
//! implementation; the batched/device variants in `cpu` and the AOT
//! pipeline must agree with them bit-for-tolerance (enforced by the
//! cross-implementation integration tests).

use crate::params::BfastParams;

/// σ̂ from the history residuals (Alg. 3: dof = n − (2 + 2k)).
pub fn sigma_hat(residuals: &[f64], params: &BfastParams) -> f64 {
    let n = params.n_hist;
    let ss: f64 = residuals[..n].iter().map(|r| r * r).sum();
    (ss / params.dof() as f64).sqrt()
}

/// Normalised MOSUM process MO_t for t = n+1..N (Eq. 3):
/// `MO_t = 1/(σ̂√n) Σ_{s=t-h+1..t} r_s` — windows of h terms ending at
/// t. Runs the paper's rolling-update scheme (Alg. 3 lines 22–27):
/// O(1) per step after the initial sum.
pub fn mosum_process(residuals: &[f64], params: &BfastParams) -> Vec<f64> {
    let (n, h) = (params.n_hist, params.h);
    let n_mon = params.n_monitor();
    let sigma = sigma_hat(residuals, params);
    let denom = sigma * (n as f64).sqrt();
    let mut out = Vec::with_capacity(n_mon);
    // initial window: ends at t = n+1 (0-based residuals n-h+1 ..= n)
    let mut acc: f64 = residuals[n + 1 - h..=n].iter().sum();
    out.push(acc / denom);
    // slide for t = n+2..=N: drop r_{t-h-1}, add r_t (1-based). The
    // paired iterators walk the 0-based add/sub rows in lock-step with
    // no per-step indexing; `acc += add - sub` keeps the f64 op order
    // of the indexed formulation, so values are bit-identical.
    for (&add, &sub) in residuals[n + 1..].iter().zip(&residuals[n + 1 - h..]) {
        acc += add - sub;
        out.push(acc / denom);
    }
    out
}

/// log₊ of Eq. (4): 1 for x ≤ e, ln(x) otherwise.
#[inline]
pub fn log_plus(x: f64) -> f64 {
    if x <= std::f64::consts::E {
        1.0
    } else {
        x.ln()
    }
}

/// Boundary b_t = λ √(log₊ (t/n)) for t = n+1..N (Eq. 4).
pub fn boundary(params: &BfastParams) -> Vec<f64> {
    (0..params.n_monitor()).map(|ti| boundary_at(params, ti)).collect()
}

/// One Eq. (4) boundary value at 0-based monitor index `ti`
/// (i.e. t = n + 1 + ti). Incremental consumers — the monitor
/// session extends the boundary one layer at a time — must agree
/// bit-for-bit with [`boundary`], so both share this kernel.
pub fn boundary_at(params: &BfastParams, ti: usize) -> f64 {
    let n = params.n_hist as f64;
    let t = params.n_hist + 1 + ti;
    params.lambda * log_plus(t as f64 / n).sqrt()
}

/// One rolling MOSUM update in the fused engine's mixed precision:
/// the f64 accumulator absorbs the f32 residual difference
/// (`acc += add − sub`, Alg. 3 lines 22–27) and the normalised value
/// is truncated to f32 exactly as the batched engines store it.
/// `denom` is σ̂√n. This is the per-pixel form of the update inside
/// `cpu::FusedCpuBfast`'s vectorised MOSUM phase; the agreement is
/// pinned bit-for-bit by the monitor-session equivalence tests, which
/// is what lets `monitor::MonitorSession` advance one layer at a time
/// without refitting.
#[inline]
pub fn rolling_step(acc: &mut f64, denom: f64, add: f32, sub: f32) -> f32 {
    *acc += add as f64 - sub as f64;
    (*acc / denom) as f32
}

/// Banded window-sum operator W ∈ R^{(N−n)×N}, row-major f32:
/// `W[i, j] = 1` for `j ∈ [n+i−h+1, n+i]` (0-based), so `W · r` yields
/// every Eq. (3) window sum at once. This is the runtime input the AOT
/// modules contract against (the MXU-shaped formulation of the rolling
/// update; supplied at runtime because xla_extension 0.5.1 miscompiles
/// it as an HLO constant — see python/compile/kernels/mosum.py).
pub fn window_matrix_f32(n_total: usize, n_hist: usize, h: usize) -> Vec<f32> {
    let nm = n_total - n_hist;
    let mut w = vec![0.0f32; nm * n_total];
    for i in 0..nm {
        for j in n_hist + i + 1 - h..=n_hist + i {
            w[i * n_total + j] = 1.0;
        }
    }
    w
}

/// Result of scanning one pixel's MOSUM against the boundary.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BreakScan {
    /// Whether |MO_t| crossed the boundary anywhere in the monitor period.
    pub has_break: bool,
    /// 0-based monitor index of the first crossing, or -1.
    pub first: i32,
    /// max_t |MO_t| (the Fig. 9 heatmap statistic).
    pub momax: f64,
}

/// Scan a MOSUM process against a boundary (Alg. 1 step 13).
pub fn scan_breaks(mo: &[f64], bound: &[f64]) -> BreakScan {
    debug_assert_eq!(mo.len(), bound.len());
    let mut first = -1i32;
    let mut momax = 0.0f64;
    for (i, (&m, &b)) in mo.iter().zip(bound).enumerate() {
        let a = m.abs();
        if a > momax {
            momax = a;
        }
        if first < 0 && a > b {
            first = i as i32;
        }
    }
    BreakScan { has_break: first >= 0, first, momax }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Normal;

    fn params() -> BfastParams {
        BfastParams::with_lambda(40, 24, 6, 1, 12.0, 0.05, 2.0).unwrap()
    }

    #[test]
    fn rolling_update_equals_direct_sums() {
        let p = params();
        let mut nrm = Normal::from_seed(1);
        let r: Vec<f64> = (0..p.n_total).map(|_| nrm.sample()).collect();
        let mo = mosum_process(&r, &p);
        let sigma = sigma_hat(&r, &p);
        for (i, &v) in mo.iter().enumerate() {
            let t = p.n_hist + 1 + i; // 1-based
            let direct: f64 = r[t - p.h..t].iter().sum();
            let want = direct / (sigma * (p.n_hist as f64).sqrt());
            assert!((v - want).abs() < 1e-12, "t={t}: {v} vs {want}");
        }
    }

    #[test]
    fn sigma_uses_history_only_with_dof() {
        let p = params();
        let mut r = vec![0.5; p.n_total];
        // monitor residuals should not affect sigma
        for v in r.iter_mut().skip(p.n_hist) {
            *v = 100.0;
        }
        let s = sigma_hat(&r, &p);
        let want = (0.25 * p.n_hist as f64 / p.dof() as f64).sqrt();
        assert!((s - want).abs() < 1e-12);
    }

    #[test]
    fn log_plus_definition() {
        assert_eq!(log_plus(0.5), 1.0);
        assert_eq!(log_plus(std::f64::consts::E), 1.0);
        assert!((log_plus(10.0) - 10f64.ln()).abs() < 1e-15);
    }

    #[test]
    fn boundary_flat_then_growing() {
        // t/n <= e for all t <= e*n: boundary == lambda there
        let p = BfastParams::with_lambda(300, 100, 50, 3, 23.0, 0.05, 2.5).unwrap();
        let b = boundary(&p);
        assert_eq!(b.len(), 200);
        let e_cut = (std::f64::consts::E * 100.0).floor() as usize; // t <= 271
        for (i, &v) in b.iter().enumerate() {
            let t = 101 + i;
            if t <= e_cut {
                assert!((v - 2.5).abs() < 1e-12, "t={t}");
            } else {
                assert!(v > 2.5, "t={t}");
            }
        }
        assert!(b.last().unwrap() > &2.5);
    }

    #[test]
    fn scan_finds_first_crossing() {
        let mo = vec![0.1, -0.2, 3.0, 0.5, -4.0];
        let bound = vec![2.0; 5];
        let s = scan_breaks(&mo, &bound);
        assert!(s.has_break);
        assert_eq!(s.first, 2);
        assert!((s.momax - 4.0).abs() < 1e-15);
        let none = scan_breaks(&[0.1, 0.2], &[2.0, 2.0]);
        assert!(!none.has_break);
        assert_eq!(none.first, -1);
    }

    #[test]
    fn boundary_at_matches_boundary() {
        let p = BfastParams::with_lambda(300, 100, 50, 3, 23.0, 0.05, 2.5).unwrap();
        let b = boundary(&p);
        for (ti, &v) in b.iter().enumerate() {
            assert_eq!(v, boundary_at(&p, ti), "ti={ti}");
        }
    }

    #[test]
    fn rolling_step_tracks_window_sums() {
        let p = params();
        let mut nrm = Normal::from_seed(7);
        let r: Vec<f32> = (0..p.n_total).map(|_| nrm.sample() as f32).collect();
        let denom = 3.7f64;
        // start from the initial window ending at t = n+1
        let (n, h) = (p.n_hist, p.h);
        let mut acc: f64 = r[n + 1 - h..=n].iter().map(|&v| v as f64).sum();
        for t in n + 1..p.n_total {
            let mo = rolling_step(&mut acc, denom, r[t], r[t - h]);
            let direct: f64 = r[t + 1 - h..=t].iter().map(|&v| v as f64).sum();
            assert!((acc - direct).abs() < 1e-9, "t={t}: {acc} vs {direct}");
            assert_eq!(mo, (acc / denom) as f32);
        }
    }

    #[test]
    fn no_break_under_null_with_big_lambda() {
        let p = BfastParams::with_lambda(200, 100, 50, 3, 23.0, 0.05, 50.0).unwrap();
        let mut nrm = Normal::from_seed(2);
        let r: Vec<f64> = (0..p.n_total).map(|_| nrm.sample() * 0.01).collect();
        let mo = mosum_process(&r, &p);
        let s = scan_breaks(&mo, &boundary(&p));
        assert!(!s.has_break);
    }
}
