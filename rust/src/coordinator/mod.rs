//! L3 coordinator — the streaming scene pipeline (the paper's system
//! contribution, rust-side).
//!
//! The paper's profile shows the device pipeline is dominated by the
//! host→device transfer of Y; its future-work section asks for that
//! transfer to be overlapped/compressed. This coordinator implements
//! the overlap:
//!
//! ```text
//!   staging workers (CPU threads)          executor thread (owns backend)
//!  ┌───────────────────────────────┐      ┌─────────────────────────────┐
//!  │ gather chunk px range          │ ───▶ │ transfer → execute → read   │
//!  │ pad to m_chunk, gap-fill       │ sync │ back, assemble break map    │
//!  └───────────────────────────────┘ chan └─────────────────────────────┘
//! ```
//!
//! * the bounded channel (depth = [`RunnerConfig::queue_depth`])
//!   provides **backpressure**: staging can run at most `depth` chunks
//!   ahead of the executor, bounding memory;
//! * chunk buffers are **recycled** through a free-list channel (no
//!   allocation in the steady state);
//! * device handles (PJRT) are not `Send`, so the executor thread owns
//!   the [`ExecutorBackend`] exclusively — the analogue of a
//!   CUDA-stream owner thread. The emulated backend honours the same
//!   contract.
//!
//! [`BfastRunner`] is the leader API; it is backend-agnostic: pass any
//! [`ExecutorBackend`] to [`BfastRunner::new`], or use the
//! constructors [`BfastRunner::emulated`] (pure-rust, default build),
//! `BfastRunner::from_manifest_dir` (PJRT artifacts, feature `pjrt`)
//! and [`BfastRunner::auto`] (artifacts when available, else
//! emulated). `phased` mode swaps the fused execution for the
//! per-phase instrumented one to reproduce the paper's phase figures.

use crate::api::CancelToken;
use crate::error::{ensure, Context, Result};
use crate::fill;
use crate::metrics::PhaseTimes;
use crate::params::BfastParams;
use crate::pixel::{DirectBfast, PixelResult};
use crate::raster::{BreakMap, ChunkPlan, TimeStack};
use crate::runtime::{ChunkOutput, EmulatedDevice, ExecutorBackend};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

/// Staging-side phase label (host work before the executor sees data).
pub const PHASE_STAGING: &str = "staging (host)";

/// A resolved artifact must carry exactly the analysis shape — a
/// shape-specialised backend may return a spec for a different one.
fn ensure_spec_shape(spec: &crate::runtime::ArtifactSpec, params: &BfastParams) -> Result<()> {
    ensure!(
        spec.n_total == params.n_total
            && spec.n_hist == params.n_hist
            && spec.h == params.h
            && spec.k == params.k,
        "artifact {} is shaped (N={}, n={}, h={}, k={}) but params are \
         (N={}, n={}, h={}, k={})",
        spec.name,
        spec.n_total,
        spec.n_hist,
        spec.h,
        spec.k,
        params.n_total,
        params.n_hist,
        params.h,
        params.k
    );
    Ok(())
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct RunnerConfig {
    /// Artifact config name; `None` = auto-select by analysis shape.
    pub artifact: Option<String>,
    /// Bounded-queue depth between staging and executor (≥ 1;
    /// 2 = classic double buffering).
    pub queue_depth: usize,
    /// Staging worker threads.
    pub staging_threads: usize,
    /// Run the per-phase instrumented path instead of the fused one.
    pub phased: bool,
    /// Gap-fill NaN observations during staging (paper footnote 2).
    pub fill_missing: bool,
    /// Override the backend-resolved chunk width (pixels per executed
    /// chunk). Only honoured by backends whose
    /// [`ExecutorBackend::flexible_chunk`] is `true`; shape-specialised
    /// artifact backends reject the override. `None` = use whatever
    /// the backend resolves. Typically seeded from
    /// `bench::tune_m_chunk` measurements.
    pub m_chunk: Option<usize>,
    /// Let [`BfastRunner::auto`] pick `m_chunk` with the bench
    /// autotuner on its first run (ignored when [`RunnerConfig::m_chunk`]
    /// pins a width, and only honoured by auto-built runners over
    /// flexible backends — explicit constructors never tune).
    pub autotune: bool,
}

impl Default for RunnerConfig {
    fn default() -> Self {
        Self {
            artifact: None,
            queue_depth: 2,
            staging_threads: (crate::threadpool::default_threads() / 2).max(1),
            phased: false,
            fill_missing: true,
            m_chunk: None,
            autotune: true,
        }
    }
}

/// Results of one coordinated run.
#[derive(Debug)]
pub struct RunResult {
    pub map: BreakMap,
    pub phases: PhaseTimes,
    pub chunks: usize,
    pub artifact: String,
    pub wall: std::time::Duration,
}

impl RunResult {
    pub fn break_count(&self) -> usize {
        self.map.break_count()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// The leader: owns the executor backend and drives scene analyses.
///
/// Generic over how the backend is stored so the *shareability* of a
/// runner follows its backend: the default `BfastRunner` erases to
/// `dyn ExecutorBackend` (PJRT device handles are thread-confined),
/// while [`SharedBfastRunner`] erases to
/// `dyn ExecutorBackend + Send + Sync` and can sit behind one `Arc`
/// serving many worker threads — the serving layer's shared runner.
/// Every analysis entry point takes `&self`.
pub struct BfastRunner<B: ?Sized + ExecutorBackend = dyn ExecutorBackend> {
    pub cfg: RunnerConfig,
    /// First-run autotuner verdict (`None` inside = tuning ran and
    /// declined/failed); `OnceLock` so concurrent first runs through
    /// a shared runner tune exactly once.
    tuned: std::sync::OnceLock<Option<usize>>,
    /// Set only by [`BfastRunner::auto`] (from [`RunnerConfig::autotune`]):
    /// explicitly constructed runners never self-tune.
    autotune_armed: bool,
    backend: Box<B>,
}

/// A runner whose backend may be used from any thread (the emulated
/// device qualifies; PJRT does not). `bfast serve` hands one of these
/// to its HTTP and scheduler workers behind a single `Arc`.
pub type SharedBfastRunner = BfastRunner<dyn ExecutorBackend + Send + Sync>;

impl BfastRunner {
    /// Pure-rust emulated backend (the default build's device).
    pub fn emulated(cfg: RunnerConfig) -> Result<Self> {
        Self::new(Box::new(EmulatedDevice::new()), cfg)
    }

    /// Command-stream backend (`--engine cmd`): every chunk is
    /// recorded into a single-chunk [`crate::cmd::CmdStream`] and
    /// replayed through the op interpreter — bit-identical to the
    /// fused CPU path, exercised end to end.
    pub fn cmdstream(cfg: RunnerConfig) -> Result<Self> {
        Self::new(Box::new(crate::cmd::CmdBackend::new()), cfg)
    }

    /// Open the PJRT runtime from an artifact directory
    /// (see `make artifacts`).
    #[cfg(feature = "pjrt")]
    pub fn from_manifest_dir(dir: impl AsRef<std::path::Path>, cfg: RunnerConfig) -> Result<Self> {
        Self::new(Box::new(crate::runtime::pjrt::DeviceRuntime::new(dir)?), cfg)
    }

    /// Best available backend: the PJRT artifact runtime when the
    /// crate was built with `pjrt`, `dir` holds a manifest *and* the
    /// device opens (the stub `xla` crate, for instance, cannot) —
    /// otherwise the emulated device. This is what the CLI, benches
    /// and examples use so they run in any build.
    pub fn auto(dir: impl AsRef<std::path::Path>, cfg: RunnerConfig) -> Result<Self> {
        #[cfg(feature = "pjrt")]
        {
            if dir.as_ref().join("manifest.json").exists() {
                match crate::runtime::pjrt::DeviceRuntime::new(&dir) {
                    Ok(rt) => return Self::new(Box::new(rt), cfg),
                    Err(e) => crate::trace::log!(
                        Warn,
                        "coordinator",
                        "pjrt_unavailable",
                        "error" => format!("{e:#}"),
                        "fallback" => "emulated",
                    ),
                }
            }
        }
        let _ = &dir;
        let mut r = Self::emulated(cfg)?;
        r.autotune_armed = r.cfg.autotune;
        Ok(r)
    }
}

impl SharedBfastRunner {
    /// Emulated backend behind a thread-shareable runner (see
    /// [`SharedBfastRunner`]).
    pub fn emulated_shared(cfg: RunnerConfig) -> Result<Self> {
        Self::new(Box::new(EmulatedDevice::new()), cfg)
    }
}

impl<B: ?Sized + ExecutorBackend> BfastRunner<B> {
    /// Wrap an arbitrary backend.
    pub fn new(backend: Box<B>, cfg: RunnerConfig) -> Result<Self> {
        ensure!(cfg.queue_depth >= 1, "queue_depth must be >= 1");
        ensure!(cfg.staging_threads >= 1, "staging_threads must be >= 1");
        Ok(Self { backend, cfg, tuned: std::sync::OnceLock::new(), autotune_armed: false })
    }

    /// The backend in use.
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Human-readable backend/platform description.
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Apply [`RunnerConfig::m_chunk`] to a resolved spec, if set.
    /// Fails with a **typed validation error** ([`crate::api::invalid`],
    /// detectable via [`crate::api::is_invalid`], a 400 at the serving
    /// layer) when the width is zero or the backend runs
    /// shape-specialised artifacts (its chunk width is baked into the
    /// compiled executable) — the override is never silently padded
    /// or dropped.
    fn apply_chunk_override(&self, spec: &mut crate::runtime::ArtifactSpec) -> Result<()> {
        if let Some(mc) = self.cfg.m_chunk {
            if mc < 1 {
                return Err(crate::api::invalid("m_chunk override must be >= 1"));
            }
            if !self.backend.flexible_chunk() {
                return Err(crate::api::invalid(format!(
                    "backend {} runs shape-specialised artifacts; its m_chunk cannot be \
                     overridden",
                    self.backend.platform()
                )));
            }
            spec.m_chunk = mc;
        }
        Ok(())
    }

    /// First-run chunk-width autotune (see [`RunnerConfig::autotune`]).
    /// Failure is never fatal: a tuning error logs a warning and the
    /// backend-resolved width stands.
    fn autotuned_chunk(&self, params: &BfastParams, m: usize) -> Option<usize> {
        *self.tuned.get_or_init(|| {
            let tune_m = m.min(4096);
            let cands: Vec<usize> = crate::bench::TUNE_CANDIDATES
                .iter()
                .copied()
                .filter(|&c| c < m && c <= tune_m)
                .collect();
            if cands.len() < 2 {
                return None; // nothing to choose between
            }
            match crate::bench::tune_m_chunk(params, tune_m, &cands, 1) {
                Ok((best, _)) => Some(best),
                Err(e) => {
                    crate::trace::log!(
                        Warn,
                        "coordinator",
                        "autotune_failed",
                        "error" => format!("{e:#}"),
                    );
                    None
                }
            }
        })
    }

    /// The chunk width the first-run autotuner settled on, if it ran
    /// and picked one.
    pub fn tuned_m_chunk(&self) -> Option<usize> {
        self.tuned.get().copied().flatten()
    }

    /// Analyse a scene. Streams chunks through the staging → executor
    /// pipeline; returns the assembled break map plus phase timings
    /// (executor phases + accumulated staging time).
    pub fn run(&self, stack: &TimeStack, params: &BfastParams) -> Result<RunResult> {
        self.run_with_progress(stack, params, &CancelToken::new(), |_, _| {})
    }

    /// [`BfastRunner::run`] with progress observation and cooperative
    /// cancellation: after every executed chunk,
    /// `progress(chunks_done, chunks_total)` fires on the executor
    /// thread (the serving layer's job scheduler feeds its
    /// `running/{progress}` status from it), and `cancel` is checked
    /// at every chunk boundary — once set, the run stops staging,
    /// drains in-flight chunks and returns
    /// [`crate::api::cancelled`] instead of a result.
    pub fn run_with_progress(
        &self,
        stack: &TimeStack,
        params: &BfastParams,
        cancel: &CancelToken,
        progress: impl Fn(usize, usize),
    ) -> Result<RunResult> {
        params.validate()?;
        if cancel.is_cancelled() {
            return Err(crate::api::cancelled());
        }
        ensure!(
            stack.n_times() == params.n_total,
            "stack has {} layers, params expect N={}",
            stack.n_times(),
            params.n_total
        );
        let t0 = Instant::now();
        let mut spec = self
            .backend
            .resolve(self.cfg.artifact.as_deref(), params)?;
        let name = spec.name.clone();
        ensure_spec_shape(&spec, params)?;
        self.apply_chunk_override(&mut spec)?;
        let m = stack.n_pixels();
        let want_tune =
            self.cfg.m_chunk.is_none() && self.autotune_armed && self.backend.flexible_chunk();
        if want_tune {
            if let Some(mc) = self.autotuned_chunk(params, m) {
                spec.m_chunk = mc;
            }
        }
        let plan = ChunkPlan::new(m, spec.m_chunk);
        let t_axis: Vec<f32> = stack.time_axis.iter().map(|&v| v as f32).collect();
        let freq = params.freq as f32;
        let lambda = params.lambda as f32;

        let mut map = BreakMap::zeros(m);
        let mut phases = PhaseTimes::new();
        let staging_ns = AtomicUsize::new(0);
        let chunk_len = spec.n_total * spec.m_chunk;

        // Compile/load before the clock starts ticking per-chunk
        // (one-time; backends cache across runs of the same runner).
        let mut exec = self.backend.load(&spec, self.cfg.phased)?;

        if plan.is_empty() {
            return Ok(RunResult {
                map,
                phases,
                chunks: 0,
                artifact: name,
                wall: t0.elapsed(),
            });
        }

        let (full_tx, full_rx) =
            mpsc::sync_channel::<(crate::raster::PixelChunk, Vec<f32>)>(self.cfg.queue_depth);
        let (free_tx, free_rx) = mpsc::channel::<Vec<f32>>();
        // Pre-seed the free list: queue_depth in flight + one being
        // staged per worker.
        for _ in 0..self.cfg.queue_depth + self.cfg.staging_threads {
            let _ = free_tx.send(vec![0.0f32; chunk_len]);
        }
        let next_chunk = AtomicUsize::new(0);
        let fill_missing = self.cfg.fill_missing;
        let n_workers = self.cfg.staging_threads.min(plan.len());

        let free_rx = std::sync::Mutex::new(free_rx);
        // The run-level span (opened by the serving layer or shard
        // front door) is on *this* thread's stack; chunk spans open
        // under it via the handle so they parent correctly even though
        // the executor loop runs inside the scope closure.
        let run_span = crate::trace::current_handle();
        let result: Result<()> = std::thread::scope(|scope| {
            // --- staging workers ---------------------------------------
            for _ in 0..n_workers {
                let full_tx = full_tx.clone();
                let plan = &plan;
                let next_chunk = &next_chunk;
                let staging_ns = &staging_ns;
                let free_rx = &free_rx;
                scope.spawn(move || {
                    loop {
                        let idx = next_chunk.fetch_add(1, Ordering::Relaxed);
                        if idx >= plan.len() {
                            break;
                        }
                        let chunk = plan.get(idx);
                        let mut buf = free_rx
                            .lock()
                            .unwrap()
                            .recv()
                            .unwrap_or_else(|_| vec![0.0f32; chunk_len]);
                        if buf.len() != chunk_len {
                            buf = vec![0.0f32; chunk_len];
                        }
                        let s0 = Instant::now();
                        stack.copy_chunk_padded(
                            chunk.start,
                            chunk.end,
                            chunk.padded,
                            0.0,
                            &mut buf,
                        );
                        if fill_missing {
                            fill::fill_columns(&mut buf, spec.n_total, chunk.padded);
                        }
                        staging_ns
                            .fetch_add(s0.elapsed().as_nanos() as usize, Ordering::Relaxed);
                        if full_tx.send((chunk, buf)).is_err() {
                            break; // executor bailed
                        }
                    }
                });
            }
            drop(full_tx);

            // --- executor (this thread owns the backend handles) --------
            // On executor failure, keep draining (and recycling) so the
            // staging workers can finish and the scope join completes —
            // bailing out of the loop directly would leave workers
            // blocked on a full queue / empty free list forever.
            let mut done = 0usize;
            let mut exec_err = None;
            while let Ok((chunk, buf)) = full_rx.recv() {
                if exec_err.is_none() && cancel.is_cancelled() {
                    exec_err = Some(crate::api::cancelled());
                    // same early-stop contract as the failure path
                    next_chunk.store(plan.len(), Ordering::Relaxed);
                }
                if exec_err.is_none() {
                    let _chunk_span = crate::trace::span_under(&run_span, "chunk").map(|s| {
                        s.with_attr("chunk", chunk.index)
                            .with_attr("pixels_start", chunk.start)
                            .with_attr("pixels_end", chunk.end)
                    });
                    match exec.run_chunk(&t_axis, freq, &buf, lambda, &mut phases) {
                        Ok(out) => {
                            let w = chunk.width();
                            map.write_at(
                                chunk.start,
                                &out.breaks[..w],
                                &out.first[..w],
                                &out.momax[..w],
                            );
                            done += 1;
                            progress(done, plan.len());
                        }
                        Err(e) => {
                            exec_err = Some(e);
                            // Exhaust the chunk counter so staging
                            // workers stop after their current chunk
                            // instead of staging the rest of the scene.
                            next_chunk.store(plan.len(), Ordering::Relaxed);
                        }
                    }
                }
                let _ = free_tx.send(buf); // recycle (also while draining)
            }
            if let Some(e) = exec_err {
                return Err(e);
            }
            ensure!(done == plan.len(), "executor saw {done}/{} chunks", plan.len());
            Ok(())
        });
        result?;
        phases.add(
            PHASE_STAGING,
            std::time::Duration::from_nanos(staging_ns.load(Ordering::Relaxed) as u64),
        );
        Ok(RunResult {
            map,
            phases,
            chunks: plan.len(),
            artifact: name,
            wall: t0.elapsed(),
        })
    }

    /// Record the chunk contract for one scene into a replayable
    /// [`crate::cmd::CmdStream`] instead of executing it. The stream
    /// captures exactly what [`BfastRunner::run`] would feed the
    /// executor — the same resolved chunk plan and the same staged
    /// (raw, pre-fill) bytes, with gap-fill carried as its own op —
    /// so replaying it is bit-identical to the direct run. Recording
    /// never consults the autotuner: a captured stream must mean the
    /// same thing on every machine that replays it.
    pub fn record(
        &self,
        stack: &TimeStack,
        params: &BfastParams,
        tag: &str,
    ) -> Result<crate::cmd::CmdStream> {
        self.record_jobs(&[crate::cmd::RecordJob { tag: tag.to_string(), stack, params }])
    }

    /// [`BfastRunner::record`] over several jobs sharing one chunk
    /// contract (see [`crate::cmd::record_stream`]) — the serve
    /// scheduler's batching path records compatible queued requests
    /// into one stream through this.
    pub fn record_jobs(&self, jobs: &[crate::cmd::RecordJob<'_>]) -> Result<crate::cmd::CmdStream> {
        let first = jobs.first().context("record_jobs: no jobs")?;
        first.params.validate()?;
        ensure!(
            first.stack.n_times() == first.params.n_total,
            "stack has {} layers, params expect N={}",
            first.stack.n_times(),
            first.params.n_total
        );
        let mut spec = self.backend.resolve(self.cfg.artifact.as_deref(), first.params)?;
        ensure_spec_shape(&spec, first.params)?;
        self.apply_chunk_override(&mut spec)?;
        crate::cmd::record_stream(jobs, spec.m_chunk, self.cfg.fill_missing)
    }

    /// Record a scene and immediately replay the stream: returns both
    /// the reusable [`crate::cmd::CmdStream`] (encode it to `.bcmd`)
    /// and a [`RunResult`] bit-identical to [`BfastRunner::run`].
    pub fn record_run(
        &self,
        stack: &TimeStack,
        params: &BfastParams,
        tag: &str,
    ) -> Result<(crate::cmd::CmdStream, RunResult)> {
        let t0 = Instant::now();
        let stream = self.record(stack, params, tag)?;
        let mut phases = PhaseTimes::new();
        let maps = crate::cmd::ReplayExecutor::new().execute(&stream, &mut phases)?;
        let map = maps.into_iter().next().context("replay produced no job results")?;
        let chunks = stream.chunks_of(0);
        let res = RunResult {
            map,
            phases,
            chunks,
            artifact: crate::cmd::REPLAY_ENGINE.to_string(),
            wall: t0.elapsed(),
        };
        Ok((stream, res))
    }

    /// Execute several compatible jobs through **one** recorded stream
    /// on one prepared engine — the batching path behind the serve
    /// scheduler. Returns one [`RunResult`] per job, in order, each
    /// bit-identical to running that job alone (pinned by
    /// `tests/cmdstream.rs`). Phase times and wall time are
    /// stream-wide (the work was genuinely shared) and repeat in every
    /// result.
    pub fn run_recorded(&self, jobs: &[crate::cmd::RecordJob<'_>]) -> Result<Vec<RunResult>> {
        let t0 = Instant::now();
        let stream = self.record_jobs(jobs)?;
        let mut phases = PhaseTimes::new();
        let maps = crate::cmd::ReplayExecutor::new().execute(&stream, &mut phases)?;
        let wall = t0.elapsed();
        Ok(maps
            .into_iter()
            .enumerate()
            .map(|(ji, map)| RunResult {
                map,
                phases: phases.clone(),
                chunks: stream.chunks_of(ji as u32),
                artifact: crate::cmd::REPLAY_ENGINE.to_string(),
                wall,
            })
            .collect())
    }

    /// Open an incremental [`MonitorSession`] over an initial archive:
    /// the staged history pass runs once, sharded with the same chunk
    /// plan this runner's backend resolves for the analysis shape, and
    /// subsequent layers are absorbed by `session.ingest` in O(m·p)
    /// with no refit. The session's break map after ingesting layers
    /// `n+1..=N` is bit-identical to [`BfastRunner::run`] on the full
    /// N-layer stack (pinned by `tests/monitor.rs`).
    pub fn start_monitor(
        &self,
        stack: &TimeStack,
        params: &BfastParams,
    ) -> Result<crate::monitor::MonitorSession> {
        let mut spec = self.backend.resolve(self.cfg.artifact.as_deref(), params)?;
        ensure_spec_shape(&spec, params)?;
        self.apply_chunk_override(&mut spec)?;
        let cfg = crate::monitor::MonitorConfig {
            m_chunk: spec.m_chunk,
            threads: crate::threadpool::default_threads(),
            fill_missing: self.cfg.fill_missing,
        };
        crate::monitor::MonitorSession::start(stack, params, cfg)
    }

    /// Post-hoc inspection of a single pixel on the CPU — the paper's
    /// workflow for analysing intermediaries (residuals, MOSUM) of
    /// interesting pixels after the device pass located the breaks.
    pub fn inspect_pixel(
        &self,
        stack: &TimeStack,
        params: &BfastParams,
        pixel: usize,
    ) -> Result<PixelResult> {
        ensure!(pixel < stack.n_pixels(), "pixel {pixel} out of range");
        let direct = DirectBfast::new(params.clone(), &stack.time_axis)?;
        let mut y = stack.series_f64(pixel);
        // mirror staging-side gap handling
        let mut yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
        fill::fill_series(&mut yf);
        for (a, &b) in y.iter_mut().zip(&yf) {
            *a = b as f64;
        }
        direct.run_pixel(&y).context("inspect pixel")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::PhaseTimes;
    use crate::runtime::{ArtifactSpec, ChunkExecutor, EmulatedDevice};

    /// Backend whose executor always fails — exercises the mid-run
    /// error path of the coordinator loop.
    struct FailingBackend;

    struct FailingExec;

    impl ChunkExecutor for FailingExec {
        fn run_chunk(
            &mut self,
            _t_axis: &[f32],
            _freq: f32,
            _y: &[f32],
            _lambda: f32,
            _times: &mut PhaseTimes,
        ) -> Result<ChunkOutput> {
            crate::bail!("injected executor failure")
        }
    }

    impl ExecutorBackend for FailingBackend {
        fn platform(&self) -> String {
            "failing (test)".into()
        }

        fn resolve(&self, artifact: Option<&str>, params: &BfastParams) -> Result<ArtifactSpec> {
            EmulatedDevice::new().with_m_chunk(8).resolve(artifact, params)
        }

        fn load<'a>(
            &'a self,
            _spec: &ArtifactSpec,
            _phased: bool,
        ) -> Result<Box<dyn ChunkExecutor + 'a>> {
            Ok(Box::new(FailingExec))
        }
    }

    #[test]
    fn executor_error_surfaces_instead_of_deadlocking() {
        // More chunks than queue_depth + staging_threads so staging
        // would block forever if the executor bailed without draining.
        let params = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap();
        let data = crate::synth::ArtificialDataset::new(params.clone(), 200, 1).generate();
        let runner = BfastRunner::new(
            Box::new(FailingBackend),
            RunnerConfig { queue_depth: 1, staging_threads: 2, ..Default::default() },
        )
        .unwrap();
        let err = runner.run(&data.stack, &params).unwrap_err().to_string();
        assert!(err.contains("injected executor failure"), "{err}");
    }

    #[test]
    fn config_validation() {
        let bad = RunnerConfig { queue_depth: 0, ..Default::default() };
        assert!(BfastRunner::emulated(bad).is_err());
        let bad = RunnerConfig { staging_threads: 0, ..Default::default() };
        assert!(BfastRunner::emulated(bad).is_err());
    }

    #[test]
    fn start_monitor_matches_run_on_same_stack() {
        let params = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap();
        let data = crate::synth::ArtificialDataset::new(params.clone(), 300, 7).generate();
        let runner = BfastRunner::new(
            Box::new(EmulatedDevice::new().with_m_chunk(64)),
            RunnerConfig::default(),
        )
        .unwrap();
        let session = runner.start_monitor(&data.stack, &params).unwrap();
        let res = runner.run(&data.stack, &params).unwrap();
        let map = session.break_map();
        assert_eq!(map.breaks, res.map.breaks);
        assert_eq!(map.first, res.map.first);
        assert_eq!(map.momax, res.map.momax);
    }

    #[test]
    fn auto_falls_back_to_emulated() {
        let r = BfastRunner::auto("/nonexistent/artifacts", RunnerConfig::default()).unwrap();
        assert!(r.platform().contains("emulated"), "{}", r.platform());
    }

    #[test]
    fn m_chunk_override_applies_to_flexible_backend() {
        let params = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap();
        let data = crate::synth::ArtificialDataset::new(params.clone(), 100, 3).generate();
        let base = BfastRunner::emulated(RunnerConfig::default()).unwrap();
        let want = base.run(&data.stack, &params).unwrap();
        let runner = BfastRunner::emulated(RunnerConfig {
            m_chunk: Some(7),
            ..Default::default()
        })
        .unwrap();
        let res = runner.run(&data.stack, &params).unwrap();
        assert_eq!(res.chunks, 100usize.div_ceil(7), "override drives the chunk plan");
        // chunk geometry never changes the arithmetic
        assert_eq!(res.map.breaks, want.map.breaks);
        assert_eq!(res.map.first, want.map.first);
        let same = res
            .map
            .momax
            .iter()
            .zip(&want.map.momax)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "momax must be bit-identical across chunk widths");
    }

    #[test]
    fn m_chunk_override_rejected_by_shape_specialised_backend() {
        // FailingBackend leaves flexible_chunk at its default (false):
        // the override must be refused before any chunk runs.
        let params = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap();
        let data = crate::synth::ArtificialDataset::new(params.clone(), 20, 1).generate();
        let runner = BfastRunner::new(
            Box::new(FailingBackend),
            RunnerConfig { m_chunk: Some(16), ..Default::default() },
        )
        .unwrap();
        let err = runner.run(&data.stack, &params).unwrap_err().to_string();
        assert!(err.contains("cannot be overridden"), "{err}");
        let bad = BfastRunner::emulated(RunnerConfig {
            m_chunk: Some(0),
            ..Default::default()
        })
        .unwrap();
        assert!(bad.run(&data.stack, &params).is_err(), "m_chunk=0 must be rejected");
    }

    #[test]
    fn m_chunk_override_errors_are_typed_invalid() {
        let params = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap();
        let data = crate::synth::ArtificialDataset::new(params.clone(), 20, 1).generate();
        let runner = BfastRunner::new(
            Box::new(FailingBackend),
            RunnerConfig { m_chunk: Some(16), ..Default::default() },
        )
        .unwrap();
        let err = runner.run(&data.stack, &params).unwrap_err();
        assert!(crate::api::is_invalid(&err), "shape-specialised rejection is typed: {err:#}");
        let bad = BfastRunner::emulated(RunnerConfig {
            m_chunk: Some(0),
            ..Default::default()
        })
        .unwrap();
        let err = bad.run(&data.stack, &params).unwrap_err();
        assert!(crate::api::is_invalid(&err), "m_chunk=0 rejection is typed: {err:#}");
    }

    #[test]
    fn autotuned_auto_runner_stays_bit_identical_to_untuned() {
        let params = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap();
        let data = crate::synth::ArtificialDataset::new(params.clone(), 600, 11).generate();
        let plain = BfastRunner::auto(
            "/nonexistent/artifacts",
            RunnerConfig { autotune: false, ..Default::default() },
        )
        .unwrap();
        assert!(!plain.cfg.autotune);
        let want = plain.run(&data.stack, &params).unwrap();
        assert!(plain.tuned_m_chunk().is_none(), "opted-out runner must not tune");

        let tuned = BfastRunner::auto("/nonexistent/artifacts", RunnerConfig::default()).unwrap();
        let got = tuned.run(&data.stack, &params).unwrap();
        let pick = tuned.tuned_m_chunk();
        assert!(pick.is_some(), "600 px admits two candidates, tuning must pick one");
        assert!(crate::bench::TUNE_CANDIDATES.contains(&pick.unwrap()));
        assert_eq!(got.map.breaks, want.map.breaks);
        assert_eq!(got.map.first, want.map.first);
        let same = got
            .map
            .momax
            .iter()
            .zip(&want.map.momax)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "the tuned chunk width must not change the arithmetic");
    }

    #[test]
    fn record_run_matches_the_streamed_run() {
        let params = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap();
        let data = crate::synth::ArtificialDataset::new(params.clone(), 150, 5).generate();
        let runner = BfastRunner::emulated(RunnerConfig {
            m_chunk: Some(64),
            ..Default::default()
        })
        .unwrap();
        let want = runner.run(&data.stack, &params).unwrap();
        let (stream, res) = runner.record_run(&data.stack, &params, "scene").unwrap();
        assert_eq!(stream.jobs.len(), 1);
        assert_eq!(stream.header.m_chunk, 64, "override drives the recorded plan");
        assert_eq!(res.chunks, want.chunks);
        assert_eq!(res.artifact, crate::cmd::REPLAY_ENGINE);
        assert_eq!(res.map.breaks, want.map.breaks);
        assert_eq!(res.map.first, want.map.first);
        let same = res
            .map
            .momax
            .iter()
            .zip(&want.map.momax)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "recorded replay must be bit-identical to the streamed run");
    }

    #[test]
    fn run_recorded_batches_jobs_without_changing_their_results() {
        let params = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap();
        let a = crate::synth::ArtificialDataset::new(params.clone(), 40, 6).generate();
        let b = crate::synth::ArtificialDataset::new(params.clone(), 25, 7).generate();
        let runner = BfastRunner::emulated(RunnerConfig {
            m_chunk: Some(16),
            ..Default::default()
        })
        .unwrap();
        let res = runner
            .run_recorded(&[
                crate::cmd::RecordJob { tag: "a".into(), stack: &a.stack, params: &params },
                crate::cmd::RecordJob { tag: "b".into(), stack: &b.stack, params: &params },
            ])
            .unwrap();
        assert_eq!(res.len(), 2);
        assert_eq!((res[0].chunks, res[1].chunks), (3, 2));
        let solo_a = runner.run(&a.stack, &params).unwrap();
        let solo_b = runner.run(&b.stack, &params).unwrap();
        assert_eq!(res[0].map.breaks, solo_a.map.breaks);
        assert_eq!(res[1].map.breaks, solo_b.map.breaks);
        assert_eq!(res[0].map.first, solo_a.map.first);
        assert_eq!(res[1].map.first, solo_b.map.first);
    }
}
