//! Missing-value handling (paper footnote 2: *"in case of almost
//! complete time series, one can resort to simple schemes such as
//! forward/backward filling to remove the missing values (spending
//! linear time)"*).
//!
//! Missing observations are encoded as NaN. [`fill_series`] runs
//! forward fill then backward fill over one series; [`fill_stack`]
//! applies it to every pixel of a time-major stack in parallel.

use crate::raster::TimeStack;
use crate::threadpool::{self, SyncSlice};

/// Per-pixel validity statistics of a stack.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ValidityStats {
    /// Pixels with at least one missing observation.
    pub pixels_with_gaps: usize,
    /// Pixels that are entirely missing (cannot be filled).
    pub pixels_all_missing: usize,
    /// Total missing observations.
    pub missing_values: usize,
    /// Longest run of consecutive missing values seen anywhere.
    pub longest_gap: usize,
}

/// Forward fill then backward fill one series in place.
/// Returns the number of values that were missing. A series that is
/// entirely NaN is left untouched.
pub fn fill_series(y: &mut [f32]) -> usize {
    let mut missing = 0;
    let mut last: Option<f32> = None;
    for v in y.iter_mut() {
        if v.is_nan() {
            missing += 1;
            if let Some(l) = last {
                *v = l;
            }
        } else {
            last = Some(*v);
        }
    }
    if missing == 0 || last.is_none() {
        return missing; // complete, or all-NaN
    }
    // leading NaNs remain — backward fill
    let mut next: Option<f32> = None;
    for v in y.iter_mut().rev() {
        if v.is_nan() {
            if let Some(nx) = next {
                *v = nx;
            }
        } else {
            next = Some(*v);
        }
    }
    missing
}

/// Gap statistics of one series (does not modify it).
pub fn series_stats(y: &[f32]) -> (usize, usize) {
    let mut missing = 0;
    let mut longest = 0;
    let mut run = 0;
    for &v in y {
        if v.is_nan() {
            missing += 1;
            run += 1;
            longest = longest.max(run);
        } else {
            run = 0;
        }
    }
    (missing, longest)
}

/// Forward/backward fill each column of a time-major buffer
/// (`n_times × width`) in place — the staging-side gap handling shared
/// by the coordinator's chunk workers and the monitor session's
/// history pass. Per-column arithmetic is exactly [`fill_series`], so
/// the result is independent of how a scene is chunked.
pub fn fill_columns(buf: &mut [f32], n_times: usize, width: usize) {
    debug_assert_eq!(buf.len(), n_times * width);
    // Fast path: no NaN anywhere (bulk scan is vectorisable).
    if !buf.iter().any(|v| v.is_nan()) {
        return;
    }
    let mut series = vec![0.0f32; n_times];
    for col in 0..width {
        let mut has_nan = false;
        for t in 0..n_times {
            let v = buf[t * width + col];
            series[t] = v;
            has_nan |= v.is_nan();
        }
        if !has_nan {
            continue;
        }
        fill_series(&mut series);
        for t in 0..n_times {
            buf[t * width + col] = series[t];
        }
    }
}

/// Fill every pixel of a stack in place (parallel over pixels).
/// Stacks are time-major (`N × m`), so per-pixel series are strided;
/// each worker gathers, fills, and scatters its pixel range.
pub fn fill_stack(stack: &mut TimeStack, threads: usize) -> ValidityStats {
    let n = stack.n_times();
    let m = stack.n_pixels();
    use std::sync::atomic::{AtomicUsize, Ordering};
    let gaps = AtomicUsize::new(0);
    let all_missing = AtomicUsize::new(0);
    let missing_total = AtomicUsize::new(0);
    let longest = AtomicUsize::new(0);
    {
        let data = SyncSlice::new(stack.data_mut());
        threadpool::parallel_ranges(m, 1024, threads, |s, e| {
            let mut series = vec![0.0f32; n];
            for px in s..e {
                // gather strided series (each worker owns its pixel range)
                for (t, s) in series.iter_mut().enumerate() {
                    *s = unsafe { data.read(t * m + px) };
                }
                let (miss, run) = series_stats(&series);
                if miss == 0 {
                    continue;
                }
                gaps.fetch_add(1, Ordering::Relaxed);
                missing_total.fetch_add(miss, Ordering::Relaxed);
                longest.fetch_max(run, Ordering::Relaxed);
                if miss == n {
                    all_missing.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                fill_series(&mut series);
                for t in 0..n {
                    unsafe { data.write(t * m + px, series[t]) };
                }
            }
        });
    }
    ValidityStats {
        pixels_with_gaps: gaps.load(Ordering::Relaxed),
        pixels_all_missing: all_missing.load(Ordering::Relaxed),
        missing_values: missing_total.load(Ordering::Relaxed),
        longest_gap: longest.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_then_backward() {
        let mut y = vec![f32::NAN, f32::NAN, 1.0, f32::NAN, 3.0, f32::NAN];
        let miss = fill_series(&mut y);
        assert_eq!(miss, 4);
        assert_eq!(y, vec![1.0, 1.0, 1.0, 1.0, 3.0, 3.0]);
    }

    #[test]
    fn complete_series_untouched() {
        let mut y = vec![1.0, 2.0, 3.0];
        assert_eq!(fill_series(&mut y), 0);
        assert_eq!(y, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn all_nan_left_alone() {
        let mut y = vec![f32::NAN; 4];
        assert_eq!(fill_series(&mut y), 4);
        assert!(y.iter().all(|v| v.is_nan()));
    }

    #[test]
    fn stats_longest_gap() {
        let y = [1.0, f32::NAN, f32::NAN, 2.0, f32::NAN, f32::NAN, f32::NAN, 3.0];
        assert_eq!(series_stats(&y), (5, 3));
    }

    #[test]
    fn fill_columns_handles_columns_independently() {
        // 3 times × 2 cols; col 0 has a gap, col 1 complete
        let mut buf = vec![1.0, 10.0, f32::NAN, 20.0, 3.0, 30.0];
        fill_columns(&mut buf, 3, 2);
        assert_eq!(buf, vec![1.0, 10.0, 1.0, 20.0, 3.0, 30.0]);
    }

    #[test]
    fn fill_columns_noop_when_complete() {
        let mut buf = vec![1.0f32; 12];
        fill_columns(&mut buf, 3, 4);
        assert_eq!(buf, vec![1.0f32; 12]);
    }

    #[test]
    fn stack_fill_parallel_matches_serial() {
        let (n, m) = (10, 500);
        let mut data = vec![0.0f32; n * m];
        for (i, v) in data.iter_mut().enumerate() {
            *v = (i % 17) as f32;
        }
        // punch holes
        for px in (0..m).step_by(3) {
            for t in (px % 4)..(px % 4 + 3).min(n) {
                data[t * m + px] = f32::NAN;
            }
        }
        let mut s1 = TimeStack::from_vec(n, m, data.clone()).unwrap();
        let mut s2 = TimeStack::from_vec(n, m, data).unwrap();
        let st1 = fill_stack(&mut s1, 1);
        let st2 = fill_stack(&mut s2, 8);
        assert_eq!(st1, st2);
        assert_eq!(s1.data(), s2.data());
        assert!(st1.pixels_with_gaps > 0);
        assert!(!s1.data().iter().any(|v| v.is_nan()));
    }
}
