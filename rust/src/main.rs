//! `bfast` — the leader binary: generate data, run break detection
//! through any of the four implementations, inspect pixels, and print
//! critical-value tables.

use bfast::cli::Command;
use bfast::error::{bail, Result};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::params::BfastParams;
use bfast::pixel::{DirectBfast, NaiveBfast};
use bfast::raster::{io as rio, pgm};
use bfast::synth::{ArtificialDataset, ChileScene};
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

const TOPLEVEL: &str = "\
bfast — massively-parallel break detection for satellite data

USAGE: bfast <command> [flags]   (bfast <command> --help for details)

COMMANDS:
  info          show executor backend + artifact manifest
  generate      write a synthetic .bsq stack (artificial or chile)
  run           analyse a .bsq stack (engine: device|emulated|cpu|direct|naive)
  inspect       per-pixel MOSUM/fit details for one pixel
  lambda-table  print simulated critical values λ(α, h/n)
";

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print!("{TOPLEVEL}");
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "info" => cmd_info(rest),
        "generate" => cmd_generate(rest),
        "run" => cmd_run(rest),
        "inspect" => cmd_inspect(rest),
        "lambda-table" => cmd_lambda(rest),
        "--help" | "-h" | "help" => {
            print!("{TOPLEVEL}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{TOPLEVEL}"),
    }
}

fn params_from(m: &bfast::cli::Matches) -> Result<BfastParams> {
    let n_total = m.usize("n-total")?;
    let n_hist = m.usize("n-hist")?;
    BfastParams::new(
        n_total,
        n_hist,
        m.usize("h")?,
        m.usize("k")?,
        m.f64("freq")?,
        m.f64("alpha")?,
    )
}

fn param_flags(c: Command) -> Command {
    c.opt("n-total", "200", "series length N")
        .opt("n-hist", "100", "stable history length n")
        .opt("h", "50", "MOSUM bandwidth")
        .opt("k", "3", "harmonic terms")
        .opt("freq", "23", "observations per period f")
        .opt("alpha", "0.05", "significance level")
}

fn cmd_info(args: &[String]) -> Result<()> {
    let cmd = Command::new("info", "show backend + artifacts")
        .opt("artifacts", "artifacts", "artifact directory");
    let m = cmd.parse(args)?;
    let runner = BfastRunner::auto(m.str("artifacts")?, RunnerConfig::default())?;
    println!("backend: {}", runner.platform());
    println!(
        "features: pjrt={}  (default backend: {})",
        cfg!(feature = "pjrt"),
        if cfg!(feature = "pjrt") { "device when artifacts exist" } else { "emulated" }
    );
    let dir = std::path::Path::new(m.str("artifacts")?);
    if dir.join("manifest.json").exists() {
        let man = bfast::runtime::Manifest::load(dir)?;
        println!("artifacts ({}):", man.artifacts.len());
        for a in &man.artifacts {
            println!(
                "  {:<14} {:<8} N={:<4} n={:<4} h={:<4} k={} m_chunk={:<6} pallas={}",
                a.name, a.phase, a.n_total, a.n_hist, a.h, a.k, a.m_chunk, a.use_pallas
            );
        }
    } else {
        println!(
            "no artifact manifest at {} — analyses run on the emulated backend",
            dir.display()
        );
    }
    Ok(())
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let cmd = param_flags(
        Command::new("generate", "write a synthetic stack")
            .req("out", "output .bsq path")
            .opt("kind", "artificial", "artificial | chile")
            .opt("m", "10000", "pixels (artificial)")
            .opt("width", "240", "scene width (chile)")
            .opt("height", "186", "scene height (chile)")
            .opt("seed", "42", "generator seed")
            .opt("cloud-rate", "0", "missing-value probability (chile)"),
    );
    let m = cmd.parse(args)?;
    let out = m.str("out")?;
    match m.str("kind")? {
        "artificial" => {
            let params = params_from(&m)?;
            let data = ArtificialDataset::new(params, m.usize("m")?, m.u64("seed")?).generate();
            rio::write_stack(out, &data.stack)?;
            println!(
                "wrote {out}: {} x {} (artificial, {} with injected breaks)",
                data.stack.n_times(),
                data.stack.n_pixels(),
                data.truth.iter().filter(|&&t| t).count()
            );
        }
        "chile" => {
            let scene = ChileScene {
                width: m.usize("width")?,
                height: m.usize("height")?,
                seed: m.u64("seed")?,
                cloud_rate: m.f64("cloud-rate")?,
                ..ChileScene::default()
            };
            let (stack, truth) = scene.generate();
            rio::write_stack(out, &stack)?;
            println!(
                "wrote {out}: {} x {} ({}x{} chile scene, {} forest px)",
                stack.n_times(),
                stack.n_pixels(),
                scene.width,
                scene.height,
                truth.is_forest.iter().filter(|&&f| f).count()
            );
        }
        other => bail!("unknown kind {other:?} (artificial|chile)"),
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let cmd = param_flags(
        Command::new("run", "analyse a stack")
            .req("input", "input .bsq stack")
            .opt("engine", "device", "device | emulated | cpu | direct | naive")
            .opt("artifacts", "artifacts", "artifact directory (device)")
            .opt("artifact", "", "artifact config name override (device)")
            .opt("queue-depth", "2", "staging queue depth (device)")
            .opt("staging-threads", "0", "staging threads, 0 = auto (device)")
            .opt("momax-pgm", "", "write max|MOSUM| heatmap PGM here")
            .switch("phased", "run the per-phase executables (instrumented)")
            .switch("timings", "print the phase breakdown"),
    );
    let m = cmd.parse(args)?;
    let stack = rio::read_stack(m.str("input")?)?;
    let params = params_from(&m)?;
    let t0 = Instant::now();
    let (map, phases) = match m.str("engine")? {
        engine @ ("device" | "emulated") => {
            let mut cfg = RunnerConfig {
                phased: m.flag("phased"),
                queue_depth: m.usize("queue-depth")?,
                ..Default::default()
            };
            if m.usize("staging-threads")? > 0 {
                cfg.staging_threads = m.usize("staging-threads")?;
            }
            let name = m.str("artifact")?;
            if !name.is_empty() {
                cfg.artifact = Some(name.to_string());
            }
            let mut runner = if engine == "emulated" {
                BfastRunner::emulated(cfg)?
            } else {
                BfastRunner::auto(m.str("artifacts")?, cfg)?
            };
            if engine == "device" && runner.platform().starts_with("emulated") {
                eprintln!(
                    "bfast: no device backend available (no artifacts at {:?}); \
                     running on the emulated backend — use --engine emulated to \
                     select it explicitly",
                    m.str("artifacts")?
                );
            }
            let res = runner.run(&stack, &params)?;
            println!(
                "{} run: backend={} artifact={} chunks={} wall={:.3}s",
                engine,
                runner.platform(),
                res.artifact,
                res.chunks,
                res.wall.as_secs_f64()
            );
            (res.map, Some(res.phases))
        }
        "cpu" => {
            let eng = FusedCpuBfast::new(params.clone(), &stack.time_axis)?;
            let (map, times) = eng.run(&stack)?;
            (map, Some(times))
        }
        "direct" => (DirectBfast::new(params.clone(), &stack.time_axis)?.run(&stack)?, None),
        "naive" => (NaiveBfast::new(params.clone()).run(&stack)?, None),
        other => bail!("unknown engine {other:?}"),
    };
    let wall = t0.elapsed();
    println!(
        "{} pixels, {} breaks ({:.2}%) in {:.3}s  [lambda={:.3}]",
        map.len(),
        map.break_count(),
        100.0 * map.break_fraction(),
        wall.as_secs_f64(),
        params.lambda
    );
    if m.flag("timings") {
        if let Some(p) = &phases {
            print!("{}", p.table("phase breakdown"));
        }
    }
    let pgm_path = m.str("momax-pgm")?;
    if !pgm_path.is_empty() {
        let (w, h) = match (stack.width, stack.height) {
            (Some(w), Some(h)) => (w, h),
            _ => (map.len(), 1),
        };
        let (lo, hi) = pgm::write_pgm_autoscale(pgm_path, &map.momax, w, h)?;
        println!("wrote {pgm_path} (scale {lo:.2}..{hi:.2})");
    }
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let cmd = param_flags(
        Command::new("inspect", "per-pixel detail")
            .req("input", "input .bsq stack")
            .req("pixel", "pixel index"),
    );
    let m = cmd.parse(args)?;
    let stack = rio::read_stack(m.str("input")?)?;
    let params = params_from(&m)?;
    let px = m.usize("pixel")?;
    // inspection is a pure-CPU path; any backend works
    let runner = BfastRunner::emulated(RunnerConfig::default())?;
    let res = runner.inspect_pixel(&stack, &params, px)?;
    println!(
        "pixel {px}: break={} first={} momax={:.3}",
        res.scan.has_break, res.scan.first, res.scan.momax
    );
    let bound = bfast::mosum::boundary(&params);
    println!("  t        MO_t     bound");
    for (i, (mo, b)) in res.mosum.iter().zip(&bound).enumerate() {
        let t = params.n_hist + 1 + i;
        let mark = if mo.abs() > *b { "  <-- break" } else { "" };
        println!("  {t:<6} {mo:>8.3}  {b:>8.3}{mark}");
    }
    Ok(())
}

fn cmd_lambda(args: &[String]) -> Result<()> {
    let cmd = Command::new("lambda-table", "simulated critical values")
        .opt("horizon", "2", "monitoring horizon N/n")
        .opt("alphas", "0.01,0.05,0.1", "comma-separated alphas (percent as fractions)")
        .opt("h-fracs", "0.25,0.5,1.0", "comma-separated h/n values");
    let m = cmd.parse(args)?;
    let alphas: Vec<f64> = m
        .str("alphas")?
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| bfast::err!("bad alpha {s:?}")))
        .collect::<Result<_>>()?;
    let hfracs: Vec<f64> = m
        .str("h-fracs")?
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| bfast::err!("bad h/n {s:?}")))
        .collect::<Result<_>>()?;
    print!("{}", bfast::lambda::table(m.f64("horizon")?, &alphas, &hfracs)?);
    Ok(())
}
