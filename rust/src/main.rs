//! `bfast` — the leader binary. Every subcommand is a thin shell over
//! the [`bfast::api`] front door: `run` parses its flags into an
//! `AnalysisRequest` and executes it, `client submit` posts the same
//! JSON the library speaks, `monitor --init` builds a `SessionInit`.

use bfast::api::{self, JobHandle};
use bfast::bench;
use bfast::cli::Command;
use bfast::error::{bail, ensure, Result};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::json;
use bfast::monitor::{self, MonitorSession};
use bfast::params::BfastParams;
use bfast::raster::{io as rio, pgm};
use bfast::runtime::bten::{bten_to_bytes, Tensor};
use bfast::serve::{http as shttp, ServeConfig, Server};
use bfast::store;
use bfast::synth::{ArtificialDataset, ChileScene};
use std::time::{Duration, Instant};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

const TOPLEVEL: &str = "\
bfast — massively-parallel break detection for satellite data

USAGE: bfast <command> [flags]   (bfast <command> --help for details)

COMMANDS:
  info          show executor backend + artifact manifest
  generate      write a synthetic .bsq stack (artificial or chile)
  run           analyse a .bsq stack (engine: device|emulated|cmd|cpu|direct|naive);
                --record FILE.bcmd captures the run as a command stream
  replay        re-execute a recorded .bcmd command stream bit-identically,
                or dump it as JSON (--dump)
  monitor       incremental session: one-time history pass, then ingest
                new layers (.bsq/.pgm) with no refit (--state dir/)
  serve         break-detection service: HTTP API, bounded job queue,
                live monitor sessions (--addr host:port --state dir/)
  shard         fan one analysis out across several serve workers and
                merge the shard maps bit-exactly (--workers a:p,b:p)
  gateway       resident fleet coordinator: health-checked workers,
                throughput-weighted placement, mid-run rebalancing
  client        talk to a running server (health | submit | cancel | ingest | ...)
  cache         inspect or clear a server's result cache (stats | clear)
  inspect       per-pixel MOSUM/fit details for one pixel
  lambda-table  print simulated critical values λ(α, h/n)
  bench         perf trajectory: run the pinned fig2/fig3 scenarios,
                diff two reports, validate report JSON, tune m_chunk
";

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print!("{TOPLEVEL}");
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "info" => cmd_info(rest),
        "generate" => cmd_generate(rest),
        "run" => cmd_run(rest),
        "replay" => cmd_replay(rest),
        "monitor" => cmd_monitor(rest),
        "serve" => cmd_serve(rest),
        "shard" => cmd_shard(rest),
        "gateway" => cmd_gateway(rest),
        "client" => cmd_client(rest),
        "cache" => cmd_cache(rest),
        "inspect" => cmd_inspect(rest),
        "lambda-table" => cmd_lambda(rest),
        "bench" => cmd_bench(rest),
        "--help" | "-h" | "help" => {
            print!("{TOPLEVEL}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{TOPLEVEL}"),
    }
}

fn params_from(m: &bfast::cli::Matches) -> Result<BfastParams> {
    let n_total = m.usize("n-total")?;
    let n_hist = m.usize("n-hist")?;
    BfastParams::new(
        n_total,
        n_hist,
        m.usize("h")?,
        m.usize("k")?,
        m.f64("freq")?,
        m.f64("alpha")?,
    )
}

use bfast::api::param_flags;

fn cmd_info(args: &[String]) -> Result<()> {
    let cmd = Command::new("info", "show backend + artifacts")
        .opt("artifacts", "artifacts", "artifact directory");
    let m = cmd.parse(args)?;
    let runner = BfastRunner::auto(m.str("artifacts")?, RunnerConfig::default())?;
    println!("backend: {}", runner.platform());
    println!(
        "features: pjrt={}  (default backend: {})",
        cfg!(feature = "pjrt"),
        if cfg!(feature = "pjrt") { "device when artifacts exist" } else { "emulated" }
    );
    let dir = std::path::Path::new(m.str("artifacts")?);
    if dir.join("manifest.json").exists() {
        let man = bfast::runtime::Manifest::load(dir)?;
        println!("artifacts ({}):", man.artifacts.len());
        for a in &man.artifacts {
            println!(
                "  {:<14} {:<8} N={:<4} n={:<4} h={:<4} k={} m_chunk={:<6} pallas={}",
                a.name, a.phase, a.n_total, a.n_hist, a.h, a.k, a.m_chunk, a.use_pallas
            );
        }
    } else {
        println!(
            "no artifact manifest at {} — analyses run on the emulated backend",
            dir.display()
        );
    }
    Ok(())
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let cmd = param_flags(
        Command::new("generate", "write a synthetic stack")
            .req("out", "output .bsq path")
            .opt("kind", "artificial", "artificial | chile")
            .opt("m", "10000", "pixels (artificial)")
            .opt("width", "240", "scene width (chile)")
            .opt("height", "186", "scene height (chile)")
            .opt("seed", "42", "generator seed")
            .opt("cloud-rate", "0", "missing-value probability (chile)"),
    );
    let m = cmd.parse(args)?;
    let out = m.str("out")?;
    match m.str("kind")? {
        "artificial" => {
            let params = params_from(&m)?;
            let data = ArtificialDataset::new(params, m.usize("m")?, m.u64("seed")?).generate();
            rio::write_stack(out, &data.stack)?;
            println!(
                "wrote {out}: {} x {} (artificial, {} with injected breaks)",
                data.stack.n_times(),
                data.stack.n_pixels(),
                data.truth.iter().filter(|&&t| t).count()
            );
        }
        "chile" => {
            let scene = ChileScene {
                width: m.usize("width")?,
                height: m.usize("height")?,
                seed: m.u64("seed")?,
                cloud_rate: m.f64("cloud-rate")?,
                ..ChileScene::default()
            };
            let (stack, truth) = scene.generate();
            rio::write_stack(out, &stack)?;
            println!(
                "wrote {out}: {} x {} ({}x{} chile scene, {} forest px)",
                stack.n_times(),
                stack.n_pixels(),
                scene.width,
                scene.height,
                truth.is_forest.iter().filter(|&&f| f).count()
            );
        }
        other => bail!("unknown kind {other:?} (artificial|chile)"),
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    // the whole command is one trip through the front door: flags →
    // AnalysisRequest → execute (bit-identical to a wire submit of the
    // same request — pinned by tests/api.rs)
    let m = api::run_command().parse(args)?;
    let req = api::run_request_from_matches(&m)?;
    let record_path = m.str("record")?;
    let res = if record_path.is_empty() {
        req.execute(&JobHandle::new())?
    } else {
        // record-then-replay: the .bcmd written here is the exact
        // stream whose replay produced the printed result, so
        // `bfast replay` reproduces the envelope byte-for-byte
        let (stream, res) = api::record_request(&req)?;
        let bytes = stream.encode();
        std::fs::write(record_path, &bytes)?;
        println!(
            "recorded {record_path}: {} op(s), {} chunk(s), {} bytes (re-run with `bfast replay`)",
            stream.ops.len(),
            res.chunks,
            bytes.len()
        );
        res
    };
    println!(
        "{} run: engine={} artifact={} chunks={} wall={:.3}s",
        req.engine.label(),
        res.engine,
        res.artifact,
        res.chunks,
        res.wall.as_secs_f64()
    );
    println!(
        "{} pixels, {} breaks ({:.2}%) in {:.3}s  [lambda={:.3}]",
        res.map.len(),
        res.map.break_count(),
        100.0 * res.map.break_fraction(),
        res.wall.as_secs_f64(),
        res.params.lambda
    );
    if req.outputs.timings {
        if let Some(p) = &res.phases {
            print!("{}", p.table("phase breakdown"));
        }
    }
    write_outputs(&req.outputs, &res)?;
    Ok(())
}

/// Honour the request's `outputs` section (shared by `run` and
/// `shard`): momax PGM heatmap and/or the v1 result envelope.
fn write_outputs(outputs: &bfast::api::OutputSpec, res: &bfast::api::AnalysisResult) -> Result<()> {
    if let Some(pgm_path) = &outputs.momax_pgm {
        let (w, h) = match (res.width, res.height) {
            (Some(w), Some(h)) => (w, h),
            _ => (res.map.len(), 1),
        };
        let (lo, hi) = pgm::write_pgm_autoscale(pgm_path, &res.map.momax, w, h)?;
        println!("wrote {pgm_path} (scale {lo:.2}..{hi:.2})");
    }
    if let Some(json_path) = &outputs.result_json {
        let text = res.to_json_string();
        std::fs::write(json_path, text.as_bytes())?;
        println!("wrote {json_path} ({} bytes, v1 result envelope)", text.len());
    }
    Ok(())
}

fn cmd_replay(args: &[String]) -> Result<()> {
    let cmd = Command::new(
        "replay",
        "re-execute a recorded .bcmd command stream through the replay executor \
         (bit-identical to the run that recorded it), or dump the decoded stream \
         as JSON for inspection.\n\nUSAGE: bfast replay FILE.bcmd [flags]",
    )
    .opt("result-json", "", "write the v1 result envelope here (.N suffix per extra job)")
    .opt("momax-pgm", "", "write the momax heatmap here (.N suffix per extra job)")
    .switch("dump", "print the stream as JSON instead of executing it");
    let m = cmd.parse(args)?;
    ensure!(m.positional.len() == 1, "usage: bfast replay FILE.bcmd\n\n{}", cmd.usage());
    let path = &m.positional[0];
    let bytes = std::fs::read(path)
        .map_err(|e| bfast::err!("{path}: {e} (expected a .bcmd from `bfast run --record`)"))?;
    let stream = bfast::cmd::CmdStream::decode(&bytes)?;
    if m.flag("dump") {
        println!("{}", stream.to_json().to_string_pretty());
        return Ok(());
    }
    let t0 = Instant::now();
    let results = bfast::cmd::replay_to_results(&stream)?;
    println!(
        "replayed {path}: {} job(s), {} op(s), m_chunk {} in {:.3}s",
        stream.jobs.len(),
        stream.ops.len(),
        stream.header.m_chunk,
        t0.elapsed().as_secs_f64()
    );
    for (job, res) in stream.jobs.iter().zip(&results) {
        println!(
            "  {}: {} pixels, {} breaks ({:.2}%)  [lambda={:.3}]",
            job.tag,
            res.map.len(),
            res.map.break_count(),
            100.0 * res.map.break_fraction(),
            res.params.lambda
        );
    }
    // single-job streams write outputs exactly like `run`; multi-job
    // streams suffix the job index so nothing is silently overwritten
    let result_json = m.str("result-json")?;
    let momax_pgm = m.str("momax-pgm")?;
    for (ji, res) in results.iter().enumerate() {
        let outputs = bfast::api::OutputSpec {
            momax_pgm: replay_out_path(momax_pgm, ji, results.len()),
            result_json: replay_out_path(result_json, ji, results.len()),
            ..Default::default()
        };
        write_outputs(&outputs, res)?;
    }
    Ok(())
}

/// Output path for replayed job `ji`: untouched when the stream holds
/// one job, `.N`-suffixed otherwise (`""` = output not requested).
fn replay_out_path(base: &str, ji: usize, jobs: usize) -> Option<String> {
    if base.is_empty() {
        None
    } else if jobs == 1 {
        Some(base.to_string())
    } else {
        Some(format!("{base}.{ji}"))
    }
}

fn cmd_shard(args: &[String]) -> Result<()> {
    let m = bfast::shard::shard_command().parse(args)?;
    let (req, workers, opts) = bfast::shard::shard_args_from_matches(&m)?;
    let handle = JobHandle::new();
    let run = bfast::shard::run_sharded(&req, &workers, &opts, &handle)?;
    let res = &run.result;
    println!(
        "sharded run: {} shards on {} workers, engine={} chunks={} wall={:.3}s",
        run.shards.len(),
        workers.len(),
        res.engine,
        res.chunks,
        res.wall.as_secs_f64()
    );
    println!(
        "{} pixels, {} breaks ({:.2}%)  [lambda={:.3}]",
        res.map.len(),
        res.map.break_count(),
        100.0 * res.map.break_fraction(),
        res.params.lambda
    );
    print!("{}", bfast::report::shard_table(&run.shards).to_console());
    if req.outputs.timings {
        if let Some(p) = &res.phases {
            print!("{}", p.table("merged phase breakdown"));
        }
    }
    write_outputs(&req.outputs, res)?;
    Ok(())
}

fn cmd_monitor(args: &[String]) -> Result<()> {
    let cmd = Command::new(
        "monitor",
        "incremental monitoring session: `--init archive.bsq` runs the one-time \
         history pass (N is taken from the archive), positional .bsq/.pgm files \
         are ingested layer by layer; state persists under --state",
    )
    .req("state", "session state directory")
    .opt("init", "", "initialise the session from this .bsq archive")
    .opt("init-layers", "0", "prime on only the first K layers of --init (0 = all)")
    .opt("n-hist", "100", "stable history length n (init)")
    .opt("h", "50", "MOSUM bandwidth (init)")
    .opt("k", "3", "harmonic terms (init)")
    .opt("freq", "23", "observations per period f (init)")
    .opt("alpha", "0.05", "significance level (init)")
    .opt("m-chunk", "1024", "pixels per chunk of the staged passes (init)")
    .opt("threads", "0", "worker threads, 0 = auto")
    .opt("t", "", "acquisition time of the first ingested .pgm layer")
    .opt("dt", "16", "time step between successive .pgm layers")
    .opt("momax-pgm", "", "write the running max|MOSUM| heatmap here")
    .opt("roc-quantile", "1.0", "quantile of per-pixel ROC starts (with --roc)")
    .switch("roc", "trim the unstable history with a reverse-ordered CUSUM scan (init)")
    .switch("no-fill", "disable forward/backward gap filling (init)")
    .switch("status", "print session status and exit");
    let m = cmd.parse(args)?;
    let state_dir = m.str("state")?.to_string();
    let threads = match m.usize("threads")? {
        0 => bfast::threadpool::default_threads(),
        n => n,
    };

    let mut session = if m.str("init")?.is_empty() {
        // resuming: every init-only flag would be silently ignored —
        // reject non-default values instead of dropping them
        for (flag, default) in [
            ("init-layers", "0"),
            ("n-hist", "100"),
            ("h", "50"),
            ("k", "3"),
            ("freq", "23"),
            ("alpha", "0.05"),
            ("m-chunk", "1024"),
            ("roc-quantile", "1.0"),
        ] {
            ensure!(
                m.str(flag)? == default,
                "--{flag} only applies with --init; the resumed session keeps its saved \
                 configuration"
            );
        }
        ensure!(
            !m.flag("roc") && !m.flag("no-fill"),
            "--roc/--no-fill only apply with --init; the resumed session keeps its saved \
             configuration"
        );
        let s = MonitorSession::load(&state_dir, threads)?;
        println!(
            "resumed session from {state_dir}: {} px, {} layers (n={}, h={}, k={}), \
             {} breaks so far",
            s.n_pixels(),
            s.n_seen(),
            s.params().n_hist,
            s.params().h,
            s.params().k,
            s.break_count()
        );
        s
    } else {
        ensure!(
            !std::path::Path::new(&state_dir).join("session.json").exists(),
            "{state_dir} already holds a session; --init would destroy its accumulated \
             state — remove the directory or choose another --state to start over"
        );
        let mut stack = rio::read_stack(m.str("init")?)?;
        let keep = m.usize("init-layers")?;
        if keep > 0 {
            stack = stack.prefix(keep)?;
        }
        let mut params = BfastParams::new(
            stack.n_times(),
            m.usize("n-hist")?,
            m.usize("h")?,
            m.usize("k")?,
            m.f64("freq")?,
            m.f64("alpha")?,
        )?;
        if m.flag("roc") {
            let sel = monitor::roc_select(&stack, &params, m.f64("roc-quantile")?, threads)?;
            println!(
                "ROC scan: stable history starts at layer {} (quantile {} of {} pixels)",
                sel.chosen,
                m.str("roc-quantile")?,
                sel.starts.len()
            );
            let (trimmed, adjusted) = monitor::apply_roc(&stack, &params, sel.chosen)?;
            stack = trimmed;
            params = adjusted;
        }
        // through the front door: the primed session is described by
        // the same SessionInit the serve API accepts
        let init = api::SessionInit {
            source: api::SceneSource::Inline(stack),
            params: api::ParamSpec::from_params(&params),
            init_layers: 0, // prefix/ROC trims already applied above
        };
        let t0 = Instant::now();
        let s = init.start_local(m.usize("m-chunk")?, threads, !m.flag("no-fill"))?;
        println!(
            "primed session: {} px, {} layers (n={}, h={}, k={}, lambda={:.3}) in {:.3}s; \
             {} breaks in the initial archive",
            s.n_pixels(),
            s.n_seen(),
            params.n_hist,
            params.h,
            params.k,
            s.params().lambda,
            t0.elapsed().as_secs_f64(),
            s.break_count()
        );
        s
    };

    if m.flag("status") {
        ensure!(
            m.positional.is_empty(),
            "--status does not ingest: drop it to absorb {:?}",
            m.positional
        );
        session.save(&state_dir)?; // persists a freshly-primed session too
        println!(
            "state {state_dir}: {} px, {} layers, last t={:.3}, {} breaks ({:.2}%)",
            session.n_pixels(),
            session.n_seen(),
            session.time_axis().last().copied().unwrap_or(f64::NAN),
            session.break_count(),
            100.0 * session.break_count() as f64 / session.n_pixels().max(1) as f64
        );
        return Ok(());
    }

    // ingest positional layer files (.bsq archives or single .pgm layers)
    let mut deltas = Vec::new();
    let mut next_pgm_t = match m.str("t")? {
        "" => None,
        s => Some(s.parse::<f64>().map_err(|_| bfast::err!("--t: expected number, got {s:?}"))?),
    };
    let pgm_dt = m.f64("dt")?;
    for file in &m.positional {
        if file.ends_with(".pgm") {
            let t = next_pgm_t.ok_or_else(|| {
                bfast::err!("--t is required to ingest .pgm layers (they carry no time axis)")
            })?;
            let (w, h, values) = pgm::read_pgm(file)?;
            ensure!(
                w * h == session.n_pixels(),
                "{file}: {w}x{h} layer does not match the session's {} pixels",
                session.n_pixels()
            );
            let d = session.ingest(t, &values)?;
            next_pgm_t = Some(t + pgm_dt);
            deltas.push(d);
        } else {
            let stack = rio::read_stack(file)?;
            let skipped = stack.n_times();
            let new = session.ingest_stack(&stack)?;
            let skipped = skipped - new.len();
            if skipped > 0 {
                println!("{file}: skipped {skipped} already-seen layers");
            }
            deltas.extend(new);
        }
    }
    for d in &deltas {
        let head: Vec<String> =
            d.new_breaks.iter().take(8).map(|px| px_label(*px, &session)).collect();
        println!(
            "layer {} (t={:.3}): +{} new breaks, {} total{}{}",
            d.layer,
            d.t,
            d.new_breaks.len(),
            d.total_breaks,
            if head.is_empty() { "" } else { " — " },
            head.join(", ")
        );
    }
    if !deltas.is_empty() {
        print!(
            "{}",
            bfast::report::monitor_delta_table(&deltas, session.n_pixels()).to_console()
        );
    }

    let pgm_path = m.str("momax-pgm")?;
    if !pgm_path.is_empty() {
        let map = session.break_map();
        let (w, h) = match session.geometry() {
            (Some(w), Some(h)) => (w, h),
            _ => (map.momax.len(), 1),
        };
        let (lo, hi) = pgm::write_pgm_autoscale(pgm_path, &map.momax, w, h)?;
        println!("wrote {pgm_path} (scale {lo:.2}..{hi:.2})");
    }

    session.save(&state_dir)?;
    println!(
        "saved session to {state_dir}: {} layers, {} breaks",
        session.n_seen(),
        session.break_count()
    );
    Ok(())
}

/// Pixel label for delta reporting: `(x, y)` when the scene has
/// geometry, the flat index otherwise.
fn px_label(px: usize, session: &MonitorSession) -> String {
    match session.geometry() {
        (Some(w), Some(_)) if w > 0 => format!("({}, {})", px % w, px / w),
        _ => px.to_string(),
    }
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let cmd = Command::new(
        "serve",
        "run the break-detection service: an HTTP API over a bounded job \
         scheduler and live monitor sessions (see the README's Serving section)",
    )
    .opt("addr", "127.0.0.1:7878", "listen address (host:port; port 0 = ephemeral)")
    .opt("state", "", "state directory: sessions persist and resume from here")
    .opt("http-threads", "0", "HTTP worker threads (0 = auto)")
    .opt("job-workers", "1", "scheduler workers driving analysis runs")
    .opt("queue", "32", "job queue capacity (further submissions get 429)")
    .opt("max-body-mb", "256", "largest accepted request body (MiB)")
    .opt("finished-cap", "256", "finished job records kept for status/map queries")
    .opt("cache-cap-mb", "64", "result cache capacity (MiB; 0 disables caching)")
    .opt("finished-max-age-s", "3600", "seconds a finished job record is retained (0 = no age limit)")
    .opt("gateway", "", "gateway address to register with and heartbeat (host:port)")
    .opt("advertise", "", "address advertised to the gateway (default: the bound address)")
    .opt("heartbeat-ms", "1000", "heartbeat interval when --gateway is set (ms)")
    .opt("log-level", "info", "log verbosity: error|warn|info|debug|trace")
    .opt("log-format", "json", "log line format: json|text")
    .opt("trace", "on", "flight recorder (span capture): on|off");
    let m = cmd.parse(args)?;
    apply_observability_flags(&m)?;
    let cfg = ServeConfig {
        addr: m.str("addr")?.to_string(),
        state_dir: match m.str("state")? {
            "" => None,
            s => Some(s.into()),
        },
        http_threads: m.usize("http-threads")?,
        job_workers: m.usize("job-workers")?,
        queue_capacity: m.usize("queue")?,
        max_body: m.usize("max-body-mb")? << 20,
        finished_cap: m.usize("finished-cap")?,
        finished_max_age: Duration::from_secs(m.u64("finished-max-age-s")?),
        cache_cap: m.usize("cache-cap-mb")? << 20,
        runner: RunnerConfig::default(),
        gateway: match m.str("gateway")? {
            "" => None,
            s => Some(s.to_string()),
        },
        advertise: match m.str("advertise")? {
            "" => None,
            s => Some(s.to_string()),
        },
        heartbeat: Duration::from_millis(m.u64("heartbeat-ms")?),
    };
    let state_desc = cfg
        .state_dir
        .as_ref()
        .map(|p| p.display().to_string())
        .unwrap_or_else(|| "(in-memory)".into());
    let server = Server::start(cfg)?;
    println!(
        "bfast serve: listening on http://{} (queue {}, state {state_desc}); \
         POST /shutdown stops it",
        server.addr(),
        m.usize("queue")?
    );
    server.wait()
}

/// Apply the shared `--log-level` / `--log-format` / `--trace` flags
/// (serve and gateway) to the process-wide observability switches.
fn apply_observability_flags(m: &bfast::cli::Matches) -> Result<()> {
    bfast::trace::set_log_level(bfast::trace::Level::parse(m.str("log-level")?)?);
    bfast::trace::set_log_format(m.str("log-format")?)?;
    match m.str("trace")? {
        "on" => bfast::trace::set_enabled(true),
        "off" => bfast::trace::set_enabled(false),
        other => bail!("--trace: expected on|off, got {other:?}"),
    }
    Ok(())
}

fn cmd_gateway(args: &[String]) -> Result<()> {
    let m = bfast::gateway::gateway_command().parse(args)?;
    apply_observability_flags(&m)?;
    let cfg = bfast::gateway::gateway_config_from_matches(&m)?;
    let statics = cfg.workers.len();
    let gw = bfast::gateway::Gateway::start(cfg)?;
    println!(
        "bfast gateway: listening on http://{} ({statics} static worker(s) seeded; \
         workers join via POST /v1/workers); POST /shutdown stops it",
        gw.addr()
    );
    gw.wait()
}

fn client_param_spec(m: &bfast::cli::Matches) -> Result<api::ParamSpec> {
    Ok(api::ParamSpec {
        n_total: None,
        n_hist: m.usize("n-hist")?,
        h: m.usize("h")?,
        k: m.usize("k")?,
        freq: m.f64("freq")?,
        alpha: m.f64("alpha")?,
        lambda: None,
    })
}

/// Fail on non-2xx, surfacing the message from the server's uniform
/// `{"error": {...}}` envelope.
fn expect_ok(resp: (u16, Vec<u8>)) -> Result<Vec<u8>> {
    let (status, body) = resp;
    ensure!(
        (200..300).contains(&status),
        "HTTP {status}: {}",
        shttp::error_message(&body)
    );
    Ok(body)
}

fn client_print_or_write(body: &[u8], out: &str) -> Result<()> {
    if out.is_empty() {
        print!("{}", String::from_utf8_lossy(body));
    } else {
        std::fs::write(out, body)?;
        println!("wrote {out} ({} bytes)", body.len());
    }
    Ok(())
}

fn client_wait_for_job(addr: &str, job: usize) -> Result<()> {
    loop {
        let body = expect_ok(shttp::roundtrip(addr, "GET", &format!("/v1/runs/{job}"), "", &[])?)?;
        let v = json::parse(std::str::from_utf8(&body)?.trim())?;
        match v.get("status")?.as_str()? {
            "done" => {
                println!(
                    "job {job} done: {} of {} pixels broke in {:.3}s",
                    v.get("breaks")?.as_usize()?,
                    v.get("pixels")?.as_usize()?,
                    v.get("wall_s")?.as_f64()?
                );
                return Ok(());
            }
            "failed" => bail!("job {job} failed: {}", v.get("error")?.as_str()?),
            _ => std::thread::sleep(std::time::Duration::from_millis(100)),
        }
    }
}

/// Largest compressed result envelope `client result` will inflate
/// (same role as the server's `--max-body-mb` bound, client-side).
const RESULT_DECODE_CAP: usize = 1 << 30;

fn cmd_client(args: &[String]) -> Result<()> {
    let cmd = Command::new(
        "client",
        "HTTP client for a running `bfast serve` or `bfast gateway`. Positional \
         action: health | metrics | jobs | workers | submit | status | cancel | \
         map | result | trace | session-init | session | ingest | session-map | shutdown",
    )
    .opt("addr", "127.0.0.1:7878", "server address (host:port)")
    .opt("input", "", "input file (.bsq scene; .bten/.pgm layer for ingest)")
    .opt("job", "0", "job id (status / cancel / map / result / trace)")
    .opt("name", "", "session name")
    .opt("t", "", "acquisition time of the ingested layer")
    .opt("out", "", "write the response payload here instead of stdout")
    .opt("n-hist", "100", "stable history length n (submit / session-init)")
    .opt("h", "50", "MOSUM bandwidth (submit / session-init)")
    .opt("k", "3", "harmonic terms (submit / session-init)")
    .opt("freq", "23", "observations per period f (submit / session-init)")
    .opt("alpha", "0.05", "significance level (submit / session-init)")
    .opt("init-layers", "0", "prime on only the first K layers (session-init)")
    .opt("etag", "", "previously-seen ETag; sent as If-None-Match (result)")
    .switch("compress", "gzip the request body over the wire (submit)")
    .switch("wait", "poll until the submitted job finishes (submit)")
    .switch("pgm", "fetch the break map as a PGM heatmap (map / session-map)");
    let m = cmd.parse(args)?;
    let action = m.positional.first().map(|s| s.as_str()).unwrap_or("health");
    let addr = m.str("addr")?;
    let name = m.str("name")?;
    let need_name = || -> Result<&str> {
        ensure!(!name.is_empty(), "--name is required for {action}");
        Ok(name)
    };
    let need_input = || -> Result<Vec<u8>> {
        let input = m.str("input")?;
        ensure!(!input.is_empty(), "--input is required for {action}");
        Ok(std::fs::read(input)?)
    };
    let fmt_suffix = if m.flag("pgm") { "?format=pgm" } else { "" };
    match action {
        "health" => {
            let body = expect_ok(shttp::roundtrip(addr, "GET", "/healthz", "", &[])?)?;
            print!("{}", String::from_utf8_lossy(&body));
        }
        "metrics" => {
            let body = expect_ok(shttp::roundtrip(addr, "GET", "/metrics", "", &[])?)?;
            print!("{}", String::from_utf8_lossy(&body));
        }
        "jobs" => {
            let body = expect_ok(shttp::roundtrip(addr, "GET", "/v1/runs", "", &[])?)?;
            let v = json::parse(std::str::from_utf8(&body)?.trim())?;
            let rows: Vec<(u64, String, f64)> = v
                .get("jobs")?
                .as_arr()?
                .iter()
                .map(|j| {
                    Ok((
                        j.get("job")?.as_usize()? as u64,
                        j.get("status")?.as_str()?.to_string(),
                        j.get("progress")?.as_f64()?,
                    ))
                })
                .collect::<Result<_>>()?;
            print!("{}", bfast::report::jobs_table(&rows).to_console());
        }
        "workers" => {
            // gateway-only: the fleet view behind GET /v1/workers
            let body = expect_ok(shttp::roundtrip(addr, "GET", "/v1/workers", "", &[])?)?;
            let v = json::parse(std::str::from_utf8(&body)?.trim())?;
            let rows: Vec<bfast::gateway::WorkerInfo> = v
                .get("workers")?
                .as_arr()?
                .iter()
                .map(|w| {
                    Ok(bfast::gateway::WorkerInfo {
                        addr: w.get("addr")?.as_str()?.to_string(),
                        alive: w.get("alive")?.as_bool()?,
                        down: w.get("down")?.as_bool()?,
                        is_static: w.get("static")?.as_bool()?,
                        weight: w.get("weight")?.as_f64()?,
                        rate: w.get("rate_chunks_per_s")?.as_f64()?,
                        beats: w.get("beats")?.as_usize()? as u64,
                        last_beat: Duration::from_secs_f64(w.get("last_beat_s")?.as_f64()?),
                    })
                })
                .collect::<Result<_>>()?;
            print!("{}", bfast::report::workers_table(&rows).to_console());
        }
        "submit" => {
            // post exactly what the library executes: the canonical
            // AnalysisRequest JSON (scene inline). A 429 from a full
            // queue is retried with bounded exponential backoff,
            // honouring the server's Retry-After hint.
            let bytes = need_input()?;
            let stack = rio::stack_from_bytes(&bytes, m.str("input")?)?;
            let mut analysis = api::AnalysisRequest::new(api::SceneSource::Inline(stack));
            analysis.params = client_param_spec(&m)?;
            let payload = analysis.to_json_string().into_bytes();
            let (wire, extra): (Vec<u8>, &[(&str, &str)]) = if m.flag("compress") {
                (store::gzip_compress(&payload), &[("Content-Encoding", "gzip")])
            } else {
                (payload, &[])
            };
            let body = expect_ok(shttp::roundtrip_retry_with(
                addr,
                "POST",
                "/v1/runs",
                "application/json",
                extra,
                &wire,
                8,
            )?)?;
            let v = json::parse(std::str::from_utf8(&body)?.trim())?;
            let job = v.get("job")?.as_usize()?;
            let cached = v.get("cached").and_then(|c| c.as_bool()).unwrap_or(false);
            println!("submitted job {job}{}", if cached { " (cache hit)" } else { "" });
            if m.flag("wait") {
                client_wait_for_job(addr, job)?;
            }
        }
        "status" => {
            let job = m.usize("job")?;
            let body =
                expect_ok(shttp::roundtrip(addr, "GET", &format!("/v1/runs/{job}"), "", &[])?)?;
            print!("{}", String::from_utf8_lossy(&body));
        }
        "cancel" => {
            let job = m.usize("job")?;
            let body = expect_ok(shttp::roundtrip(
                addr,
                "DELETE",
                &format!("/v1/runs/{job}"),
                "",
                &[],
            )?)?;
            print!("{}", String::from_utf8_lossy(&body));
        }
        "map" => {
            let job = m.usize("job")?;
            let path = format!("/v1/runs/{job}/map{fmt_suffix}");
            let body = expect_ok(shttp::roundtrip(addr, "GET", &path, "", &[])?)?;
            client_print_or_write(&body, m.str("out")?)?;
        }
        "result" => {
            // the canonical v1 AnalysisResult envelope — lossless,
            // replayable, and what the shard coordinator merges. The
            // envelope's ETag is echoed on stderr; pass it back via
            // --etag to turn an unchanged re-fetch into a bodyless 304.
            let job = m.usize("job")?;
            let path = format!("/v1/runs/{job}/result");
            let etag = m.str("etag")?;
            let mut extra: Vec<(&str, &str)> = vec![("Accept-Encoding", "gzip")];
            if !etag.is_empty() {
                extra.push(("If-None-Match", etag));
            }
            let mut client = shttp::Client::connect(addr)?;
            let (status, headers, body) =
                client.request_with_headers("GET", &path, "", &extra, &[])?;
            if status == 304 {
                println!("job {job} result unchanged (matches {etag})");
                return Ok(());
            }
            let body = expect_ok((status, body))?;
            let gzipped = headers
                .iter()
                .any(|(k, v)| k == "content-encoding" && v.eq_ignore_ascii_case("gzip"));
            let body =
                if gzipped { store::gzip_decompress(&body, RESULT_DECODE_CAP)? } else { body };
            if let Some((_, tag)) = headers.iter().find(|(k, _)| k == "etag") {
                eprintln!("etag: {tag}");
            }
            client_print_or_write(&body, m.str("out")?)?;
        }
        "trace" => {
            // Chrome trace-event JSON for one run — load the file into
            // chrome://tracing or https://ui.perfetto.dev. Against a
            // gateway this is the merged fleet trace (gateway + every
            // worker that held a shard, one process lane each).
            let job = m.usize("job")?;
            let path = format!("/v1/runs/{job}/trace");
            let body = expect_ok(shttp::roundtrip(addr, "GET", &path, "", &[])?)?;
            client_print_or_write(&body, m.str("out")?)?;
        }
        "session-init" => {
            let name = need_name()?;
            let bytes = need_input()?;
            let init = api::SessionInit {
                source: api::SceneSource::Inline(rio::stack_from_bytes(
                    &bytes,
                    m.str("input")?,
                )?),
                params: client_param_spec(&m)?,
                init_layers: m.usize("init-layers")?,
            };
            let body = expect_ok(shttp::roundtrip(
                addr,
                "POST",
                &format!("/v1/sessions/{name}"),
                "application/json",
                init.to_json().to_string_compact().as_bytes(),
            )?)?;
            print!("{}", String::from_utf8_lossy(&body));
        }
        "session" => {
            let name = need_name()?;
            let body = expect_ok(shttp::roundtrip(
                addr,
                "GET",
                &format!("/v1/sessions/{name}"),
                "",
                &[],
            )?)?;
            print!("{}", String::from_utf8_lossy(&body));
        }
        "ingest" => {
            let name = need_name()?;
            let t: f64 = m
                .str("t")?
                .parse()
                .map_err(|_| bfast::err!("--t must be the layer's acquisition time"))?;
            let input = m.str("input")?;
            ensure!(!input.is_empty(), "--input is required for ingest");
            let bytes = if input.ends_with(".pgm") {
                let (_, _, values) = pgm::read_pgm(input)?;
                bten_to_bytes(&Tensor::F32 { shape: vec![values.len()], data: values })?
            } else {
                std::fs::read(input)?
            };
            let path = format!("/v1/sessions/{name}/ingest?t={t}");
            let body = expect_ok(shttp::roundtrip(
                addr,
                "POST",
                &path,
                "application/octet-stream",
                &bytes,
            )?)?;
            print!("{}", String::from_utf8_lossy(&body));
        }
        "session-map" => {
            let name = need_name()?;
            let path = format!("/v1/sessions/{name}/map{fmt_suffix}");
            let body = expect_ok(shttp::roundtrip(addr, "GET", &path, "", &[])?)?;
            client_print_or_write(&body, m.str("out")?)?;
        }
        "shutdown" => {
            let body = expect_ok(shttp::roundtrip(addr, "POST", "/shutdown", "", &[])?)?;
            print!("{}", String::from_utf8_lossy(&body));
        }
        other => bail!("unknown client action {other:?}\n\n{}", cmd.usage()),
    }
    Ok(())
}

fn cmd_cache(args: &[String]) -> Result<()> {
    let cmd = Command::new(
        "cache",
        "inspect or clear the result cache of a running serve/gateway.\n\nACTIONS:\n  \
         stats   show hit/miss/eviction counters and held bytes (default action)\n  \
         clear   drop every cached result",
    )
    .opt("addr", "127.0.0.1:7878", "server address (host:port)");
    let m = cmd.parse(args)?;
    let addr = m.str("addr")?;
    let action = m.positional.first().map(|s| s.as_str()).unwrap_or("stats");
    match action {
        "stats" => {
            let body = expect_ok(shttp::roundtrip(addr, "GET", "/v1/cache", "", &[])?)?;
            print!("{}", String::from_utf8_lossy(&body));
        }
        "clear" => {
            let body = expect_ok(shttp::roundtrip(addr, "DELETE", "/v1/cache", "", &[])?)?;
            let v = json::parse(std::str::from_utf8(&body)?.trim())?;
            println!("cleared {} cached result(s)", v.get("cleared")?.as_usize()?);
        }
        other => bail!("unknown cache action {other:?}\n\n{}", cmd.usage()),
    }
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let cmd = param_flags(
        Command::new("inspect", "per-pixel detail")
            .req("input", "input .bsq stack")
            .req("pixel", "pixel index"),
    );
    let m = cmd.parse(args)?;
    let stack = rio::read_stack(m.str("input")?)?;
    let params = params_from(&m)?;
    let px = m.usize("pixel")?;
    // inspection is a pure-CPU path; any backend works
    let runner = BfastRunner::emulated(RunnerConfig::default())?;
    let res = runner.inspect_pixel(&stack, &params, px)?;
    println!(
        "pixel {px}: break={} first={} momax={:.3}",
        res.scan.has_break, res.scan.first, res.scan.momax
    );
    let bound = bfast::mosum::boundary(&params);
    println!("  t        MO_t     bound");
    for (i, (mo, b)) in res.mosum.iter().zip(&bound).enumerate() {
        let t = params.n_hist + 1 + i;
        let mark = if mo.abs() > *b { "  <-- break" } else { "" };
        println!("  {t:<6} {mo:>8.3}  {b:>8.3}{mark}");
    }
    Ok(())
}

fn cmd_lambda(args: &[String]) -> Result<()> {
    let cmd = Command::new("lambda-table", "simulated critical values")
        .opt("horizon", "2", "monitoring horizon N/n")
        .opt("alphas", "0.01,0.05,0.1", "comma-separated alphas (percent as fractions)")
        .opt("h-fracs", "0.25,0.5,1.0", "comma-separated h/n values");
    let m = cmd.parse(args)?;
    let alphas: Vec<f64> = m
        .str("alphas")?
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| bfast::err!("bad alpha {s:?}")))
        .collect::<Result<_>>()?;
    let hfracs: Vec<f64> = m
        .str("h-fracs")?
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| bfast::err!("bad h/n {s:?}")))
        .collect::<Result<_>>()?;
    print!("{}", bfast::lambda::table(m.f64("horizon")?, &alphas, &hfracs)?);
    Ok(())
}

fn cmd_bench(args: &[String]) -> Result<()> {
    let cmd = Command::new(
        "bench",
        "perf trajectory harness\n\nACTIONS:\n  run                 measure the pinned scenarios (default action)\n  diff BASE.json NEW.json\n                      compare two reports (NEW relative to BASE)\n  check FILE.json...  validate schema + canonical round-trip\n  tune                measure m_chunk candidates on the emulated engine",
    )
    .opt("out", "", "run: write the report JSON here")
    .opt("scale", "0", "run: workload scale; 0 = BFAST_BENCH_SCALE (default 1.0)")
    .opt("trials", "5", "run/tune: measured trials per engine")
    .opt("warmup", "1", "run: unmeasured warmup runs per engine")
    .opt("scenarios", "", "run: comma-separated scenario filter (e.g. fig2)")
    .opt("engines", "", "run: comma-separated engine filter (e.g. fused-cpu,emulated)")
    .opt(
        "fail-threshold",
        "0",
        "diff: fail when a pair is more than this fraction slower (0 = report only)",
    )
    .opt("m", "4096", "tune: pixel count for tuning runs")
    .opt("candidates", "", "tune: comma-separated m_chunk candidates (default built-in set)");
    let m = cmd.parse(args)?;
    let action = m.positional.first().map(|s| s.as_str()).unwrap_or("run");
    let csv = |s: &str| -> Vec<String> {
        s.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect()
    };
    match action {
        "run" => {
            let mut cfg = bench::BenchConfig::default();
            let scale = m.f64("scale")?;
            if scale > 0.0 {
                cfg.scale = scale;
            }
            cfg.trials = m.usize("trials")?.max(1);
            cfg.warmup = m.usize("warmup")?;
            cfg.scenarios = csv(m.str("scenarios")?);
            cfg.engines = csv(m.str("engines")?);
            let report = bench::run_all(&cfg)?;
            print!("{}", report.table());
            let out = m.str("out")?;
            if !out.is_empty() {
                report.save(out)?;
                println!("wrote {out}");
            }
        }
        "diff" => {
            ensure!(
                m.positional.len() == 3,
                "usage: bfast bench diff BASE.json NEW.json\n\n{}",
                cmd.usage()
            );
            let base = bench::BenchReport::load(&m.positional[1])?;
            let new = bench::BenchReport::load(&m.positional[2])?;
            if base.fingerprint.source != new.fingerprint.source {
                println!(
                    "note: comparing across sources ({} vs {})",
                    base.fingerprint.source, new.fingerprint.source
                );
            }
            let d = bench::diff(&base, &new);
            print!("{}", d.table());
            let thr = m.f64("fail-threshold")?;
            if thr > 0.0 {
                let regs = d.regressions(thr);
                ensure!(
                    regs.is_empty(),
                    "{} regression(s) beyond {:.1}%: {}",
                    regs.len(),
                    thr * 100.0,
                    regs.iter()
                        .map(|r| format!("{}/{} {:.2}x", r.scenario, r.engine, r.speedup))
                        .collect::<Vec<_>>()
                        .join(", ")
                );
            }
        }
        "check" => {
            ensure!(
                m.positional.len() >= 2,
                "usage: bfast bench check FILE.json...\n\n{}",
                cmd.usage()
            );
            for f in &m.positional[1..] {
                let report = bench::BenchReport::load(f)?;
                let canon = report.to_json_string();
                let back = bench::BenchReport::from_json_str(&canon)
                    .map_err(|e| bfast::err!("{f}: canonical form does not re-parse: {e}"))?;
                ensure!(
                    back.to_json_string() == canon,
                    "{f}: to_json -> from_json is not a fixed point"
                );
                println!(
                    "{f}: ok (schema v{}, {} scenario(s), source {})",
                    report.version,
                    report.scenarios.len(),
                    report.fingerprint.source
                );
            }
        }
        "tune" => {
            let raw = m.str("candidates")?;
            let cands: Vec<usize> = if raw.trim().is_empty() {
                bench::TUNE_CANDIDATES.to_vec()
            } else {
                m.usize_list("candidates")?
            };
            let params = BfastParams::paper_synthetic();
            let pixels = m.usize("m")?;
            let trials = m.usize("trials")?.max(1);
            println!(
                "tuning m_chunk over {cands:?} (m={pixels}, {trials} trial(s), seed {})",
                bench::TUNE_SEED
            );
            let (best, rows) = bench::tune_m_chunk(&params, pixels, &cands, trials)?;
            for (mc, ns) in &rows {
                let mark = if *mc == best { "  <-- best" } else { "" };
                println!("  m_chunk {mc:>6}: median {:>12} ns{mark}", ns);
            }
            println!("best m_chunk for this host: {best}");
        }
        other => bail!("unknown bench action {other:?}\n\n{}", cmd.usage()),
    }
    Ok(())
}
