//! `bfast` — the leader binary: generate data, run break detection
//! through any of the four implementations, inspect pixels, and print
//! critical-value tables.

use bfast::cli::Command;
use bfast::error::{bail, ensure, Result};
use bfast::coordinator::{BfastRunner, RunnerConfig};
use bfast::cpu::FusedCpuBfast;
use bfast::monitor::{self, MonitorConfig, MonitorSession};
use bfast::params::BfastParams;
use bfast::pixel::{DirectBfast, NaiveBfast};
use bfast::raster::{io as rio, pgm};
use bfast::synth::{ArtificialDataset, ChileScene};
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

const TOPLEVEL: &str = "\
bfast — massively-parallel break detection for satellite data

USAGE: bfast <command> [flags]   (bfast <command> --help for details)

COMMANDS:
  info          show executor backend + artifact manifest
  generate      write a synthetic .bsq stack (artificial or chile)
  run           analyse a .bsq stack (engine: device|emulated|cpu|direct|naive)
  monitor       incremental session: one-time history pass, then ingest
                new layers (.bsq/.pgm) with no refit (--state dir/)
  inspect       per-pixel MOSUM/fit details for one pixel
  lambda-table  print simulated critical values λ(α, h/n)
";

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print!("{TOPLEVEL}");
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "info" => cmd_info(rest),
        "generate" => cmd_generate(rest),
        "run" => cmd_run(rest),
        "monitor" => cmd_monitor(rest),
        "inspect" => cmd_inspect(rest),
        "lambda-table" => cmd_lambda(rest),
        "--help" | "-h" | "help" => {
            print!("{TOPLEVEL}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{TOPLEVEL}"),
    }
}

fn params_from(m: &bfast::cli::Matches) -> Result<BfastParams> {
    let n_total = m.usize("n-total")?;
    let n_hist = m.usize("n-hist")?;
    BfastParams::new(
        n_total,
        n_hist,
        m.usize("h")?,
        m.usize("k")?,
        m.f64("freq")?,
        m.f64("alpha")?,
    )
}

fn param_flags(c: Command) -> Command {
    c.opt("n-total", "200", "series length N")
        .opt("n-hist", "100", "stable history length n")
        .opt("h", "50", "MOSUM bandwidth")
        .opt("k", "3", "harmonic terms")
        .opt("freq", "23", "observations per period f")
        .opt("alpha", "0.05", "significance level")
}

fn cmd_info(args: &[String]) -> Result<()> {
    let cmd = Command::new("info", "show backend + artifacts")
        .opt("artifacts", "artifacts", "artifact directory");
    let m = cmd.parse(args)?;
    let runner = BfastRunner::auto(m.str("artifacts")?, RunnerConfig::default())?;
    println!("backend: {}", runner.platform());
    println!(
        "features: pjrt={}  (default backend: {})",
        cfg!(feature = "pjrt"),
        if cfg!(feature = "pjrt") { "device when artifacts exist" } else { "emulated" }
    );
    let dir = std::path::Path::new(m.str("artifacts")?);
    if dir.join("manifest.json").exists() {
        let man = bfast::runtime::Manifest::load(dir)?;
        println!("artifacts ({}):", man.artifacts.len());
        for a in &man.artifacts {
            println!(
                "  {:<14} {:<8} N={:<4} n={:<4} h={:<4} k={} m_chunk={:<6} pallas={}",
                a.name, a.phase, a.n_total, a.n_hist, a.h, a.k, a.m_chunk, a.use_pallas
            );
        }
    } else {
        println!(
            "no artifact manifest at {} — analyses run on the emulated backend",
            dir.display()
        );
    }
    Ok(())
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let cmd = param_flags(
        Command::new("generate", "write a synthetic stack")
            .req("out", "output .bsq path")
            .opt("kind", "artificial", "artificial | chile")
            .opt("m", "10000", "pixels (artificial)")
            .opt("width", "240", "scene width (chile)")
            .opt("height", "186", "scene height (chile)")
            .opt("seed", "42", "generator seed")
            .opt("cloud-rate", "0", "missing-value probability (chile)"),
    );
    let m = cmd.parse(args)?;
    let out = m.str("out")?;
    match m.str("kind")? {
        "artificial" => {
            let params = params_from(&m)?;
            let data = ArtificialDataset::new(params, m.usize("m")?, m.u64("seed")?).generate();
            rio::write_stack(out, &data.stack)?;
            println!(
                "wrote {out}: {} x {} (artificial, {} with injected breaks)",
                data.stack.n_times(),
                data.stack.n_pixels(),
                data.truth.iter().filter(|&&t| t).count()
            );
        }
        "chile" => {
            let scene = ChileScene {
                width: m.usize("width")?,
                height: m.usize("height")?,
                seed: m.u64("seed")?,
                cloud_rate: m.f64("cloud-rate")?,
                ..ChileScene::default()
            };
            let (stack, truth) = scene.generate();
            rio::write_stack(out, &stack)?;
            println!(
                "wrote {out}: {} x {} ({}x{} chile scene, {} forest px)",
                stack.n_times(),
                stack.n_pixels(),
                scene.width,
                scene.height,
                truth.is_forest.iter().filter(|&&f| f).count()
            );
        }
        other => bail!("unknown kind {other:?} (artificial|chile)"),
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let cmd = param_flags(
        Command::new("run", "analyse a stack")
            .req("input", "input .bsq stack")
            .opt("engine", "device", "device | emulated | cpu | direct | naive")
            .opt("artifacts", "artifacts", "artifact directory (device)")
            .opt("artifact", "", "artifact config name override (device)")
            .opt("queue-depth", "2", "staging queue depth (device)")
            .opt("staging-threads", "0", "staging threads, 0 = auto (device)")
            .opt("momax-pgm", "", "write max|MOSUM| heatmap PGM here")
            .switch("phased", "run the per-phase executables (instrumented)")
            .switch("timings", "print the phase breakdown"),
    );
    let m = cmd.parse(args)?;
    let stack = rio::read_stack(m.str("input")?)?;
    let params = params_from(&m)?;
    let t0 = Instant::now();
    let (map, phases) = match m.str("engine")? {
        engine @ ("device" | "emulated") => {
            let mut cfg = RunnerConfig {
                phased: m.flag("phased"),
                queue_depth: m.usize("queue-depth")?,
                ..Default::default()
            };
            if m.usize("staging-threads")? > 0 {
                cfg.staging_threads = m.usize("staging-threads")?;
            }
            let name = m.str("artifact")?;
            if !name.is_empty() {
                cfg.artifact = Some(name.to_string());
            }
            let mut runner = if engine == "emulated" {
                BfastRunner::emulated(cfg)?
            } else {
                BfastRunner::auto(m.str("artifacts")?, cfg)?
            };
            if engine == "device" && runner.platform().starts_with("emulated") {
                eprintln!(
                    "bfast: no device backend available (no artifacts at {:?}); \
                     running on the emulated backend — use --engine emulated to \
                     select it explicitly",
                    m.str("artifacts")?
                );
            }
            let res = runner.run(&stack, &params)?;
            println!(
                "{} run: backend={} artifact={} chunks={} wall={:.3}s",
                engine,
                runner.platform(),
                res.artifact,
                res.chunks,
                res.wall.as_secs_f64()
            );
            (res.map, Some(res.phases))
        }
        "cpu" => {
            let eng = FusedCpuBfast::new(params.clone(), &stack.time_axis)?;
            let (map, times) = eng.run(&stack)?;
            (map, Some(times))
        }
        "direct" => (DirectBfast::new(params.clone(), &stack.time_axis)?.run(&stack)?, None),
        "naive" => (NaiveBfast::new(params.clone()).run(&stack)?, None),
        other => bail!("unknown engine {other:?}"),
    };
    let wall = t0.elapsed();
    println!(
        "{} pixels, {} breaks ({:.2}%) in {:.3}s  [lambda={:.3}]",
        map.len(),
        map.break_count(),
        100.0 * map.break_fraction(),
        wall.as_secs_f64(),
        params.lambda
    );
    if m.flag("timings") {
        if let Some(p) = &phases {
            print!("{}", p.table("phase breakdown"));
        }
    }
    let pgm_path = m.str("momax-pgm")?;
    if !pgm_path.is_empty() {
        let (w, h) = match (stack.width, stack.height) {
            (Some(w), Some(h)) => (w, h),
            _ => (map.len(), 1),
        };
        let (lo, hi) = pgm::write_pgm_autoscale(pgm_path, &map.momax, w, h)?;
        println!("wrote {pgm_path} (scale {lo:.2}..{hi:.2})");
    }
    Ok(())
}

fn cmd_monitor(args: &[String]) -> Result<()> {
    let cmd = Command::new(
        "monitor",
        "incremental monitoring session: `--init archive.bsq` runs the one-time \
         history pass (N is taken from the archive), positional .bsq/.pgm files \
         are ingested layer by layer; state persists under --state",
    )
    .req("state", "session state directory")
    .opt("init", "", "initialise the session from this .bsq archive")
    .opt("init-layers", "0", "prime on only the first K layers of --init (0 = all)")
    .opt("n-hist", "100", "stable history length n (init)")
    .opt("h", "50", "MOSUM bandwidth (init)")
    .opt("k", "3", "harmonic terms (init)")
    .opt("freq", "23", "observations per period f (init)")
    .opt("alpha", "0.05", "significance level (init)")
    .opt("m-chunk", "1024", "pixels per chunk of the staged passes (init)")
    .opt("threads", "0", "worker threads, 0 = auto")
    .opt("t", "", "acquisition time of the first ingested .pgm layer")
    .opt("dt", "16", "time step between successive .pgm layers")
    .opt("momax-pgm", "", "write the running max|MOSUM| heatmap here")
    .opt("roc-quantile", "1.0", "quantile of per-pixel ROC starts (with --roc)")
    .switch("roc", "trim the unstable history with a reverse-ordered CUSUM scan (init)")
    .switch("no-fill", "disable forward/backward gap filling (init)")
    .switch("status", "print session status and exit");
    let m = cmd.parse(args)?;
    let state_dir = m.str("state")?.to_string();
    let threads = match m.usize("threads")? {
        0 => bfast::threadpool::default_threads(),
        n => n,
    };

    let mut session = if m.str("init")?.is_empty() {
        // resuming: every init-only flag would be silently ignored —
        // reject non-default values instead of dropping them
        for (flag, default) in [
            ("init-layers", "0"),
            ("n-hist", "100"),
            ("h", "50"),
            ("k", "3"),
            ("freq", "23"),
            ("alpha", "0.05"),
            ("m-chunk", "1024"),
            ("roc-quantile", "1.0"),
        ] {
            ensure!(
                m.str(flag)? == default,
                "--{flag} only applies with --init; the resumed session keeps its saved \
                 configuration"
            );
        }
        ensure!(
            !m.flag("roc") && !m.flag("no-fill"),
            "--roc/--no-fill only apply with --init; the resumed session keeps its saved \
             configuration"
        );
        let s = MonitorSession::load(&state_dir, threads)?;
        println!(
            "resumed session from {state_dir}: {} px, {} layers (n={}, h={}, k={}), \
             {} breaks so far",
            s.n_pixels(),
            s.n_seen(),
            s.params().n_hist,
            s.params().h,
            s.params().k,
            s.break_count()
        );
        s
    } else {
        ensure!(
            !std::path::Path::new(&state_dir).join("session.json").exists(),
            "{state_dir} already holds a session; --init would destroy its accumulated \
             state — remove the directory or choose another --state to start over"
        );
        let mut stack = rio::read_stack(m.str("init")?)?;
        let keep = m.usize("init-layers")?;
        if keep > 0 {
            stack = stack.prefix(keep)?;
        }
        let mut params = BfastParams::new(
            stack.n_times(),
            m.usize("n-hist")?,
            m.usize("h")?,
            m.usize("k")?,
            m.f64("freq")?,
            m.f64("alpha")?,
        )?;
        if m.flag("roc") {
            let sel = monitor::roc_select(&stack, &params, m.f64("roc-quantile")?, threads)?;
            println!(
                "ROC scan: stable history starts at layer {} (quantile {} of {} pixels)",
                sel.chosen,
                m.str("roc-quantile")?,
                sel.starts.len()
            );
            let (trimmed, adjusted) = monitor::apply_roc(&stack, &params, sel.chosen)?;
            stack = trimmed;
            params = adjusted;
        }
        let cfg = MonitorConfig {
            m_chunk: m.usize("m-chunk")?,
            threads,
            fill_missing: !m.flag("no-fill"),
        };
        let t0 = Instant::now();
        let s = MonitorSession::start(&stack, &params, cfg)?;
        println!(
            "primed session: {} px, {} layers (n={}, h={}, k={}, lambda={:.3}) in {:.3}s; \
             {} breaks in the initial archive",
            s.n_pixels(),
            s.n_seen(),
            params.n_hist,
            params.h,
            params.k,
            s.params().lambda,
            t0.elapsed().as_secs_f64(),
            s.break_count()
        );
        s
    };

    if m.flag("status") {
        ensure!(
            m.positional.is_empty(),
            "--status does not ingest: drop it to absorb {:?}",
            m.positional
        );
        session.save(&state_dir)?; // persists a freshly-primed session too
        println!(
            "state {state_dir}: {} px, {} layers, last t={:.3}, {} breaks ({:.2}%)",
            session.n_pixels(),
            session.n_seen(),
            session.time_axis().last().copied().unwrap_or(f64::NAN),
            session.break_count(),
            100.0 * session.break_count() as f64 / session.n_pixels().max(1) as f64
        );
        return Ok(());
    }

    // ingest positional layer files (.bsq archives or single .pgm layers)
    let mut deltas = Vec::new();
    let mut next_pgm_t = match m.str("t")? {
        "" => None,
        s => Some(s.parse::<f64>().map_err(|_| bfast::err!("--t: expected number, got {s:?}"))?),
    };
    let pgm_dt = m.f64("dt")?;
    for file in &m.positional {
        if file.ends_with(".pgm") {
            let t = next_pgm_t.ok_or_else(|| {
                bfast::err!("--t is required to ingest .pgm layers (they carry no time axis)")
            })?;
            let (w, h, values) = pgm::read_pgm(file)?;
            ensure!(
                w * h == session.n_pixels(),
                "{file}: {w}x{h} layer does not match the session's {} pixels",
                session.n_pixels()
            );
            let d = session.ingest(t, &values)?;
            next_pgm_t = Some(t + pgm_dt);
            deltas.push(d);
        } else {
            let stack = rio::read_stack(file)?;
            let skipped = stack.n_times();
            let new = session.ingest_stack(&stack)?;
            let skipped = skipped - new.len();
            if skipped > 0 {
                println!("{file}: skipped {skipped} already-seen layers");
            }
            deltas.extend(new);
        }
    }
    for d in &deltas {
        let head: Vec<String> =
            d.new_breaks.iter().take(8).map(|px| px_label(*px, &session)).collect();
        println!(
            "layer {} (t={:.3}): +{} new breaks, {} total{}{}",
            d.layer,
            d.t,
            d.new_breaks.len(),
            d.total_breaks,
            if head.is_empty() { "" } else { " — " },
            head.join(", ")
        );
    }
    if !deltas.is_empty() {
        print!(
            "{}",
            bfast::report::monitor_delta_table(&deltas, session.n_pixels()).to_console()
        );
    }

    let pgm_path = m.str("momax-pgm")?;
    if !pgm_path.is_empty() {
        let map = session.break_map();
        let (w, h) = match session.geometry() {
            (Some(w), Some(h)) => (w, h),
            _ => (map.momax.len(), 1),
        };
        let (lo, hi) = pgm::write_pgm_autoscale(pgm_path, &map.momax, w, h)?;
        println!("wrote {pgm_path} (scale {lo:.2}..{hi:.2})");
    }

    session.save(&state_dir)?;
    println!(
        "saved session to {state_dir}: {} layers, {} breaks",
        session.n_seen(),
        session.break_count()
    );
    Ok(())
}

/// Pixel label for delta reporting: `(x, y)` when the scene has
/// geometry, the flat index otherwise.
fn px_label(px: usize, session: &MonitorSession) -> String {
    match session.geometry() {
        (Some(w), Some(_)) if w > 0 => format!("({}, {})", px % w, px / w),
        _ => px.to_string(),
    }
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let cmd = param_flags(
        Command::new("inspect", "per-pixel detail")
            .req("input", "input .bsq stack")
            .req("pixel", "pixel index"),
    );
    let m = cmd.parse(args)?;
    let stack = rio::read_stack(m.str("input")?)?;
    let params = params_from(&m)?;
    let px = m.usize("pixel")?;
    // inspection is a pure-CPU path; any backend works
    let runner = BfastRunner::emulated(RunnerConfig::default())?;
    let res = runner.inspect_pixel(&stack, &params, px)?;
    println!(
        "pixel {px}: break={} first={} momax={:.3}",
        res.scan.has_break, res.scan.first, res.scan.momax
    );
    let bound = bfast::mosum::boundary(&params);
    println!("  t        MO_t     bound");
    for (i, (mo, b)) in res.mosum.iter().zip(&bound).enumerate() {
        let t = params.n_hist + 1 + i;
        let mark = if mo.abs() > *b { "  <-- break" } else { "" };
        println!("  {t:<6} {mo:>8.3}  {b:>8.3}{mark}");
    }
    Ok(())
}

fn cmd_lambda(args: &[String]) -> Result<()> {
    let cmd = Command::new("lambda-table", "simulated critical values")
        .opt("horizon", "2", "monitoring horizon N/n")
        .opt("alphas", "0.01,0.05,0.1", "comma-separated alphas (percent as fractions)")
        .opt("h-fracs", "0.25,0.5,1.0", "comma-separated h/n values");
    let m = cmd.parse(args)?;
    let alphas: Vec<f64> = m
        .str("alphas")?
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| bfast::err!("bad alpha {s:?}")))
        .collect::<Result<_>>()?;
    let hfracs: Vec<f64> = m
        .str("h-fracs")?
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| bfast::err!("bad h/n {s:?}")))
        .collect::<Result<_>>()?;
    print!("{}", bfast::lambda::table(m.f64("horizon")?, &alphas, &hfracs)?);
    Ok(())
}
