//! BFAST(monitor) hyper-parameters and their validation (paper §2.1).

use crate::error::{ensure, Result};

/// Parameters of one BFAST(monitor) analysis.
///
/// * `n_total` (N) — time-series length (history + monitor)
/// * `n_hist` (n) — stable history period used for the OLS fit
/// * `h` — MOSUM bandwidth, `1 ≤ h ≤ n`
/// * `k` — number of harmonic terms (season), regressors p = 2 + 2k
/// * `freq` (f) — observations per period (23 for 16-day series, 365
///   for day-of-year time axes)
/// * `alpha` — significance level of the boundary crossing
/// * `lambda` — critical value λ(α, h/n, N/n); either supplied or
///   derived via [`crate::lambda`]
#[derive(Clone, Debug, PartialEq)]
pub struct BfastParams {
    pub n_total: usize,
    pub n_hist: usize,
    pub h: usize,
    pub k: usize,
    pub freq: f64,
    pub alpha: f64,
    pub lambda: f64,
}

impl BfastParams {
    /// Construct with λ looked up from the built-in critical-value
    /// table for the given α.
    pub fn new(
        n_total: usize,
        n_hist: usize,
        h: usize,
        k: usize,
        freq: f64,
        alpha: f64,
    ) -> Result<Self> {
        let mut p = Self { n_total, n_hist, h, k, freq, alpha, lambda: f64::NAN };
        p.validate()?;
        p.lambda = crate::lambda::critical_value(
            alpha,
            h as f64 / n_hist as f64,
            n_total as f64 / n_hist as f64,
        )?;
        Ok(p)
    }

    /// Construct with an explicit λ (e.g. from a simulation run).
    pub fn with_lambda(
        n_total: usize,
        n_hist: usize,
        h: usize,
        k: usize,
        freq: f64,
        alpha: f64,
        lambda: f64,
    ) -> Result<Self> {
        let p = Self { n_total, n_hist, h, k, freq, alpha, lambda };
        p.validate()?;
        ensure!(lambda > 0.0, "lambda must be positive, got {lambda}");
        Ok(p)
    }

    /// Number of regressors p = 2 + 2k.
    pub fn p(&self) -> usize {
        2 + 2 * self.k
    }

    /// Length of the monitor period N − n.
    pub fn n_monitor(&self) -> usize {
        self.n_total - self.n_hist
    }

    /// σ̂ degrees of freedom n − (2 + 2k) (paper Alg. 3).
    pub fn dof(&self) -> usize {
        self.n_hist - self.p()
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.n_hist >= 1 && self.n_hist < self.n_total,
            "need 1 <= n < N, got n={} N={}",
            self.n_hist,
            self.n_total
        );
        ensure!(
            self.h >= 1 && self.h <= self.n_hist,
            "need 1 <= h <= n, got h={} n={}",
            self.h,
            self.n_hist
        );
        ensure!(self.k >= 1 && self.k <= 8, "need 1 <= k <= 8, got {}", self.k);
        ensure!(
            self.n_hist > self.p(),
            "history too short: n={} <= p={}",
            self.n_hist,
            self.p()
        );
        ensure!(self.freq > 0.0, "freq must be positive, got {}", self.freq);
        ensure!(
            self.alpha > 0.0 && self.alpha < 1.0,
            "alpha must be in (0,1), got {}",
            self.alpha
        );
        Ok(())
    }

    /// The paper's default synthetic-benchmark setting
    /// (§4.2: N=200, n=100, f=23, h=50, k=3, α=0.05).
    pub fn paper_synthetic() -> Self {
        Self::new(200, 100, 50, 3, 23.0, 0.05).expect("paper defaults are valid")
    }

    /// The paper's Chile Landsat setting
    /// (§4.3: N=288, n=144, h=72, k=3, f=365, α=0.05).
    pub fn paper_chile() -> Self {
        Self::new(288, 144, 72, 3, 365.0, 0.05).expect("paper defaults are valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_validate() {
        let p = BfastParams::paper_synthetic();
        assert_eq!(p.p(), 8);
        assert_eq!(p.n_monitor(), 100);
        assert_eq!(p.dof(), 92);
        assert!(p.lambda > 0.5 && p.lambda < 10.0, "lambda={}", p.lambda);
        let c = BfastParams::paper_chile();
        assert_eq!(c.n_monitor(), 144);
    }

    #[test]
    fn rejects_invalid() {
        assert!(BfastParams::new(100, 100, 10, 3, 23.0, 0.05).is_err()); // n == N
        assert!(BfastParams::new(200, 100, 101, 3, 23.0, 0.05).is_err()); // h > n
        assert!(BfastParams::new(200, 100, 0, 3, 23.0, 0.05).is_err()); // h == 0
        assert!(BfastParams::new(200, 7, 2, 3, 23.0, 0.05).is_err()); // n <= p
        assert!(BfastParams::new(200, 100, 50, 3, -1.0, 0.05).is_err()); // freq
        assert!(BfastParams::new(200, 100, 50, 3, 23.0, 1.5).is_err()); // alpha
        assert!(BfastParams::with_lambda(200, 100, 50, 3, 23.0, 0.05, -2.0).is_err());
    }
}
