//! Minimal JSON substrate (replaces `serde_json` for the offline
//! build). Parses/serialises the artifact manifest, config files and
//! experiment reports.
//!
//! Full RFC 8259 value model; numbers are kept as f64 (sufficient for
//! the shapes/params we store). Object key order is preserved.
//!
//! One deliberate extension beyond RFC 8259: non-finite numbers
//! serialise as the literals `NaN`, `Infinity` and `-Infinity` (the
//! same dialect Python's `json` emits) and parse back exactly, so
//! momax/β̂ statistics survive a serialize→parse round-trip the way
//! the `bten` container already guarantees bit-wise.

use crate::error::{bail, err, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    // -- typed accessors -------------------------------------------------

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            v => bail!("expected bool, got {}", v.kind()),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            v => bail!("expected number, got {}", v.kind()),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > u32::MAX as f64 * 4096.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("expected integer, got {n}");
        }
        Ok(n as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            v => bail!("expected string, got {}", v.kind()),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            v => bail!("expected array, got {}", v.kind()),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Ok(o),
            v => bail!("expected object, got {}", v.kind()),
        }
    }

    /// Object member lookup (error if absent).
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.try_get(key)
            .ok_or_else(|| err!("missing key {key:?} in object"))
    }

    /// Object member lookup (None if absent or not an object).
    pub fn try_get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
        }
    }

    // -- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_num(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    // -- serialisation ----------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(out, *n),
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !o.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() {
        out.push_str("NaN");
    } else if n == f64::INFINITY {
        out.push_str("Infinity");
    } else if n == f64::NEG_INFINITY {
        out.push_str("-Infinity");
    } else if n == 0.0 && n.is_sign_negative() {
        out.push_str("-0.0"); // the i64 shortcut would drop the sign
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value().context("JSON parse error")?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {} of JSON document", p.pos);
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Value> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'N') => self.literal("NaN", Value::Num(f64::NAN)),
            Some(b'I') => self.literal("Infinity", Value::Num(f64::INFINITY)),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            if self.peek() == Some(b'I') {
                return self.literal("Infinity", Value::Num(f64::NEG_INFINITY));
            }
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text.parse().with_context(|| format!("bad number {text:?}"))?;
        Ok(Value::Num(n))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| err!("truncated \\u escape"))?;
                            let code =
                                u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            // Surrogate pairs: only handle BMP + paired surrogates.
                            if (0xD800..0xDC00).contains(&code) {
                                // expect low surrogate
                                if self.bytes.get(self.pos + 5) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 6) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 7..self.pos + 11)
                                        .ok_or_else(|| err!("truncated surrogate"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2)?,
                                        16,
                                    )?;
                                    let c = 0x10000
                                        + ((code - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    s.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| err!("bad surrogate pair"))?,
                                    );
                                    self.pos += 10;
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                s.push(
                                    char::from_u32(code)
                                        .ok_or_else(|| err!("bad \\u escape"))?,
                                );
                                self.pos += 4;
                            }
                        }
                        other => bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                other => bail!("expected ',' or ']', found {:?}", other.map(|b| b as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut items: Vec<(String, Value)> = Vec::new();
        let mut seen = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(items));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            if seen.insert(key.clone(), ()).is_some() {
                bail!("duplicate key {key:?}");
            }
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            items.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(items));
                }
                other => bail!("expected ',' or '}}', found {:?}", other.map(|b| b as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"version": 1, "artifacts": [
            {"name": "small", "phase": "fused", "m_chunk": 1024,
             "use_pallas": true, "inputs": [{"name":"t","shape":[200],"dtype":"f32"}]}
        ]}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 1);
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("name").unwrap().as_str().unwrap(), "small");
        assert!(arts[0].get("use_pallas").unwrap().as_bool().unwrap());
        let ins = arts[0].get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(
            ins[0].get("shape").unwrap().as_arr().unwrap()[0]
                .as_usize()
                .unwrap(),
            200
        );
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = Value::obj(vec![
            ("a", Value::Num(1.5)),
            ("b", Value::Arr(vec![Value::Bool(true), Value::Null])),
            ("s", Value::Str("he\"llo\nworld".into())),
            ("empty", Value::Obj(vec![])),
        ]);
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn numbers() {
        for (s, want) in [
            ("0", 0.0),
            ("-12", -12.0),
            ("3.5", 3.5),
            ("1e3", 1000.0),
            ("-2.5E-2", -0.025),
        ] {
            assert_eq!(parse(s).unwrap().as_f64().unwrap(), want, "{s}");
        }
        assert!(parse("01abc").is_err());
    }

    #[test]
    fn non_finite_f32_fields_roundtrip() {
        // NaN/±inf momax/beta statistics must survive serialize→parse
        // (as bten already guarantees bit-wise)
        let momax = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.5, -0.0];
        let v = Value::obj(vec![
            ("momax", Value::arr_num(&momax.iter().map(|&x| x as f64).collect::<Vec<_>>())),
            ("beta", Value::Num(f64::NEG_INFINITY)),
        ]);
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            let back = parse(&text).unwrap();
            let arr = back.get("momax").unwrap().as_arr().unwrap();
            assert_eq!(arr.len(), momax.len());
            for (got, &want) in arr.iter().zip(&momax) {
                let got = got.as_f64().unwrap() as f32;
                assert_eq!(got.to_bits(), want.to_bits(), "{got} vs {want} in {text}");
            }
            assert_eq!(back.get("beta").unwrap().as_f64().unwrap(), f64::NEG_INFINITY);
        }
        // bare literals parse; lookalikes don't
        assert!(parse("NaN").unwrap().as_f64().unwrap().is_nan());
        assert_eq!(parse("Infinity").unwrap().as_f64().unwrap(), f64::INFINITY);
        assert_eq!(parse("-Infinity").unwrap().as_f64().unwrap(), f64::NEG_INFINITY);
        for bad in ["Nan", "Inf", "-Inf", "NaNx", "+Infinity"] {
            assert!(parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn escapes_and_unicode() {
        let v = parse(r#""é\tA 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é\tA 😀");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\":}", "tru", "\"abc", "{\"a\":1,\"a\":2}", "[] []"] {
            assert!(parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn typed_accessor_errors() {
        let v = parse("{\"n\": 1.5}").unwrap();
        assert!(v.get("n").unwrap().as_usize().is_err());
        assert!(v.get("n").unwrap().as_str().is_err());
        assert!(v.get("missing").is_err());
        assert!(v.try_get("missing").is_none());
    }
}
