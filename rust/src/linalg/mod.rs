//! Dense linear algebra substrate (replaces BLAS/LAPACK/numpy for the
//! offline build).
//!
//! Two tiers, matching how the paper's pipeline uses linear algebra:
//!
//! * [`Mat`] — small f64 matrices (design matrix, Gram, pseudo-inverse;
//!   p = 2+2k ≤ 12, n ≤ a few hundred). Clarity over speed.
//! * [`sgemm`] — the f32 hot path: blocked row-major matmul used by the
//!   fused multi-core implementation for β = M·Y and Ŷ = Xᵀβ where the
//!   pixel axis m reaches 10⁶.

pub mod gemm;

pub use gemm::{par_sgemm, sgemm, sgemm_acc};

use crate::error::{bail, ensure, Result};

/// Row-major dense f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        ensure!(
            data.len() == rows * cols,
            "Mat::from_vec: {}x{} needs {} elements, got {}",
            rows,
            cols,
            rows * cols,
            data.len()
        );
        Ok(Self { rows, cols, data })
    }

    /// Build row-by-row from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// C = self · other.
    pub fn matmul(&self, other: &Mat) -> Result<Mat> {
        ensure!(
            self.cols == other.rows,
            "matmul: {}x{} · {}x{}",
            self.rows,
            self.cols,
            other.rows,
            other.cols
        );
        let mut out = Mat::zeros(self.rows, other.cols);
        // ikj order: stream over rows of `other`, vectorises well.
        for i in 0..self.rows {
            for kk in 0..self.cols {
                let a = self[(i, kk)];
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(kk);
                let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Ok(out)
    }

    /// C = self · otherᵀ — avoids materialising the transpose.
    pub fn matmul_nt(&self, other: &Mat) -> Result<Mat> {
        ensure!(
            self.cols == other.cols,
            "matmul_nt: {}x{} · ({}x{})ᵀ",
            self.rows,
            self.cols,
            other.rows,
            other.cols
        );
        let mut out = Mat::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let arow = self.row(i);
            for j in 0..other.rows {
                out[(i, j)] = dot(arow, other.row(j));
            }
        }
        Ok(out)
    }

    /// y = self · x for a vector x.
    pub fn matvec(&self, x: &[f64]) -> Result<Vec<f64>> {
        ensure!(self.cols == x.len(), "matvec: {}x{} · {}", self.rows, self.cols, x.len());
        Ok((0..self.rows).map(|i| dot(self.row(i), x)).collect())
    }

    /// Inverse via Gauss–Jordan with partial pivoting.
    pub fn inverse(&self) -> Result<Mat> {
        ensure!(self.rows == self.cols, "inverse of non-square {}x{}", self.rows, self.cols);
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = Mat::eye(n);
        for col in 0..n {
            // partial pivot
            let mut piv = col;
            let mut best = a[(col, col)].abs();
            for r in col + 1..n {
                if a[(r, col)].abs() > best {
                    best = a[(r, col)].abs();
                    piv = r;
                }
            }
            if best < 1e-300 {
                bail!("inverse: singular matrix (pivot {col})");
            }
            if piv != col {
                a.swap_rows(piv, col);
                inv.swap_rows(piv, col);
            }
            let d = a[(col, col)];
            for j in 0..n {
                a[(col, j)] /= d;
                inv[(col, j)] /= d;
            }
            for r in 0..n {
                if r == col {
                    continue;
                }
                let f = a[(r, col)];
                if f == 0.0 {
                    continue;
                }
                for j in 0..n {
                    a[(r, j)] -= f * a[(col, j)];
                    inv[(r, j)] -= f * inv[(col, j)];
                }
            }
        }
        Ok(inv)
    }

    /// Cholesky factor L (lower) of an SPD matrix: self = L·Lᵀ.
    pub fn cholesky(&self) -> Result<Mat> {
        ensure!(self.rows == self.cols, "cholesky of non-square");
        let n = self.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = self[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        bail!("cholesky: matrix not positive definite (diag {i}: {s})");
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Ok(l)
    }

    /// Solve self · x = b for SPD self via Cholesky (b may be multi-column).
    pub fn solve_spd(&self, b: &Mat) -> Result<Mat> {
        ensure!(
            self.rows == b.rows,
            "solve_spd: {}x{} vs rhs {}x{}",
            self.rows,
            self.cols,
            b.rows,
            b.cols
        );
        let l = self.cholesky()?;
        let n = self.rows;
        let mut x = b.clone();
        // forward substitution L·z = b
        for col in 0..x.cols {
            for i in 0..n {
                let mut s = x[(i, col)];
                for k in 0..i {
                    s -= l[(i, k)] * x[(k, col)];
                }
                x[(i, col)] = s / l[(i, i)];
            }
            // back substitution Lᵀ·x = z
            for i in (0..n).rev() {
                let mut s = x[(i, col)];
                for k in i + 1..n {
                    s -= l[(k, i)] * x[(k, col)];
                }
                x[(i, col)] = s / l[(i, i)];
            }
        }
        Ok(x)
    }

    /// Moore–Penrose style left pseudo-inverse used by BFAST (Eq. 8):
    /// M = (self · selfᵀ)⁻¹ · self, for a wide full-row-rank matrix.
    pub fn pinv_wide(&self) -> Result<Mat> {
        let g = self.matmul_nt(self)?; // (p, p)
        g.solve_spd(self)
    }

    /// Frobenius-norm distance to another matrix.
    pub fn dist(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    fn swap_rows(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        let (a, b) = (a.min(b), a.max(b));
        let (top, bot) = self.data.split_at_mut(b * self.cols);
        top[a * self.cols..(a + 1) * self.cols]
            .swap_with_slice(&mut bot[..self.cols]);
    }

    /// Cast to a flat row-major f32 buffer.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg32;

    fn random_mat(rng: &mut Pcg32, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.uniform_in(-1.0, 1.0))
    }

    fn random_spd(rng: &mut Pcg32, n: usize) -> Mat {
        let a = random_mat(rng, n, n);
        let mut g = a.matmul_nt(&a).unwrap();
        for i in 0..n {
            g[(i, i)] += n as f64; // well-conditioned
        }
        g
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_nt_equals_matmul_of_transpose() {
        let mut rng = Pcg32::new(1);
        let a = random_mat(&mut rng, 5, 7);
        let b = random_mat(&mut rng, 4, 7);
        let c1 = a.matmul_nt(&b).unwrap();
        let c2 = a.matmul(&b.transpose()).unwrap();
        assert!(c1.dist(&c2) < 1e-12);
    }

    #[test]
    fn inverse_roundtrip() {
        let mut rng = Pcg32::new(2);
        for n in [1, 2, 5, 9] {
            let a = random_spd(&mut rng, n);
            let inv = a.inverse().unwrap();
            let id = a.matmul(&inv).unwrap();
            assert!(id.dist(&Mat::eye(n)) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn inverse_rejects_singular() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 2., 4.]).unwrap();
        assert!(a.inverse().is_err());
    }

    #[test]
    fn inverse_needs_pivoting_case() {
        // zero leading pivot — fails without partial pivoting
        let a = Mat::from_vec(2, 2, vec![0., 1., 1., 0.]).unwrap();
        let inv = a.inverse().unwrap();
        assert!(inv.dist(&a) < 1e-14); // own inverse
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg32::new(3);
        let g = random_spd(&mut rng, 8);
        let l = g.cholesky().unwrap();
        let back = l.matmul_nt(&l).unwrap();
        assert!(back.dist(&g) < 1e-9);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 2., 1.]).unwrap();
        assert!(a.cholesky().is_err());
    }

    #[test]
    fn solve_spd_matches_inverse() {
        let mut rng = Pcg32::new(4);
        let g = random_spd(&mut rng, 6);
        let b = random_mat(&mut rng, 6, 3);
        let x1 = g.solve_spd(&b).unwrap();
        let x2 = g.inverse().unwrap().matmul(&b).unwrap();
        assert!(x1.dist(&x2) < 1e-9);
    }

    #[test]
    fn pinv_wide_is_left_identity_on_range() {
        // For wide full-rank X: M = (XXᵀ)⁻¹X satisfies M·Xᵀ = I.
        let mut rng = Pcg32::new(5);
        let x = random_mat(&mut rng, 4, 20);
        let m = x.pinv_wide().unwrap();
        let id = m.matmul(&x.transpose()).unwrap();
        assert!(id.dist(&Mat::eye(4)) < 1e-9);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg32::new(6);
        let a = random_mat(&mut rng, 5, 4);
        let x: Vec<f64> = (0..4).map(|_| rng.uniform()).collect();
        let y = a.matvec(&x).unwrap();
        let xm = Mat::from_vec(4, 1, x).unwrap();
        let ym = a.matmul(&xm).unwrap();
        for i in 0..5 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-14);
        }
    }

    #[test]
    fn shape_errors_are_reported() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        assert!(a.matmul(&b).is_err());
        assert!(Mat::from_vec(2, 2, vec![0.0; 3]).is_err());
        assert!(a.matvec(&[0.0; 2]).is_err());
    }
}
