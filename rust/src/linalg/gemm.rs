//! f32 blocked GEMM — the multi-core hot path of the fused CPU
//! implementation (β = M·Y_hist, Ŷ = Xᵀ·β with m up to 10⁶ pixels).
//!
//! Row-major, no allocation, cache-blocked with a register-blocked
//! micro-kernel: MR = 4 C rows share every streamed B row, so the
//! innermost loop performs 4 multiply-adds per B load instead of 1
//! (auto-vectorises to AVX on the target). Per-element accumulation
//! order is identical to the scalar ikj kernel — for any C element the
//! k-index runs strictly increasing, and the `av == 0.0` skip is
//! applied per row exactly as before — so results are bit-identical to
//! the reference kernel. A second entry point accumulates into C for
//! panel-parallel callers.

/// Cache block sizes: an A K-panel must fit in L1-ish, B row segments
/// stream through L2. `MR` is the register tile height (C rows updated
/// together per B load).
const KC: usize = 128;
const NC: usize = 4096;
const MR: usize = 4;

/// C = A·B. A is (m × k), B is (k × n), C is (m × n); all row-major.
pub fn sgemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "sgemm: A size");
    assert_eq!(b.len(), k * n, "sgemm: B size");
    assert_eq!(c.len(), m * n, "sgemm: C size");
    c.fill(0.0);
    sgemm_acc(m, k, n, a, b, c);
}

/// C += A·B (same shapes as [`sgemm`]); caller owns the initial C.
pub fn sgemm_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "sgemm: A size");
    assert_eq!(b.len(), k * n, "sgemm: B size");
    assert_eq!(c.len(), m * n, "sgemm: C size");
    let view = crate::threadpool::SyncSlice::new(c);
    for jc in (0..n).step_by(NC) {
        let nb = NC.min(n - jc);
        // SAFETY: single caller thread; every row/panel handed out by
        // sgemm_cols is disjoint.
        unsafe { sgemm_cols(m, k, n, a, b, &view, jc, jc + nb, true) };
    }
}

/// Register-blocked micro-kernel: update `MR` C row strips with one
/// K-panel of A, streaming each B row once. When all `MR` A values for
/// a `p` are nonzero the fused path feeds all rows from one B pass;
/// otherwise each row applies (or skips) its own update in row order,
/// matching the scalar kernel's `av == 0.0` skip semantics bitwise
/// (NaN `av` takes the update, `-0.0` is skipped — same comparisons).
#[inline]
fn kpanel(
    c_rows: &mut [&mut [f32]; MR],
    a_rows: &[&[f32]; MR],
    b: &[f32],
    n: usize,
    j0: usize,
    pc: usize,
    kb: usize,
) {
    let [c0, c1, c2, c3] = c_rows;
    let [a0, a1, a2, a3] = a_rows;
    let nb = c0.len();
    for p in 0..kb {
        let (v0, v1, v2, v3) = (a0[p], a1[p], a2[p], a3[p]);
        let off = (pc + p) * n + j0;
        let brow = &b[off..off + nb];
        if v0 != 0.0 && v1 != 0.0 && v2 != 0.0 && v3 != 0.0 {
            for (j, &bv) in brow.iter().enumerate() {
                c0[j] += v0 * bv;
                c1[j] += v1 * bv;
                c2[j] += v2 * bv;
                c3[j] += v3 * bv;
            }
        } else {
            for (crow, v) in
                [(&mut **c0, v0), (&mut **c1, v1), (&mut **c2, v2), (&mut **c3, v3)]
            {
                if v == 0.0 {
                    continue;
                }
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += v * bv;
                }
            }
        }
    }
}

/// Compute the column panel `C[:, j0..j1] (+)= A · B[:, j0..j1]` where
/// A is (m × k), B is (k × n) and C is (m × n), all row-major with
/// their full widths as leading dimensions. Panels with disjoint
/// `[j0, j1)` touch disjoint C elements, so this is the unit of
/// thread-parallel GEMM (see [`par_sgemm`]).
///
/// # Safety
/// `c` is a raw view over the full C buffer; the caller guarantees
/// that concurrent calls use disjoint column ranges.
pub unsafe fn sgemm_cols(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &crate::threadpool::SyncSlice<'_, f32>,
    j0: usize,
    j1: usize,
    acc: bool,
) {
    debug_assert!(j0 <= j1 && j1 <= n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let nb = j1 - j0;
    if nb == 0 {
        return;
    }
    let mut i = 0usize;
    while i < m {
        if i + MR > m {
            // scalar tail: fewer than MR rows remain
            for r in i..m {
                let crow = unsafe { c.slice_mut(r * n + j0, r * n + j0 + nb) };
                if !acc {
                    crow.fill(0.0);
                }
                for pc in (0..k).step_by(KC) {
                    let kb = KC.min(k - pc);
                    let arow = &a[r * k + pc..r * k + pc + kb];
                    for (p, &av) in arow.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b[(pc + p) * n + j0..(pc + p) * n + j0 + nb];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += av * bv;
                        }
                    }
                }
            }
            break;
        }
        // SAFETY: the MR row strips are pairwise disjoint, and the
        // caller guarantees column panels are disjoint across threads.
        let mut rows: [&mut [f32]; MR] = unsafe {
            [
                c.slice_mut(i * n + j0, i * n + j0 + nb),
                c.slice_mut((i + 1) * n + j0, (i + 1) * n + j0 + nb),
                c.slice_mut((i + 2) * n + j0, (i + 2) * n + j0 + nb),
                c.slice_mut((i + 3) * n + j0, (i + 3) * n + j0 + nb),
            ]
        };
        if !acc {
            for r in rows.iter_mut() {
                r.fill(0.0);
            }
        }
        for pc in (0..k).step_by(KC) {
            let kb = KC.min(k - pc);
            let panels: [&[f32]; MR] = [
                &a[i * k + pc..i * k + pc + kb],
                &a[(i + 1) * k + pc..(i + 1) * k + pc + kb],
                &a[(i + 2) * k + pc..(i + 2) * k + pc + kb],
                &a[(i + 3) * k + pc..(i + 3) * k + pc + kb],
            ];
            kpanel(&mut rows, &panels, b, n, j0, pc, kb);
        }
        i += MR;
    }
}

/// Thread-parallel C = A·B by column panels (the m-pixel axis of the
/// BFAST batched fit/predict matmuls).
pub fn par_sgemm(
    threads: usize,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "par_sgemm: A size");
    assert_eq!(b.len(), k * n, "par_sgemm: B size");
    assert_eq!(c.len(), m * n, "par_sgemm: C size");
    let panel = 2048usize;
    let view = crate::threadpool::SyncSlice::new(c);
    crate::threadpool::parallel_ranges(n, panel, threads, |j0, j1| {
        // SAFETY: parallel_ranges hands out disjoint [j0, j1).
        unsafe { sgemm_cols(m, k, n, a, b, &view, j0, j1, false) };
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg32;

    fn naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] as f64 * b[p * n + j] as f64;
                }
            }
        }
        c.into_iter().map(|x| x as f32).collect()
    }

    fn rand_vec(rng: &mut Pcg32, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect()
    }

    #[test]
    fn matches_naive_over_shapes() {
        let mut rng = Pcg32::new(10);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (8, 8, 8),
            (65, 129, 33),   // crosses block boundaries
            (64, 128, 4096), // exactly one block
            (2, 300, 17),
            (130, 7, 4100),
        ] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut c = vec![0.0; m * n];
            sgemm(m, k, n, &a, &b, &mut c);
            let want = naive(m, k, n, &a, &b);
            for (i, (&x, &y)) in c.iter().zip(&want).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
                    "({m},{k},{n}) idx {i}: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn acc_accumulates() {
        let mut rng = Pcg32::new(11);
        let (m, k, n) = (4, 6, 5);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut c = vec![1.0f32; m * n];
        sgemm_acc(m, k, n, &a, &b, &mut c);
        let want = naive(m, k, n, &a, &b);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - (y + 1.0)).abs() < 1e-4);
        }
    }

    #[test]
    fn par_sgemm_matches_serial() {
        let mut rng = Pcg32::new(12);
        let (m, k, n) = (8, 100, 5000);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let mut c1 = vec![0.0; m * n];
        let mut c2 = vec![0.0; m * n];
        sgemm(m, k, n, &a, &b, &mut c1);
        par_sgemm(4, m, k, n, &a, &b, &mut c2);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    #[should_panic(expected = "sgemm: A size")]
    fn panics_on_bad_shape() {
        let mut c = vec![0.0; 4];
        sgemm(2, 2, 2, &[0.0; 3], &[0.0; 4], &mut c);
    }
}
