//! The **front door**: one typed request/response vocabulary shared by
//! the library, the CLI, the HTTP server and `bfast client`.
//!
//! Before this module, every entry point described "an analysis" in its
//! own terms — the CLI hand-assembled `BfastParams` + `RunnerConfig`
//! per subcommand, the serve queue had its own job struct, and the wire
//! used query strings. An [`AnalysisRequest`] is now the only way work
//! enters the system, which makes every request **self-describing**
//! (it can be logged, persisted, forwarded or replayed verbatim) and
//! **pixel-range-partitionable** (see [`ChunkSpec::pixel_range`]) —
//! the precondition for sharding one scene across several serve
//! instances.
//!
//! * [`AnalysisRequest`] — scene source + parameters + engine +
//!   chunking + outputs; executed via [`AnalysisRequest::execute`]
//!   (builds the engine the request names) or
//!   [`AnalysisRequest::execute_on`] (a host-provided runner — the
//!   serving path).
//! * [`SessionRequest`] / [`SessionInit`] / [`SessionIngest`] — the
//!   monitor-session vocabulary (prime once, ingest one layer at a
//!   time).
//! * [`JobHandle`] — progress observation plus cooperative
//!   cancellation: its [`CancelToken`] is threaded through the
//!   coordinator's chunk loop, so a cancelled analysis stops at the
//!   next chunk boundary instead of running to completion.
//!
//! ## v1 wire schema
//!
//! [`AnalysisRequest::to_json`] *is* the canonical on-the-wire and
//! on-disk job description (`POST /v1/runs` with
//! `Content-Type: application/json`):
//!
//! ```json
//! {
//!   "v": 1,
//!   "source":   {"kind": "inline", "bsq_b64": "<base64 .bsq bytes>"}
//!               | {"kind": "path", "path": "scene.bsq"},
//!   "params":   {"n_total": 48, "n_hist": 36, "h": 12, "k": 1,
//!                "freq": 12, "alpha": 0.05, "lambda": 3.0},
//!   "engine":   {"kind": "emulated"}
//!               | {"kind": "device", "artifacts": "artifacts", "artifact": "small"}
//!               | {"kind": "cmd"} | {"kind": "cpu"} | {"kind": "direct"}
//!               | {"kind": "naive"},
//!   "chunking": {"queue_depth": 2, "staging_threads": 0, "phased": false,
//!                "fill_missing": true, "autotune": true, "m_chunk": 512,
//!                "pixel_range": [0, 1024]},
//!   "outputs":  {"momax_pgm": "momax.pgm", "result_json": "res.json",
//!                "timings": false, "record": false}
//! }
//! ```
//!
//! Every section except `source` is optional and defaults as above
//! (`params.n_total`/`params.lambda` absent = derive from the scene /
//! the critical-value table; `pixel_range` absent = the whole scene).
//! `path` sources are for the CLI/library and trusted shard fan-out;
//! the public serve endpoints refuse them (see [`SceneSource`]).
//! `engine` and `chunking` are resolved by the *executing host*: a
//! server analyses with its own shared runner regardless of the
//! requested engine — break maps are bit-identical across backends by
//! construction (pinned by `tests/cross_backend.rs`).
//!
//! Session requests are tagged the same way: `{"kind": "init",
//! "source": ..., "params": ..., "init_layers": 37}` and
//! `{"kind": "ingest", "t": 61.0, "layer_b64": "<base64 f32 LE>"}`.
//!
//! The **response half** mirrors this design: every front door hands
//! back an [`AnalysisResult`] with its own canonical v1 JSON envelope
//! (break map as lossless base64 `.bten` tensors — served by
//! `GET /v1/runs/{id}/result`), and a sharded fan-out's per-range
//! [`PartialResult`]s reassemble into the identical bits via their
//! associative [`PartialResult::merge`]. See [`result`] for the
//! result-side schema and [`crate::shard`] for the fan-out
//! coordinator built on top.

pub mod result;

pub use result::{AnalysisResult, PartialResult};

use crate::cli::{Command, Matches};
use crate::coordinator::{BfastRunner, RunnerConfig};
use crate::cpu::FusedCpuBfast;
use crate::error::{bail, ensure, err, BfastError, Context, Result};
use crate::json::Value;
use crate::monitor::{MonitorConfig, MonitorSession};
use crate::params::BfastParams;
use crate::pixel::{DirectBfast, NaiveBfast};
use crate::raster::{io as rio, TimeStack};
use crate::runtime::ExecutorBackend;
use crate::store::hash::{HashingReader, Sha256};
use crate::b64::{base64_decode, base64_encode};
use std::borrow::Cow;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

// -- cancellation --------------------------------------------------------

/// Root-cause message of a cancelled analysis (see [`cancelled`]).
pub const CANCELLED_MSG: &str = "analysis cancelled";

/// The error a cancelled analysis returns.
pub fn cancelled() -> BfastError {
    BfastError::msg(CANCELLED_MSG)
}

/// Does this error mean "the caller cancelled", as opposed to a
/// failure? (The serve scheduler maps it to the `cancelled` job state
/// rather than `failed`.)
pub fn is_cancelled(e: &BfastError) -> bool {
    e.root_cause() == CANCELLED_MSG
}

/// Root-cause prefix of a request-validation failure (see [`invalid`]).
pub const INVALID_PREFIX: &str = "invalid request: ";

/// A **typed validation error**: the request itself is wrong (bad
/// `m_chunk`, an override the backend cannot honour, …), as opposed to
/// an execution failure. The serve layer maps these to a 400 at the
/// door; everything else stays a 500-class job failure.
pub fn invalid(msg: impl std::fmt::Display) -> BfastError {
    BfastError::msg(format!("{INVALID_PREFIX}{msg}"))
}

/// Does this error mean "the request was invalid" (see [`invalid`])?
pub fn is_invalid(e: &BfastError) -> bool {
    e.root_cause().starts_with(INVALID_PREFIX)
}

/// Cooperative cancellation flag, shareable across threads. The
/// coordinator checks it at every chunk boundary; once set, the
/// in-flight run returns [`cancelled`] instead of completing.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation (idempotent).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Live observation of one submitted analysis: chunk progress plus a
/// [`CancelToken`]. Clones share state — the serve queue keeps one
/// clone in the job record while the scheduler worker drives another.
#[derive(Clone, Debug, Default)]
pub struct JobHandle {
    cancel: CancelToken,
    progress: Arc<(AtomicUsize, AtomicUsize)>,
}

impl JobHandle {
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation of the job this handle observes.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// The token the executing runner polls.
    pub fn cancel_token(&self) -> &CancelToken {
        &self.cancel
    }

    /// Record chunk progress (called by the executing side).
    pub fn set_progress(&self, done: usize, total: usize) {
        self.progress.1.store(total, Ordering::Relaxed);
        self.progress.0.store(done, Ordering::Relaxed);
    }

    /// `(chunks_done, chunks_total)` of the observed run; `(0, 0)`
    /// before the chunk plan is known.
    pub fn progress(&self) -> (usize, usize) {
        (
            self.progress.0.load(Ordering::Relaxed),
            self.progress.1.load(Ordering::Relaxed),
        )
    }
}

// -- JSON field helpers --------------------------------------------------

fn get_usize_or(v: &Value, key: &str, default: usize) -> Result<usize> {
    match v.try_get(key) {
        None => Ok(default),
        Some(x) => x.as_usize().with_context(|| format!("field {key:?}")),
    }
}

fn get_f64_or(v: &Value, key: &str, default: f64) -> Result<f64> {
    match v.try_get(key) {
        None => Ok(default),
        Some(x) => x.as_f64().with_context(|| format!("field {key:?}")),
    }
}

fn get_bool_or(v: &Value, key: &str, default: bool) -> Result<bool> {
    match v.try_get(key) {
        None => Ok(default),
        Some(x) => x.as_bool().with_context(|| format!("field {key:?}")),
    }
}

// -- parameters ----------------------------------------------------------

/// Analysis parameters as a *request* states them — everything a
/// [`BfastParams`] needs except what the scene itself provides.
/// `n_total: None` takes N from the scene; `lambda: None` derives the
/// critical value from the built-in table.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub n_total: Option<usize>,
    pub n_hist: usize,
    pub h: usize,
    pub k: usize,
    pub freq: f64,
    pub alpha: f64,
    pub lambda: Option<f64>,
}

impl Default for ParamSpec {
    fn default() -> Self {
        Self {
            n_total: None,
            n_hist: 100,
            h: 50,
            k: 3,
            freq: 23.0,
            alpha: 0.05,
            lambda: None,
        }
    }
}

impl ParamSpec {
    /// Pin every field from concrete parameters (λ included, so a
    /// replayed request reproduces the same boundary bit-for-bit).
    pub fn from_params(p: &BfastParams) -> Self {
        Self {
            n_total: Some(p.n_total),
            n_hist: p.n_hist,
            h: p.h,
            k: p.k,
            freq: p.freq,
            alpha: p.alpha,
            lambda: Some(p.lambda),
        }
    }

    /// Resolve against a scene with `scene_layers` acquisitions.
    pub fn resolve(&self, scene_layers: usize) -> Result<BfastParams> {
        if let Some(n) = self.n_total {
            ensure!(
                n == scene_layers,
                "scene has {scene_layers} layers but the request pins N={n}"
            );
        }
        match self.lambda {
            Some(l) => BfastParams::with_lambda(
                scene_layers,
                self.n_hist,
                self.h,
                self.k,
                self.freq,
                self.alpha,
                l,
            ),
            None => BfastParams::new(
                scene_layers,
                self.n_hist,
                self.h,
                self.k,
                self.freq,
                self.alpha,
            ),
        }
    }

    pub fn to_json(&self) -> Value {
        let mut fields = Vec::new();
        if let Some(n) = self.n_total {
            fields.push(("n_total", Value::Num(n as f64)));
        }
        fields.push(("n_hist", Value::Num(self.n_hist as f64)));
        fields.push(("h", Value::Num(self.h as f64)));
        fields.push(("k", Value::Num(self.k as f64)));
        fields.push(("freq", Value::Num(self.freq)));
        fields.push(("alpha", Value::Num(self.alpha)));
        if let Some(l) = self.lambda {
            fields.push(("lambda", Value::Num(l)));
        }
        Value::obj(fields)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let d = ParamSpec::default();
        Ok(Self {
            n_total: match v.try_get("n_total") {
                None | Some(Value::Null) => None,
                Some(x) => Some(x.as_usize().context("field \"n_total\"")?),
            },
            n_hist: get_usize_or(v, "n_hist", d.n_hist)?,
            h: get_usize_or(v, "h", d.h)?,
            k: get_usize_or(v, "k", d.k)?,
            freq: get_f64_or(v, "freq", d.freq)?,
            alpha: get_f64_or(v, "alpha", d.alpha)?,
            lambda: match v.try_get("lambda") {
                None | Some(Value::Null) => None,
                Some(x) => Some(x.as_f64().context("field \"lambda\"")?),
            },
        })
    }
}

// -- scene source --------------------------------------------------------

/// Where the scene comes from. `Inline` travels with the request (the
/// wire form — serialised as base64 `.bsq` bytes); `Path` is read by
/// the executing host — the CLI form, and the form a trusted sharding
/// coordinator hands to workers that mount shared storage. The public
/// serve endpoints refuse `Path` sources (a remote caller must not be
/// able to make the server read arbitrary local files).
#[derive(Clone, Debug)]
pub enum SceneSource {
    Inline(TimeStack),
    Path(String),
}

impl SceneSource {
    /// Materialise the scene (borrowing the inline form).
    pub fn load(&self) -> Result<Cow<'_, TimeStack>> {
        match self {
            SceneSource::Inline(s) => Ok(Cow::Borrowed(s)),
            SceneSource::Path(p) => Ok(Cow::Owned(rio::read_stack(p)?)),
        }
    }

    pub fn to_json(&self) -> Value {
        match self {
            SceneSource::Inline(s) => Value::obj(vec![
                ("kind", Value::Str("inline".into())),
                ("bsq_b64", Value::Str(base64_encode(&rio::stack_to_bytes(s)))),
            ]),
            SceneSource::Path(p) => Value::obj(vec![
                ("kind", Value::Str("path".into())),
                ("path", Value::Str(p.clone())),
            ]),
        }
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        match v.get("kind")?.as_str()? {
            "inline" => {
                let bytes = base64_decode(v.get("bsq_b64")?.as_str()?)?;
                Ok(SceneSource::Inline(rio::stack_from_bytes(&bytes, "inline scene")?))
            }
            "path" => Ok(SceneSource::Path(v.get("path")?.as_str()?.to_string())),
            other => bail!("unknown scene source kind {other:?} (inline|path)"),
        }
    }
}

// -- engine --------------------------------------------------------------

/// Which implementation runs the analysis. The coordinator engines
/// (`Device`, `Emulated`, `Cmd`) stream chunks and honour progress +
/// cancellation; the reference engines (`Cpu`, `Direct`, `Naive`) are
/// the paper's comparison ladder and run scene-at-once. `Cmd` routes
/// every chunk through the recorded-command-stream interpreter
/// ([`crate::cmd`]) — same bits, different executor.
#[derive(Clone, Debug, PartialEq, Default)]
pub enum EngineSpec {
    Device { artifacts: String, artifact: Option<String> },
    #[default]
    Emulated,
    Cmd,
    Cpu,
    Direct,
    Naive,
}

impl EngineSpec {
    pub fn label(&self) -> &'static str {
        match self {
            EngineSpec::Device { .. } => "device",
            EngineSpec::Emulated => "emulated",
            EngineSpec::Cmd => "cmd",
            EngineSpec::Cpu => "cpu",
            EngineSpec::Direct => "direct",
            EngineSpec::Naive => "naive",
        }
    }

    /// Parse the CLI's `--engine` / `--artifacts` / `--artifact` trio.
    pub fn from_flags(engine: &str, artifacts: &str, artifact: &str) -> Result<Self> {
        Ok(match engine {
            "device" => EngineSpec::Device {
                artifacts: artifacts.to_string(),
                artifact: if artifact.is_empty() { None } else { Some(artifact.to_string()) },
            },
            "emulated" => EngineSpec::Emulated,
            "cmd" => EngineSpec::Cmd,
            "cpu" => EngineSpec::Cpu,
            "direct" => EngineSpec::Direct,
            "naive" => EngineSpec::Naive,
            other => bail!("unknown engine {other:?} (device|emulated|cmd|cpu|direct|naive)"),
        })
    }

    pub fn to_json(&self) -> Value {
        match self {
            EngineSpec::Device { artifacts, artifact } => {
                let mut fields = vec![
                    ("kind", Value::Str("device".into())),
                    ("artifacts", Value::Str(artifacts.clone())),
                ];
                if let Some(a) = artifact {
                    fields.push(("artifact", Value::Str(a.clone())));
                }
                Value::obj(fields)
            }
            other => Value::obj(vec![("kind", Value::Str(other.label().into()))]),
        }
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        match v.get("kind")?.as_str()? {
            "device" => Ok(EngineSpec::Device {
                artifacts: match v.try_get("artifacts") {
                    None | Some(Value::Null) => "artifacts".to_string(),
                    Some(x) => x.as_str()?.to_string(),
                },
                artifact: match v.try_get("artifact") {
                    None | Some(Value::Null) => None,
                    Some(x) => Some(x.as_str()?.to_string()),
                },
            }),
            "emulated" => Ok(EngineSpec::Emulated),
            "cmd" => Ok(EngineSpec::Cmd),
            "cpu" => Ok(EngineSpec::Cpu),
            "direct" => Ok(EngineSpec::Direct),
            "naive" => Ok(EngineSpec::Naive),
            other => bail!("unknown engine kind {other:?}"),
        }
    }
}

// -- chunking ------------------------------------------------------------

/// How the scene is streamed: the coordinator knobs plus the pixel
/// range this request covers. `pixel_range: Some((a, b))` analyses
/// only pixels `[a, b)` — a sharding coordinator splits one scene into
/// several requests that differ *only* here.
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkSpec {
    /// Bounded staging→executor queue depth (≥ 1).
    pub queue_depth: usize,
    /// Staging worker threads (0 = auto).
    pub staging_threads: usize,
    /// Run the per-phase instrumented executables.
    pub phased: bool,
    /// Gap-fill NaN observations during staging.
    pub fill_missing: bool,
    /// Pin the chunk width (pixels per executed chunk). Only honoured
    /// by flexible-chunk backends — a shape-specialised backend rejects
    /// the override with a typed [`invalid`] error rather than padding
    /// or ignoring it. `Some(0)` is refused at submit time.
    pub m_chunk: Option<usize>,
    /// Let auto-built runners pick the chunk width with the bench
    /// autotuner on first run (ignored when [`ChunkSpec::m_chunk`] is
    /// set). Defaults to on.
    pub autotune: bool,
    /// Restrict the analysis to pixels `[start, end)`.
    pub pixel_range: Option<(usize, usize)>,
}

impl Default for ChunkSpec {
    fn default() -> Self {
        Self {
            queue_depth: 2,
            staging_threads: 0,
            phased: false,
            fill_missing: true,
            m_chunk: None,
            autotune: true,
            pixel_range: None,
        }
    }
}

impl ChunkSpec {
    /// Lower to a coordinator configuration.
    pub fn runner_config(&self, artifact: Option<String>) -> RunnerConfig {
        let mut cfg = RunnerConfig {
            artifact,
            queue_depth: self.queue_depth,
            phased: self.phased,
            fill_missing: self.fill_missing,
            m_chunk: self.m_chunk,
            autotune: self.autotune,
            ..RunnerConfig::default()
        };
        if self.staging_threads > 0 {
            cfg.staging_threads = self.staging_threads;
        }
        cfg
    }

    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("queue_depth", Value::Num(self.queue_depth as f64)),
            ("staging_threads", Value::Num(self.staging_threads as f64)),
            ("phased", Value::Bool(self.phased)),
            ("fill_missing", Value::Bool(self.fill_missing)),
        ];
        if let Some(mc) = self.m_chunk {
            fields.push(("m_chunk", Value::Num(mc as f64)));
        }
        fields.push(("autotune", Value::Bool(self.autotune)));
        if let Some((a, b)) = self.pixel_range {
            fields.push(("pixel_range", Value::arr_usize(&[a, b])));
        }
        Value::obj(fields)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let d = ChunkSpec::default();
        let pixel_range = match v.try_get("pixel_range") {
            None | Some(Value::Null) => None,
            Some(x) => {
                let arr = x.as_arr().context("field \"pixel_range\"")?;
                ensure!(arr.len() == 2, "pixel_range must be [start, end]");
                Some((arr[0].as_usize()?, arr[1].as_usize()?))
            }
        };
        Ok(Self {
            queue_depth: get_usize_or(v, "queue_depth", d.queue_depth)?,
            staging_threads: get_usize_or(v, "staging_threads", d.staging_threads)?,
            phased: get_bool_or(v, "phased", d.phased)?,
            fill_missing: get_bool_or(v, "fill_missing", d.fill_missing)?,
            m_chunk: match v.try_get("m_chunk") {
                None | Some(Value::Null) => None,
                Some(x) => Some(x.as_usize().context("field \"m_chunk\"")?),
            },
            autotune: get_bool_or(v, "autotune", d.autotune)?,
            pixel_range,
        })
    }
}

// -- outputs -------------------------------------------------------------

/// What the caller wants back beyond the break map.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct OutputSpec {
    /// Render the max-|MOSUM| heatmap PGM here (CLI-side).
    pub momax_pgm: Option<String>,
    /// Write the canonical v1 [`AnalysisResult`] JSON envelope here
    /// (CLI-side) — the same bytes `GET /v1/runs/{id}/result` serves.
    pub result_json: Option<String>,
    /// Print/collect the phase breakdown.
    pub timings: bool,
    /// Capture the analysis as a replayable command stream. On serve,
    /// the recorded `.bcmd` bytes are kept with the job and served by
    /// `GET /v1/runs/{id}/cmdstream`; the CLI's `bfast run --record
    /// PATH` writes them to disk. Recorded jobs opt out of request
    /// batching (their stream must describe exactly one job).
    pub record: bool,
}

impl OutputSpec {
    pub fn to_json(&self) -> Value {
        let mut fields = Vec::new();
        if let Some(p) = &self.momax_pgm {
            fields.push(("momax_pgm", Value::Str(p.clone())));
        }
        if let Some(p) = &self.result_json {
            fields.push(("result_json", Value::Str(p.clone())));
        }
        fields.push(("timings", Value::Bool(self.timings)));
        if self.record {
            fields.push(("record", Value::Bool(true)));
        }
        Value::obj(fields)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let opt_str = |key: &str| -> Result<Option<String>> {
            match v.try_get(key) {
                None | Some(Value::Null) => Ok(None),
                Some(x) => Ok(Some(x.as_str()?.to_string())),
            }
        };
        Ok(Self {
            momax_pgm: opt_str("momax_pgm")?,
            result_json: opt_str("result_json")?,
            timings: get_bool_or(v, "timings", false)?,
            record: get_bool_or(v, "record", false)?,
        })
    }
}

// -- the analysis request ------------------------------------------------

/// One break-detection analysis, fully described. This is the only
/// unit of work the system accepts: the CLI parses its flags into one,
/// the server queues them, `bfast client submit` posts one, and the
/// library executes them directly.
#[derive(Clone, Debug)]
pub struct AnalysisRequest {
    pub source: SceneSource,
    pub params: ParamSpec,
    pub engine: EngineSpec,
    pub chunking: ChunkSpec,
    pub outputs: OutputSpec,
    /// Correlation id for the flight recorder: minted at the front
    /// door when absent ([`crate::trace::new_request_id`]), propagated
    /// on the wire both in this JSON and as `X-Request-Id`, so one
    /// gateway run stitches its workers' spans into a single
    /// distributed trace. `None` serialises to nothing (the wire form
    /// without an id is unchanged).
    pub request_id: Option<String>,
}

impl AnalysisRequest {
    /// A request over `source` with every other section defaulted.
    pub fn new(source: SceneSource) -> Self {
        Self {
            source,
            params: ParamSpec::default(),
            engine: EngineSpec::default(),
            chunking: ChunkSpec::default(),
            outputs: OutputSpec::default(),
            request_id: None,
        }
    }

    /// Cheap admission check — everything that can be verified without
    /// copying scene data or touching the filesystem. The serve layer
    /// runs this at submit time so an invalid request is a 400 at the
    /// door, not a queued job that fails minutes later (`Path` sources
    /// defer to execution, where the file is actually read).
    pub fn validate(&self) -> Result<()> {
        if self.chunking.m_chunk == Some(0) {
            return Err(invalid("chunking.m_chunk must be >= 1"));
        }
        if let SceneSource::Inline(s) = &self.source {
            if let Some((start, end)) = self.chunking.pixel_range {
                ensure!(
                    start < end && end <= s.n_pixels(),
                    "pixel_range [{start}, {end}) out of bounds for {} pixels",
                    s.n_pixels()
                );
            }
            self.params.resolve(s.n_times())?;
        }
        Ok(())
    }

    /// Materialise the (pixel-range-sliced) scene and concrete
    /// parameters this request describes.
    pub fn resolve(&self) -> Result<(Cow<'_, TimeStack>, BfastParams)> {
        let mut stack = self.source.load()?;
        if let Some((start, end)) = self.chunking.pixel_range {
            ensure!(
                start < end && end <= stack.n_pixels(),
                "pixel_range [{start}, {end}) out of bounds for {} pixels",
                stack.n_pixels()
            );
            stack = Cow::Owned(stack.slice_pixels(start, end));
        }
        let params = self.params.resolve(stack.n_times())?;
        Ok((stack, params))
    }

    /// Execute with the engine the request names, constructing it
    /// here. Coordinator engines report per-chunk progress through
    /// `handle` and stop at the next chunk boundary once
    /// [`JobHandle::cancel`] is called; the scene-at-once reference
    /// engines check the token only before starting.
    pub fn execute(&self, handle: &JobHandle) -> Result<AnalysisResult> {
        match &self.engine {
            EngineSpec::Device { artifacts, artifact } => {
                let cfg = self.chunking.runner_config(artifact.clone());
                let runner = BfastRunner::auto(artifacts, cfg)?;
                if runner.platform().starts_with("emulated") {
                    eprintln!(
                        "bfast: no device backend available (no artifacts at {artifacts:?}); \
                         running on the emulated backend — request engine \"emulated\" to \
                         select it explicitly"
                    );
                }
                self.execute_on(&runner, handle)
            }
            EngineSpec::Emulated => {
                let runner = BfastRunner::emulated(self.chunking.runner_config(None))?;
                self.execute_on(&runner, handle)
            }
            EngineSpec::Cmd => {
                let runner = BfastRunner::cmdstream(self.chunking.runner_config(None))?;
                self.execute_on(&runner, handle)
            }
            EngineSpec::Cpu | EngineSpec::Direct | EngineSpec::Naive => {
                if handle.is_cancelled() {
                    return Err(cancelled());
                }
                let (stack, params) = self.resolve()?;
                let stack = &*stack;
                let t0 = Instant::now();
                handle.set_progress(0, 1);
                let (map, phases) = match self.engine {
                    EngineSpec::Cpu => {
                        let eng = FusedCpuBfast::new(params.clone(), &stack.time_axis)?;
                        let (map, times) = eng.run(stack)?;
                        (map, Some(times))
                    }
                    EngineSpec::Direct => (
                        DirectBfast::new(params.clone(), &stack.time_axis)?.run(stack)?,
                        None,
                    ),
                    _ => (NaiveBfast::new(params.clone()).run(stack)?, None),
                };
                handle.set_progress(1, 1);
                Ok(AnalysisResult {
                    map,
                    params,
                    phases,
                    chunks: 1,
                    artifact: self.engine.label().to_string(),
                    engine: self.engine.label().to_string(),
                    wall: t0.elapsed(),
                    width: stack.width,
                    height: stack.height,
                })
            }
        }
    }

    /// Execute on a host-provided coordinator runner — the serving
    /// path, where one shared runner drains the whole job queue. The
    /// request's `engine`/`chunking` performance knobs are the host's
    /// prerogative here; `source`, `params` and `pixel_range` are
    /// honoured (break maps are backend-invariant, so the answer is
    /// the same bits either way).
    pub fn execute_on<B: ?Sized + ExecutorBackend>(
        &self,
        runner: &BfastRunner<B>,
        handle: &JobHandle,
    ) -> Result<AnalysisResult> {
        let (stack, params) = self.resolve()?;
        let res = runner.run_with_progress(
            &stack,
            &params,
            handle.cancel_token(),
            |done, total| handle.set_progress(done, total),
        )?;
        Ok(AnalysisResult {
            map: res.map,
            params,
            phases: Some(res.phases),
            chunks: res.chunks,
            artifact: res.artifact,
            engine: runner.platform(),
            wall: res.wall,
            width: stack.width,
            height: stack.height,
        })
    }

    pub fn to_json(&self) -> Value {
        let mut fields = vec![("v", Value::Num(1.0))];
        if let Some(rid) = &self.request_id {
            fields.push(("request_id", Value::Str(rid.clone())));
        }
        fields.extend([
            ("source", self.source.to_json()),
            ("params", self.params.to_json()),
            ("engine", self.engine.to_json()),
            ("chunking", self.chunking.to_json()),
            ("outputs", self.outputs.to_json()),
        ]);
        Value::obj(fields)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        if let Some(ver) = v.try_get("v") {
            let ver = ver.as_usize().context("field \"v\"")?;
            ensure!(ver == 1, "unsupported request version {ver} (this build speaks v1)");
        }
        Ok(Self {
            source: SceneSource::from_json(v.get("source").context("analysis request")?)?,
            params: match v.try_get("params") {
                None | Some(Value::Null) => ParamSpec::default(),
                Some(x) => ParamSpec::from_json(x)?,
            },
            engine: match v.try_get("engine") {
                None | Some(Value::Null) => EngineSpec::default(),
                Some(x) => EngineSpec::from_json(x)?,
            },
            chunking: match v.try_get("chunking") {
                None | Some(Value::Null) => ChunkSpec::default(),
                Some(x) => ChunkSpec::from_json(x)?,
            },
            outputs: match v.try_get("outputs") {
                None | Some(Value::Null) => OutputSpec::default(),
                Some(x) => OutputSpec::from_json(x)?,
            },
            request_id: match v.try_get("request_id") {
                None | Some(Value::Null) => None,
                Some(x) => Some(x.as_str().context("field \"request_id\"")?.to_string()),
            },
        })
    }

    /// Compact JSON — the exact bytes `bfast client submit` posts.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn from_json_str(text: &str) -> Result<Self> {
        Self::from_json(&crate::json::parse(text)?)
    }

    /// The scene's content digest: SHA-256 hex of its canonical `.bsq`
    /// byte stream. Inline scenes stream through
    /// [`rio::stack_digest_hex`] (no byte copy); `Path` sources stream
    /// the file through a [`HashingReader`]. Files written by this
    /// repo's own writer hash identically to their inline form.
    pub fn scene_digest(&self) -> Result<String> {
        match &self.source {
            SceneSource::Inline(s) => Ok(rio::stack_digest_hex(s)),
            SceneSource::Path(p) => {
                let file =
                    std::fs::File::open(p).with_context(|| format!("opening {p}"))?;
                let mut r = HashingReader::new(std::io::BufReader::new(file));
                std::io::copy(&mut r, &mut std::io::sink())
                    .with_context(|| format!("reading {p}"))?;
                Ok(r.digest_hex())
            }
        }
    }

    /// The request's cache key: SHA-256 hex over the scene digest plus
    /// the **result-relevant** fields — the params section and
    /// `pixel_range`. Engine choice, the chunking performance knobs,
    /// outputs and `request_id` are deliberately excluded: break maps
    /// are backend-invariant by construction (and the executing host
    /// owns the streaming knobs anyway), so requests differing only
    /// there are the same computation and must share a cache entry.
    pub fn request_digest(&self) -> Result<String> {
        let mut h = Sha256::new();
        h.update(b"bfast-request-v1\n");
        h.update(self.scene_digest()?.as_bytes());
        h.update(b"\n");
        h.update(self.params.to_json().to_string_compact().as_bytes());
        h.update(b"\n");
        match self.chunking.pixel_range {
            Some((a, b)) => h.update(format!("pixels:{a}:{b}").as_bytes()),
            None => h.update(b"pixels:all"),
        }
        Ok(h.finalize_hex())
    }
}

/// Serialise the wire body of a pixel-range sub-request over `stack` —
/// the shard/gateway fan-out path. Byte-identical to building an
/// [`AnalysisRequest`] with
/// `SceneSource::Inline(stack.slice_pixels(range.0, range.1))` (and
/// `chunking.pixel_range` cleared — the slice already applied it) and
/// calling [`AnalysisRequest::to_json_string`], but streams the sliced
/// `.bsq` payload straight into the body: no intermediate sliced
/// [`TimeStack`], no `Value` tree holding the base64, and no escaping
/// scan over it (base64 never needs JSON escaping). An N-worker
/// fan-out therefore holds one encoded body per shard instead of ~4
/// transient copies of each slice.
pub fn slice_request_body(
    stack: &TimeStack,
    range: (usize, usize),
    params: &ParamSpec,
    engine: &EngineSpec,
    chunking: &ChunkSpec,
    request_id: Option<&str>,
) -> String {
    let bsq = rio::slice_to_bytes(stack, range.0, range.1);
    let b64 = base64_encode(&bsq);
    drop(bsq);
    let mut sub_chunking = chunking.clone();
    sub_chunking.pixel_range = None;
    let params_js = params.to_json().to_string_compact();
    let engine_js = engine.to_json().to_string_compact();
    let chunking_js = sub_chunking.to_json().to_string_compact();
    let outputs_js = OutputSpec::default().to_json().to_string_compact();
    let mut body = String::with_capacity(
        b64.len() + params_js.len() + engine_js.len() + chunking_js.len() + outputs_js.len() + 128,
    );
    body.push_str("{\"v\":1");
    if let Some(rid) = request_id {
        body.push_str(",\"request_id\":");
        body.push_str(&Value::Str(rid.to_string()).to_string_compact());
    }
    body.push_str(",\"source\":{\"kind\":\"inline\",\"bsq_b64\":\"");
    body.push_str(&b64);
    body.push_str("\"},\"params\":");
    body.push_str(&params_js);
    body.push_str(",\"engine\":");
    body.push_str(&engine_js);
    body.push_str(",\"chunking\":");
    body.push_str(&chunking_js);
    body.push_str(",\"outputs\":");
    body.push_str(&outputs_js);
    body.push('}');
    body
}

/// Record a request's analysis into a replayable command stream plus
/// the **deterministic** replay envelope (zero wall time, no phase
/// table — see [`crate::cmd::replay_to_results`]). The stream is what
/// `bfast run --record` encodes to `.bcmd` and what a recording serve
/// job keeps for `GET /v1/runs/{id}/cmdstream`; re-executing it
/// anywhere reproduces the identical envelope byte for byte.
pub fn record_request(req: &AnalysisRequest) -> Result<(crate::cmd::CmdStream, AnalysisResult)> {
    req.validate()?;
    let (stack, params) = req.resolve()?;
    let runner = BfastRunner::cmdstream(req.chunking.runner_config(None))?;
    let tag = req.request_id.as_deref().unwrap_or("job 0");
    let stream = runner.record(&stack, &params, tag)?;
    let mut results = crate::cmd::replay_to_results(&stream)?;
    let res = results.pop().context("recording produced no job results")?;
    Ok((stream, res))
}

// -- session requests ----------------------------------------------------

/// Prime a monitor session: the one-time staged history pass over an
/// initial archive (`POST /v1/sessions/{name}`, `bfast monitor
/// --init`, or [`SessionInit::start_on`] in-process).
#[derive(Clone, Debug)]
pub struct SessionInit {
    pub source: SceneSource,
    pub params: ParamSpec,
    /// Prime on only the first K layers of the source (0 = all).
    pub init_layers: usize,
}

impl SessionInit {
    pub fn new(source: SceneSource) -> Self {
        Self { source, params: ParamSpec::default(), init_layers: 0 }
    }

    /// Materialise the (possibly truncated) initial archive and the
    /// concrete parameters. Borrows an inline scene when no truncation
    /// is needed — no double-RSS copy of a scene the request already
    /// holds.
    pub fn resolve(&self) -> Result<(Cow<'_, TimeStack>, BfastParams)> {
        let mut stack = self.source.load()?;
        if self.init_layers > 0 {
            stack = Cow::Owned(stack.prefix(self.init_layers)?);
        }
        let params = self.params.resolve(stack.n_times())?;
        Ok((stack, params))
    }

    /// Prime through a runner (chunk plan from its backend) — the
    /// serving path.
    pub fn start_on<B: ?Sized + ExecutorBackend>(
        &self,
        runner: &BfastRunner<B>,
    ) -> Result<MonitorSession> {
        let (stack, params) = self.resolve()?;
        runner.start_monitor(&stack, &params)
    }

    /// Prime with explicit chunking — the CLI path, which exposes
    /// `--m-chunk`/`--threads` directly.
    pub fn start_local(
        &self,
        m_chunk: usize,
        threads: usize,
        fill_missing: bool,
    ) -> Result<MonitorSession> {
        let (stack, params) = self.resolve()?;
        MonitorSession::start(&stack, &params, MonitorConfig { m_chunk, threads, fill_missing })
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("kind", Value::Str("init".into())),
            ("source", self.source.to_json()),
            ("params", self.params.to_json()),
            ("init_layers", Value::Num(self.init_layers as f64)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        if let Some(k) = v.try_get("kind") {
            ensure!(k.as_str()? == "init", "expected a session init request");
        }
        Ok(Self {
            source: SceneSource::from_json(v.get("source").context("session init")?)?,
            params: match v.try_get("params") {
                None | Some(Value::Null) => ParamSpec::default(),
                Some(x) => ParamSpec::from_json(x)?,
            },
            init_layers: get_usize_or(v, "init_layers", 0)?,
        })
    }
}

/// Feed one acquisition layer into a live session
/// (`POST /v1/sessions/{name}/ingest`). The JSON form is
/// `{"kind": "ingest", "t": 61.0, "layer_b64": "<base64 f32 LE>"}` —
/// `kind` may be omitted on the ingest endpoint, which only accepts
/// this shape.
#[derive(Clone, Debug)]
pub struct SessionIngest {
    /// Acquisition time (must extend the session's time axis).
    pub t: f64,
    /// One value per pixel.
    pub values: Vec<f32>,
}

impl SessionIngest {
    pub fn to_json(&self) -> Value {
        let bytes: Vec<u8> = self.values.iter().flat_map(|v| v.to_le_bytes()).collect();
        Value::obj(vec![
            ("kind", Value::Str("ingest".into())),
            ("t", Value::Num(self.t)),
            ("layer_b64", Value::Str(base64_encode(&bytes))),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        if let Some(k) = v.try_get("kind") {
            ensure!(k.as_str()? == "ingest", "expected a session ingest request");
        }
        let t = v.get("t")?.as_f64()?;
        let bytes = base64_decode(v.get("layer_b64")?.as_str()?)?;
        ensure!(
            bytes.len() % 4 == 0,
            "layer_b64 must decode to little-endian f32 values"
        );
        let values = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Self { t, values })
    }
}

/// The monitor-session vocabulary: init or ingest, dispatched on the
/// JSON `kind` tag.
#[derive(Clone, Debug)]
pub enum SessionRequest {
    Init(SessionInit),
    Ingest(SessionIngest),
}

impl SessionRequest {
    pub fn to_json(&self) -> Value {
        match self {
            SessionRequest::Init(i) => i.to_json(),
            SessionRequest::Ingest(g) => g.to_json(),
        }
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        match v.get("kind")?.as_str()? {
            "init" => Ok(SessionRequest::Init(SessionInit::from_json(v)?)),
            "ingest" => Ok(SessionRequest::Ingest(SessionIngest::from_json(v)?)),
            other => bail!("unknown session request kind {other:?} (init|ingest)"),
        }
    }
}

// -- the CLI front door --------------------------------------------------

/// Shared analysis-parameter flags (`run`, `generate`, `inspect`).
pub fn param_flags(c: Command) -> Command {
    c.opt("n-total", "200", "series length N")
        .opt("n-hist", "100", "stable history length n")
        .opt("h", "50", "MOSUM bandwidth")
        .opt("k", "3", "harmonic terms")
        .opt("freq", "23", "observations per period f")
        .opt("alpha", "0.05", "significance level")
}

/// The `bfast run` flag surface. Lives here (not in `main.rs`) so the
/// front-door equivalence tests can drive the *same* flags→request
/// parsing the binary uses.
pub fn run_command() -> Command {
    param_flags(
        Command::new("run", "analyse a stack")
            .req("input", "input .bsq stack")
            .opt("engine", "device", "device | emulated | cmd | cpu | direct | naive")
            .opt("artifacts", "artifacts", "artifact directory (device)")
            .opt("artifact", "", "artifact config name override (device)")
            .opt("queue-depth", "2", "staging queue depth (device)")
            .opt("staging-threads", "0", "staging threads, 0 = auto (device)")
            .opt("m-chunk", "0", "pin the chunk width, 0 = backend default")
            .opt("pixels", "", "analyse only the pixel range START:END")
            .opt("momax-pgm", "", "write max|MOSUM| heatmap PGM here")
            .opt("result-json", "", "write the v1 result envelope JSON here")
            .opt("record", "", "record the run as a replayable .bcmd command stream here")
            .switch("no-autotune", "disable the first-run chunk-width autotuner")
            .switch("phased", "run the per-phase executables (instrumented)")
            .switch("timings", "print the phase breakdown"),
    )
}

/// Parse `bfast run` flags into the one request type.
pub fn run_request_from_args(args: &[String]) -> Result<AnalysisRequest> {
    run_request_from_matches(&run_command().parse(args)?)
}

/// Parse a `--pixels START:END` flag value ("" = the whole scene) —
/// shared by `bfast run` and `bfast shard`.
pub fn parse_pixel_range(s: &str) -> Result<Option<(usize, usize)>> {
    match s {
        "" => Ok(None),
        s => {
            let (a, b) = s
                .split_once(':')
                .ok_or_else(|| err!("--pixels expects START:END, got {s:?}"))?;
            let start = a
                .trim()
                .parse()
                .map_err(|_| err!("--pixels: bad start {a:?}"))?;
            let end = b
                .trim()
                .parse()
                .map_err(|_| err!("--pixels: bad end {b:?}"))?;
            Ok(Some((start, end)))
        }
    }
}

/// The [`param_flags`] values as a [`ParamSpec`] with N pinned —
/// shared by every subcommand that carries the analysis-parameter
/// flag set (`run`, `shard`), so a new parameter flag is parsed in
/// exactly one place.
pub fn param_spec_from_matches(m: &Matches) -> Result<ParamSpec> {
    Ok(ParamSpec {
        n_total: Some(m.usize("n-total")?),
        n_hist: m.usize("n-hist")?,
        h: m.usize("h")?,
        k: m.usize("k")?,
        freq: m.f64("freq")?,
        alpha: m.f64("alpha")?,
        lambda: None,
    })
}

/// The `--momax-pgm`/`--result-json`/`--timings` flag trio as an
/// [`OutputSpec`] ("" = not requested) — shared by `run` and `shard`.
pub fn outputs_from_matches(m: &Matches) -> Result<OutputSpec> {
    let opt = |flag: &str| -> Result<Option<String>> {
        Ok(match m.str(flag)? {
            "" => None,
            p => Some(p.to_string()),
        })
    };
    Ok(OutputSpec {
        momax_pgm: opt("momax-pgm")?,
        result_json: opt("result-json")?,
        timings: m.flag("timings"),
        record: false,
    })
}

/// Build an [`AnalysisRequest`] from parsed `bfast run` matches.
pub fn run_request_from_matches(m: &Matches) -> Result<AnalysisRequest> {
    let pixel_range = parse_pixel_range(m.str("pixels")?)?;
    let mut outputs = outputs_from_matches(m)?;
    outputs.record = !m.str("record")?.is_empty();
    Ok(AnalysisRequest {
        source: SceneSource::Path(m.str("input")?.to_string()),
        params: param_spec_from_matches(m)?,
        engine: EngineSpec::from_flags(
            m.str("engine")?,
            m.str("artifacts")?,
            m.str("artifact")?,
        )?,
        chunking: ChunkSpec {
            queue_depth: m.usize("queue-depth")?,
            staging_threads: m.usize("staging-threads")?,
            phased: m.flag("phased"),
            fill_missing: true,
            m_chunk: match m.usize("m-chunk")? {
                0 => None,
                n => Some(n),
            },
            autotune: !m.flag("no-autotune"),
            pixel_range,
        },
        outputs,
        request_id: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::ArtificialDataset;

    fn small_stack(m: usize, seed: u64) -> TimeStack {
        let params = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap();
        ArtificialDataset::new(params, m, seed).generate().stack
    }

    #[test]
    fn cancel_token_and_handle() {
        let h = JobHandle::new();
        assert!(!h.is_cancelled());
        assert_eq!(h.progress(), (0, 0));
        h.set_progress(3, 10);
        let h2 = h.clone();
        assert_eq!(h2.progress(), (3, 10));
        h2.cancel();
        assert!(h.is_cancelled() && h.cancel_token().is_cancelled());
        assert!(is_cancelled(&cancelled()));
        assert!(!is_cancelled(&err!("something else")));
    }

    #[test]
    fn param_spec_resolves_and_roundtrips() {
        let spec = ParamSpec { n_hist: 36, h: 12, k: 1, freq: 12.0, ..Default::default() };
        let p = spec.resolve(48).unwrap();
        assert_eq!((p.n_total, p.n_hist, p.h, p.k), (48, 36, 12, 1));
        assert!(p.lambda > 0.0);
        // pinned λ reproduces exactly
        let pinned = ParamSpec::from_params(&p);
        assert_eq!(pinned.resolve(48).unwrap(), p);
        // pinned N guards against the wrong scene
        assert!(pinned.resolve(50).is_err());
        // JSON round-trip
        let back = ParamSpec::from_json(&pinned.to_json()).unwrap();
        assert_eq!(back, pinned);
        // defaults fill absent fields
        let d = ParamSpec::from_json(&crate::json::parse("{}").unwrap()).unwrap();
        assert_eq!(d, ParamSpec::default());
    }

    #[test]
    fn nan_bearing_params_survive_the_wire() {
        // a NaN λ must round-trip through JSON bit-for-bit (the
        // request stays serialisable even when it will fail to resolve)
        let spec = ParamSpec { lambda: Some(f64::NAN), ..Default::default() };
        let text = spec.to_json().to_string_compact();
        let back = ParamSpec::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert!(back.lambda.unwrap().is_nan());
        assert!(back.resolve(200).is_err()); // NaN λ is not a valid critical value
    }

    #[test]
    fn engine_and_chunk_specs_roundtrip() {
        let engines = [
            EngineSpec::Device { artifacts: "arts".into(), artifact: Some("small".into()) },
            EngineSpec::Device { artifacts: "arts".into(), artifact: None },
            EngineSpec::Emulated,
            EngineSpec::Cmd,
            EngineSpec::Cpu,
            EngineSpec::Direct,
            EngineSpec::Naive,
        ];
        for e in engines {
            let back = EngineSpec::from_json(&e.to_json()).unwrap();
            assert_eq!(back, e);
        }
        assert!(EngineSpec::from_flags("quantum", "a", "").is_err());

        let c = ChunkSpec {
            pixel_range: Some((4, 9)),
            queue_depth: 3,
            m_chunk: Some(301),
            autotune: false,
            ..Default::default()
        };
        let back = ChunkSpec::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        let d = ChunkSpec::from_json(&crate::json::parse("{}").unwrap()).unwrap();
        assert_eq!(d, ChunkSpec::default());
    }

    #[test]
    fn zero_m_chunk_is_a_typed_validation_error_at_submit() {
        let mut req = AnalysisRequest::new(SceneSource::Inline(small_stack(4, 1)));
        req.params = ParamSpec { n_hist: 24, h: 8, k: 1, freq: 12.0, ..Default::default() };
        assert!(req.validate().is_ok());
        req.chunking.m_chunk = Some(0);
        let err = req.validate().unwrap_err();
        assert!(is_invalid(&err), "{err:#}");
        req.chunking.m_chunk = Some(16);
        assert!(req.validate().is_ok());
    }

    #[test]
    fn analysis_request_roundtrips_with_nan_scene() {
        let mut stack = small_stack(6, 7);
        stack.data_mut()[3] = f32::NAN; // wire must preserve missing obs
        let scene_bytes = rio::stack_to_bytes(&stack);
        let mut req = AnalysisRequest::new(SceneSource::Inline(stack));
        req.params.n_hist = 24;
        req.params.h = 8;
        req.params.k = 1;
        req.params.freq = 12.0;
        req.chunking.pixel_range = Some((1, 5));
        req.outputs.momax_pgm = Some("x.pgm".into());
        let text = req.to_json_string();
        let back = AnalysisRequest::from_json_str(&text).unwrap();
        assert_eq!(back.params, req.params);
        assert_eq!(back.engine, req.engine);
        assert_eq!(back.chunking, req.chunking);
        assert_eq!(back.outputs, req.outputs);
        match &back.source {
            SceneSource::Inline(s) => {
                assert_eq!(rio::stack_to_bytes(s), scene_bytes, "scene bytes must be bit-exact");
            }
            other => panic!("expected inline source, got {other:?}"),
        }
        // and the round-trip is a fixed point
        assert_eq!(back.to_json_string(), text);
    }

    #[test]
    fn pixel_range_resolve_slices_and_validates() {
        let stack = small_stack(10, 3);
        let mut req = AnalysisRequest::new(SceneSource::Inline(stack.clone()));
        req.params = ParamSpec {
            n_hist: 24,
            h: 8,
            k: 1,
            freq: 12.0,
            ..Default::default()
        };
        req.chunking.pixel_range = Some((2, 7));
        let (sliced, params) = req.resolve().unwrap();
        assert_eq!(sliced.n_pixels(), 5);
        assert_eq!(params.n_total, 40);
        for p in 0..5 {
            assert_eq!(sliced.series(p), stack.series(2 + p));
        }
        req.chunking.pixel_range = Some((7, 11));
        assert!(req.resolve().is_err());
        req.chunking.pixel_range = Some((4, 4));
        assert!(req.resolve().is_err());
    }

    #[test]
    fn slice_request_body_matches_the_typed_serialisation() {
        let stack = small_stack(9, 5);
        let params = ParamSpec { n_hist: 24, h: 8, k: 1, freq: 12.0, ..Default::default() };
        let engine = EngineSpec::Emulated;
        let chunking = ChunkSpec {
            queue_depth: 3,
            // an inherited range must be cleared — the slice applies it
            pixel_range: Some((0, 4)),
            ..Default::default()
        };
        for rid in [None, Some("req-\"quoted\"-1")] {
            let body = slice_request_body(&stack, (2, 7), &params, &engine, &chunking, rid);
            let mut sub_chunking = chunking.clone();
            sub_chunking.pixel_range = None;
            let sub = AnalysisRequest {
                source: SceneSource::Inline(stack.slice_pixels(2, 7)),
                params: params.clone(),
                engine: engine.clone(),
                chunking: sub_chunking,
                outputs: OutputSpec::default(),
                request_id: rid.map(str::to_string),
            };
            assert_eq!(body, sub.to_json_string(), "request_id = {rid:?}");
        }
    }

    #[test]
    fn digests_key_on_scene_and_result_relevant_fields() {
        let stack = small_stack(6, 7);
        let mut req = AnalysisRequest::new(SceneSource::Inline(stack.clone()));
        req.params = ParamSpec { n_hist: 24, h: 8, k: 1, freq: 12.0, ..Default::default() };
        let scene = req.scene_digest().unwrap();
        assert_eq!(scene, crate::store::hash::sha256_hex(&rio::stack_to_bytes(&stack)));
        let d0 = req.request_digest().unwrap();
        assert_eq!(d0.len(), 64);
        // engine, chunking perf knobs, outputs, request_id: excluded
        let mut same = req.clone();
        same.engine = EngineSpec::Cpu;
        same.chunking.queue_depth = 7;
        same.outputs.timings = true;
        same.request_id = Some("rid".into());
        assert_eq!(same.request_digest().unwrap(), d0);
        // params and pixel_range: included
        let mut other = req.clone();
        other.params.h = 9;
        assert_ne!(other.request_digest().unwrap(), d0);
        let mut ranged = req.clone();
        ranged.chunking.pixel_range = Some((0, 3));
        assert_ne!(ranged.request_digest().unwrap(), d0);
        // a path source hashes the file bytes — same digest as inline
        let path = std::env::temp_dir()
            .join(format!("bfast_api_digest_{}.bsq", std::process::id()));
        rio::write_stack(&path, &stack).unwrap();
        let preq = AnalysisRequest::new(SceneSource::Path(path.display().to_string()));
        assert_eq!(preq.scene_digest().unwrap(), scene);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn session_requests_roundtrip() {
        let stack = small_stack(5, 11);
        let init = SessionInit {
            source: SceneSource::Inline(stack),
            params: ParamSpec { n_hist: 24, h: 8, k: 1, freq: 12.0, ..Default::default() },
            init_layers: 30,
        };
        let v = SessionRequest::Init(init.clone()).to_json();
        match SessionRequest::from_json(&v).unwrap() {
            SessionRequest::Init(back) => {
                assert_eq!(back.init_layers, 30);
                assert_eq!(back.params, init.params);
            }
            other => panic!("expected init, got {other:?}"),
        }

        let ing = SessionIngest { t: 41.5, values: vec![1.0, f32::NAN, -0.5] };
        let v = SessionRequest::Ingest(ing.clone()).to_json();
        match SessionRequest::from_json(&v).unwrap() {
            SessionRequest::Ingest(back) => {
                assert_eq!(back.t, 41.5);
                assert_eq!(back.values.len(), 3);
                for (a, b) in back.values.iter().zip(&ing.values) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("expected ingest, got {other:?}"),
        }
        assert!(SessionRequest::from_json(&Value::obj(vec![(
            "kind",
            Value::Str("reset".into())
        )]))
        .is_err());
    }

    #[test]
    fn cli_flags_build_the_same_request_as_the_library() {
        let args: Vec<String> = [
            "--input", "scene.bsq", "--engine", "emulated", "--n-total", "48", "--n-hist",
            "36", "--h", "12", "--k", "1", "--freq", "12", "--pixels", "3:9", "--m-chunk",
            "301", "--no-autotune", "--record", "run.bcmd",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let req = run_request_from_args(&args).unwrap();
        match &req.source {
            SceneSource::Path(p) => assert_eq!(p, "scene.bsq"),
            other => panic!("expected path source, got {other:?}"),
        }
        assert_eq!(req.engine, EngineSpec::Emulated);
        assert_eq!(req.params.n_total, Some(48));
        assert_eq!(req.chunking.pixel_range, Some((3, 9)));
        assert_eq!(req.chunking.m_chunk, Some(301));
        assert!(!req.chunking.autotune);
        assert!(req.outputs.record);
        // malformed pixel ranges are rejected at parse time
        let bad: Vec<String> =
            ["--input", "s.bsq", "--pixels", "oops"].iter().map(|s| s.to_string()).collect();
        assert!(run_request_from_args(&bad).is_err());
    }
}
