//! The **response half** of the front door: what an executed
//! [`AnalysisRequest`](super::AnalysisRequest) returns, in a form that
//! travels as well as the request does.
//!
//! * [`AnalysisResult`] — the one result type every entry point hands
//!   back: library execute, `bfast run`, `GET /v1/runs/{id}/result`
//!   and `bfast client result`. Like the request, it has a canonical
//!   versioned JSON wire form ([`AnalysisResult::to_json`]), so a
//!   result can be stored, forwarded, diffed, or reassembled from
//!   shards without loss.
//! * [`PartialResult`] — one shard's result tagged with the pixel
//!   range it covers. [`PartialResult::merge`] is **associative**:
//!   adjacent shards combine in any grouping, and
//!   [`PartialResult::assemble`] folds a whole fan-out back into the
//!   full-scene result **bit-exactly** (pinned by `tests/shard.rs`).
//!
//! ## v1 wire schema
//!
//! ```json
//! {
//!   "v": 1,
//!   "pixels": 150,
//!   "width": 10, "height": 15,
//!   "params":  {"n_total": 48, "n_hist": 36, "h": 12, "k": 1,
//!               "freq": 12, "alpha": 0.05, "lambda": 3.0},
//!   "engine":   "emulated (threadpool)",
//!   "artifact": "emulated-auto",
//!   "chunks":   3,
//!   "wall_ns":  123456789,
//!   "phases":   {"create model": 1200300, "mosum": 450600},
//!   "map": {
//!     "breaks_b64": "<base64 .bten i32[pixels]>",
//!     "first_b64":  "<base64 .bten i32[pixels]>",
//!     "momax_b64":  "<base64 .bten f32[pixels]>"
//!   }
//! }
//! ```
//!
//! `width`/`height` and `phases` are optional; `params` is the pinned
//! form (every field present, λ resolved) so a parsed result carries
//! the exact parameters the run used. The break map rides as three
//! base64 `.bten` tensors — a **lossless binary payload** (f32 `momax`
//! round-trips bit-for-bit, NaNs included), unlike the float-array
//! sugar of `GET .../map`. Durations are integer nanoseconds so
//! serialize → parse → serialize is byte-identical. A
//! [`PartialResult`] wraps the same envelope as
//! `{"v": 1, "pixel_range": [a, b], "result": {...}}`.

use super::ParamSpec;
use crate::b64::{base64_decode, base64_encode};
use crate::error::{bail, ensure, Context, Result};
use crate::json::Value;
use crate::metrics::PhaseTimes;
use crate::params::BfastParams;
use crate::raster::BreakMap;
use crate::runtime::bten::{bten_from_bytes, bten_to_bytes, Tensor};
use std::time::Duration;

/// What an executed [`AnalysisRequest`](super::AnalysisRequest)
/// returns, whichever front door it entered through. See the module
/// docs for the canonical v1 JSON wire form.
#[derive(Clone, Debug)]
pub struct AnalysisResult {
    pub map: BreakMap,
    /// The concrete parameters the run used (λ resolved).
    pub params: BfastParams,
    /// Phase breakdown (engines that instrument one).
    pub phases: Option<PhaseTimes>,
    pub chunks: usize,
    pub artifact: String,
    /// Executing backend description.
    pub engine: String,
    pub wall: Duration,
    /// Scene geometry, when the (unsliced) scene carried one.
    pub width: Option<usize>,
    pub height: Option<usize>,
}

/// One break-map field as a base64 `.bten` tensor (1-D, so the shape
/// always matches and encoding cannot fail).
fn tensor_b64(t: Tensor) -> Value {
    Value::Str(base64_encode(
        &bten_to_bytes(&t).expect("1-D map tensor is always encodable"),
    ))
}

fn map_to_json(map: &BreakMap) -> Value {
    Value::obj(vec![
        (
            "breaks_b64",
            tensor_b64(Tensor::I32 { shape: vec![map.breaks.len()], data: map.breaks.clone() }),
        ),
        (
            "first_b64",
            tensor_b64(Tensor::I32 { shape: vec![map.first.len()], data: map.first.clone() }),
        ),
        (
            "momax_b64",
            tensor_b64(Tensor::F32 { shape: vec![map.momax.len()], data: map.momax.clone() }),
        ),
    ])
}

fn map_from_json(v: &Value) -> Result<BreakMap> {
    let tensor = |key: &str| -> Result<Tensor> {
        let bytes = base64_decode(v.get(key)?.as_str()?)?;
        bten_from_bytes(&bytes, key)
    };
    let i32_field = |key: &str| -> Result<Vec<i32>> {
        match tensor(key)? {
            Tensor::I32 { data, .. } => Ok(data),
            other => bail!("{key} must be an i32 tensor (got shape {:?})", other.shape()),
        }
    };
    let momax = match tensor("momax_b64")? {
        Tensor::F32 { data, .. } => data,
        other => bail!("momax_b64 must be an f32 tensor (got shape {:?})", other.shape()),
    };
    let map = BreakMap { breaks: i32_field("breaks_b64")?, first: i32_field("first_b64")?, momax };
    ensure!(
        map.breaks.len() == map.first.len() && map.first.len() == map.momax.len(),
        "map fields disagree on pixel count ({} / {} / {})",
        map.breaks.len(),
        map.first.len(),
        map.momax.len()
    );
    Ok(map)
}

impl AnalysisResult {
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("v", Value::Num(1.0)),
            ("pixels", Value::Num(self.map.len() as f64)),
        ];
        if let (Some(w), Some(h)) = (self.width, self.height) {
            fields.push(("width", Value::Num(w as f64)));
            fields.push(("height", Value::Num(h as f64)));
        }
        fields.push(("params", ParamSpec::from_params(&self.params).to_json()));
        fields.push(("engine", Value::Str(self.engine.clone())));
        fields.push(("artifact", Value::Str(self.artifact.clone())));
        fields.push(("chunks", Value::Num(self.chunks as f64)));
        fields.push(("wall_ns", Value::Num(self.wall.as_nanos() as f64)));
        if let Some(p) = &self.phases {
            fields.push(("phases", p.to_json()));
        }
        fields.push(("map", map_to_json(&self.map)));
        Value::obj(fields)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        if let Some(ver) = v.try_get("v") {
            let ver = ver.as_usize().context("field \"v\"")?;
            ensure!(ver == 1, "unsupported result version {ver} (this build speaks v1)");
        }
        let spec = ParamSpec::from_json(v.get("params").context("analysis result")?)?;
        let n_total = spec.n_total.context("result params must pin n_total")?;
        let params = spec.resolve(n_total)?;
        let map = map_from_json(v.get("map").context("analysis result")?)?;
        let pixels = super::get_usize_or(v, "pixels", map.len())?;
        ensure!(
            pixels == map.len(),
            "result claims {pixels} pixels but the map holds {}",
            map.len()
        );
        let dim = |key: &str| -> Result<Option<usize>> {
            match v.try_get(key) {
                None | Some(Value::Null) => Ok(None),
                Some(x) => Ok(Some(x.as_usize().with_context(|| format!("field {key:?}"))?)),
            }
        };
        let wall_ns = v.get("wall_ns").context("analysis result")?.as_f64()?;
        ensure!(
            wall_ns.is_finite() && wall_ns >= 0.0,
            "wall_ns must be a non-negative duration, got {wall_ns}"
        );
        Ok(Self {
            map,
            params,
            phases: match v.try_get("phases") {
                None | Some(Value::Null) => None,
                Some(x) => Some(PhaseTimes::from_json(x)?),
            },
            chunks: super::get_usize_or(v, "chunks", 0)?,
            artifact: v.get("artifact")?.as_str()?.to_string(),
            engine: v.get("engine")?.as_str()?.to_string(),
            wall: Duration::from_nanos(wall_ns as u64),
            width: dim("width")?,
            height: dim("height")?,
        })
    }

    /// Compact JSON — the exact bytes `GET /v1/runs/{id}/result`
    /// serves.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn from_json_str(text: &str) -> Result<Self> {
        Self::from_json(&crate::json::parse(text)?)
    }
}

/// One shard's result: an [`AnalysisResult`] over the pixel slice
/// `[pixel_range.0, pixel_range.1)` of the full scene. Produced by the
/// [`shard`](crate::shard) coordinator (which knows each sub-request's
/// range) and folded back together with [`PartialResult::merge`] /
/// [`PartialResult::assemble`].
#[derive(Clone, Debug)]
pub struct PartialResult {
    /// The pixel range this shard covers, in full-scene coordinates.
    pub pixel_range: (usize, usize),
    pub result: AnalysisResult,
}

impl PartialResult {
    /// Wrap one shard's result; the map must be exactly as wide as the
    /// range it claims to cover.
    pub fn new(pixel_range: (usize, usize), result: AnalysisResult) -> Result<Self> {
        let (start, end) = pixel_range;
        ensure!(start < end, "shard pixel_range [{start}, {end}) is empty");
        ensure!(
            result.map.len() == end - start,
            "shard map holds {} pixels but claims the range [{start}, {end})",
            result.map.len()
        );
        Ok(Self { pixel_range, result })
    }

    /// Combine with the shard immediately to the right. This operation
    /// is **associative** — `(a ⊕ b) ⊕ c` equals `a ⊕ (b ⊕ c)` — so an
    /// assembler may fold shard results in any grouping as they
    /// arrive. Map fields concatenate (bit-exact), `chunks` add,
    /// `wall` takes the max (shards run in parallel), phase times
    /// accumulate, and both shards must have been analysed under
    /// identical resolved parameters.
    pub fn merge(self, other: PartialResult) -> Result<PartialResult> {
        ensure!(
            self.pixel_range.1 == other.pixel_range.0,
            "shards [{}, {}) and [{}, {}) are not adjacent",
            self.pixel_range.0,
            self.pixel_range.1,
            other.pixel_range.0,
            other.pixel_range.1
        );
        ensure!(
            self.result.params == other.result.params,
            "shards were analysed under different parameters"
        );
        let mut r = self.result;
        let o = other.result;
        r.map.breaks.extend_from_slice(&o.map.breaks);
        r.map.first.extend_from_slice(&o.map.first);
        r.map.momax.extend_from_slice(&o.map.momax);
        r.chunks += o.chunks;
        r.wall = r.wall.max(o.wall);
        r.phases = match (r.phases, o.phases) {
            (Some(mut a), Some(b)) => {
                a.merge(&b);
                Some(a)
            }
            (a, b) => a.or(b),
        };
        if r.engine != o.engine {
            r.engine = format!("{} + {}", r.engine, o.engine);
        }
        if r.artifact != o.artifact {
            r.artifact = format!("{} + {}", r.artifact, o.artifact);
        }
        // a pixel strip of a scene has no rectangular geometry of its
        // own; the coordinator reattaches it once the scene is whole
        r.width = None;
        r.height = None;
        Ok(PartialResult {
            pixel_range: (self.pixel_range.0, other.pixel_range.1),
            result: r,
        })
    }

    /// Fold a whole fan-out back together: sort by range start, then
    /// [`merge`](PartialResult::merge) left to right (any grouping
    /// would give the same bits — merge is associative). Errors if the
    /// ranges leave a gap or overlap.
    pub fn assemble(parts: Vec<PartialResult>) -> Result<PartialResult> {
        ensure!(!parts.is_empty(), "no shard results to assemble");
        let mut parts = parts;
        parts.sort_by_key(|p| p.pixel_range.0);
        let mut iter = parts.into_iter();
        let mut acc = iter.next().expect("non-empty");
        for p in iter {
            acc = acc.merge(p)?;
        }
        Ok(acc)
    }

    /// Finish assembly into the full-scene result: the merged range
    /// must cover `[0, pixels)` exactly; scene geometry (dropped while
    /// merging strips) is reattached.
    pub fn into_full(
        self,
        pixels: usize,
        width: Option<usize>,
        height: Option<usize>,
    ) -> Result<AnalysisResult> {
        ensure!(
            self.pixel_range == (0, pixels),
            "assembled shards cover [{}, {}) of a {pixels}-pixel scene",
            self.pixel_range.0,
            self.pixel_range.1
        );
        let mut r = self.result;
        r.width = width;
        r.height = height;
        Ok(r)
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("v", Value::Num(1.0)),
            (
                "pixel_range",
                Value::arr_usize(&[self.pixel_range.0, self.pixel_range.1]),
            ),
            ("result", self.result.to_json()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let arr = v.get("pixel_range")?.as_arr().context("field \"pixel_range\"")?;
        ensure!(arr.len() == 2, "pixel_range must be [start, end]");
        Self::new(
            (arr[0].as_usize()?, arr[1].as_usize()?),
            AnalysisResult::from_json(v.get("result").context("partial result")?)?,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(pixels: usize, seed: u32) -> AnalysisResult {
        let mut map = BreakMap::zeros(pixels);
        for p in 0..pixels {
            map.breaks[p] = ((p as u32 + seed) % 3 == 0) as i32;
            map.first[p] = if map.breaks[p] != 0 { p as i32 } else { -1 };
            map.momax[p] = (p as f32 + seed as f32) * 0.25;
        }
        let mut phases = PhaseTimes::new();
        phases.add("mosum", Duration::from_nanos(1000 + seed as u64));
        AnalysisResult {
            map,
            params: BfastParams::with_lambda(48, 36, 12, 1, 12.0, 0.05, 3.0).unwrap(),
            phases: Some(phases),
            chunks: 2,
            artifact: "emulated-auto".into(),
            engine: "emulated (threadpool)".into(),
            wall: Duration::from_nanos(5_000_123),
            width: None,
            height: None,
        }
    }

    #[test]
    fn result_json_is_a_fixed_point_including_nan_momax() {
        let mut res = result(7, 1);
        res.map.momax[3] = f32::NAN; // dead pixel: momax must survive bitwise
        res.width = Some(7);
        res.height = Some(1);
        let text = res.to_json_string();
        let back = AnalysisResult::from_json_str(&text).unwrap();
        assert_eq!(back.map.breaks, res.map.breaks);
        assert_eq!(back.map.first, res.map.first);
        for (a, b) in back.map.momax.iter().zip(&res.map.momax) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.params, res.params);
        assert_eq!(back.wall, res.wall);
        assert_eq!((back.width, back.height), (Some(7), Some(1)));
        assert_eq!(back.to_json_string(), text);
    }

    #[test]
    fn result_json_rejects_inconsistent_documents() {
        let res = result(4, 0);
        let good = res.to_json_string();
        // wrong version
        let bad = good.replacen("\"v\":1", "\"v\":2", 1);
        assert!(AnalysisResult::from_json_str(&bad).is_err());
        // pixels disagreeing with the map payload
        let bad = good.replacen("\"pixels\":4", "\"pixels\":5", 1);
        assert!(AnalysisResult::from_json_str(&bad).is_err());
        // params without a pinned n_total cannot resolve
        let bad = good.replacen("\"n_total\":48,", "", 1);
        assert!(AnalysisResult::from_json_str(&bad).is_err());
    }

    #[test]
    fn merge_concatenates_and_is_associative() {
        let a = PartialResult::new((0, 7), result(7, 1)).unwrap();
        let b = PartialResult::new((7, 8), result(1, 2)).unwrap();
        let c = PartialResult::new((8, 12), result(4, 3)).unwrap();
        let left = a.clone().merge(b.clone()).unwrap().merge(c.clone()).unwrap();
        let right = a.clone().merge(b.clone().merge(c.clone()).unwrap()).unwrap();
        assert_eq!(left.pixel_range, (0, 12));
        assert_eq!(left.to_json().to_string_compact(), right.to_json().to_string_compact());
        // assembly accepts any order and reproduces the same bits
        let assembled = PartialResult::assemble(vec![c, a, b]).unwrap();
        assert_eq!(
            assembled.to_json().to_string_compact(),
            left.to_json().to_string_compact()
        );
        let full = assembled.into_full(12, Some(4), Some(3)).unwrap();
        assert_eq!((full.width, full.height), (Some(4), Some(3)));
        assert_eq!(full.chunks, 6);
    }

    #[test]
    fn merge_rejects_gaps_overlaps_and_mismatched_params() {
        let a = PartialResult::new((0, 4), result(4, 1)).unwrap();
        let gap = PartialResult::new((5, 8), result(3, 1)).unwrap();
        assert!(a.clone().merge(gap).is_err());
        let overlap = PartialResult::new((3, 8), result(5, 1)).unwrap();
        assert!(a.clone().merge(overlap).is_err());
        let mut other = result(3, 1);
        other.params = BfastParams::with_lambda(48, 36, 12, 1, 12.0, 0.05, 4.0).unwrap();
        let mismatched = PartialResult::new((4, 7), other).unwrap();
        assert!(a.clone().merge(mismatched).is_err());
        // wrong-width maps and empty ranges are refused at construction
        assert!(PartialResult::new((0, 3), result(4, 1)).is_err());
        assert!(PartialResult::new((2, 2), result(0, 1)).is_err());
        assert!(PartialResult::assemble(vec![]).is_err());
        // incomplete coverage cannot become a full result
        assert!(a.into_full(8, None, None).is_err());
    }

    #[test]
    fn partial_result_json_roundtrips() {
        let p = PartialResult::new((3, 10), result(7, 5)).unwrap();
        let text = p.to_json().to_string_compact();
        let back = PartialResult::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.pixel_range, (3, 10));
        assert_eq!(back.to_json().to_string_compact(), text);
    }
}
