//! Command-line parsing substrate (replaces `clap` for the offline
//! build). Declarative flag specs with typed getters, auto-generated
//! `--help`, and subcommand dispatch in `main.rs`.

use crate::error::{bail, err, Result};
use std::collections::BTreeMap;

/// One flag specification.
#[derive(Clone, Debug)]
pub struct Flag {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub takes_value: bool,
}

/// A declarative command: name, about text, flags.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    flags: Vec<Flag>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, flags: Vec::new() }
    }

    /// Value flag with a default (`--chunk 16384`).
    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default: Some(default), takes_value: true });
        self
    }

    /// Required value flag.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default: None, takes_value: true });
        self
    }

    /// Boolean switch (`--verbose`).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default: None, takes_value: false });
        self
    }

    /// Render help text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nFLAGS:\n", self.name, self.about);
        for f in &self.flags {
            let head = if f.takes_value {
                format!("  --{} <value>", f.name)
            } else {
                format!("  --{}", f.name)
            };
            s.push_str(&format!("{head:<26} {}", f.help));
            if let Some(d) = f.default {
                s.push_str(&format!(" [default: {d}]"));
            }
            s.push('\n');
        }
        s
    }

    /// Parse raw args (without the program/subcommand names).
    pub fn parse(&self, args: &[String]) -> Result<Matches> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut switches: BTreeMap<String, bool> = BTreeMap::new();
        let mut positional = Vec::new();
        for f in &self.flags {
            if f.takes_value {
                if let Some(d) = f.default {
                    values.insert(f.name.to_string(), d.to_string());
                }
            } else {
                switches.insert(f.name.to_string(), false);
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(name) = a.strip_prefix("--") {
                // --name=value form
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| err!("unknown flag --{name}\n\n{}", self.usage()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| err!("--{name} needs a value"))?
                        }
                    };
                    values.insert(name.to_string(), v);
                } else {
                    if inline.is_some() {
                        bail!("switch --{name} takes no value");
                    }
                    switches.insert(name.to_string(), true);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        // required flags present?
        for f in &self.flags {
            if f.takes_value && f.default.is_none() && !values.contains_key(f.name) {
                bail!("missing required flag --{}\n\n{}", f.name, self.usage());
            }
        }
        Ok(Matches { values, switches, positional })
    }
}

/// Parsed arguments with typed access.
#[derive(Debug)]
pub struct Matches {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Matches {
    pub fn str(&self, name: &str) -> Result<&str> {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .ok_or_else(|| err!("flag --{name} not set"))
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        let s = self.str(name)?;
        s.parse().map_err(|_| err!("--{name}: expected integer, got {s:?}"))
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        let s = self.str(name)?;
        s.parse().map_err(|_| err!("--{name}: expected integer, got {s:?}"))
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        let s = self.str(name)?;
        s.parse().map_err(|_| err!("--{name}: expected number, got {s:?}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }

    /// Comma-separated list of integers ("25,50,100").
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>> {
        self.str(name)?
            .split(',')
            .map(|p| {
                p.trim()
                    .parse()
                    .map_err(|_| err!("--{name}: bad list element {p:?}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("run", "run break detection")
            .opt("chunk", "16384", "pixels per chunk")
            .opt("alpha", "0.05", "significance level")
            .req("input", "input stack path")
            .switch("verbose", "log progress")
    }

    fn parse(args: &[&str]) -> Result<Matches> {
        cmd().parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn defaults_and_overrides() {
        let m = parse(&["--input", "x.bsq"]).unwrap();
        assert_eq!(m.usize("chunk").unwrap(), 16384);
        assert_eq!(m.f64("alpha").unwrap(), 0.05);
        assert!(!m.flag("verbose"));
        let m = parse(&["--input=x.bsq", "--chunk=512", "--verbose"]).unwrap();
        assert_eq!(m.usize("chunk").unwrap(), 512);
        assert!(m.flag("verbose"));
    }

    #[test]
    fn missing_required_fails() {
        assert!(parse(&["--chunk", "2"]).is_err());
    }

    #[test]
    fn unknown_flag_fails_with_usage() {
        let err = parse(&["--input", "x", "--bogus"]).unwrap_err().to_string();
        assert!(err.contains("unknown flag --bogus"));
        assert!(err.contains("FLAGS:"));
    }

    #[test]
    fn positional_and_lists() {
        let c = Command::new("t", "").opt("hs", "25,50", "h values");
        let m = c
            .parse(&["pos1".into(), "--hs".into(), "25,50,100".into()])
            .unwrap();
        assert_eq!(m.positional, vec!["pos1"]);
        assert_eq!(m.usize_list("hs").unwrap(), vec![25, 50, 100]);
    }

    #[test]
    fn type_errors_are_caught() {
        let m = parse(&["--input", "x", "--chunk", "abc"]).unwrap();
        assert!(m.usize("chunk").is_err());
    }

    #[test]
    fn switch_rejects_value() {
        assert!(parse(&["--input", "x", "--verbose=1"]).is_err());
    }
}
