//! Artificial benchmark data (paper §4.2, Eq. 12):
//!
//! `y_t = 0.05 · sin(2πt/f) + ε_t + c`
//!
//! where ε_t is small Gaussian noise and `c` is a constant added to
//! the last 40 % of the series for the half of the pixels that should
//! exhibit a break.

use crate::params::BfastParams;
use crate::prng::{Normal, Pcg32};
use crate::raster::TimeStack;
use crate::threadpool::{self, SyncSlice};

/// Generator configuration + output labels.
#[derive(Clone, Debug)]
pub struct ArtificialDataset {
    pub params: BfastParams,
    pub m: usize,
    pub seed: u64,
    /// Amplitude of the seasonal sinus (paper: 0.05).
    pub amplitude: f64,
    /// Noise standard deviation.
    pub noise_sd: f64,
    /// Break constant `c` (paper adds a visible constant).
    pub break_shift: f64,
    /// Fraction of the series length that carries the break (paper: 0.4).
    pub break_tail: f64,
}

/// Generated stack plus per-pixel ground truth.
pub struct GeneratedData {
    pub stack: TimeStack,
    /// true where the generator injected a break (every 2nd pixel).
    pub truth: Vec<bool>,
}

impl ArtificialDataset {
    pub fn new(params: BfastParams, m: usize, seed: u64) -> Self {
        Self {
            params,
            m,
            seed,
            amplitude: 0.05,
            noise_sd: 0.01,
            break_shift: 0.1,
            break_tail: 0.4,
        }
    }

    /// Stronger breaks / noise for detection-quality tests.
    pub fn with_noise(mut self, noise_sd: f64, break_shift: f64) -> Self {
        self.noise_sd = noise_sd;
        self.break_shift = break_shift;
        self
    }

    /// Generate the stack (parallel over pixels, deterministic in the
    /// seed regardless of thread count).
    pub fn generate(&self) -> GeneratedData {
        let n = self.params.n_total;
        let m = self.m;
        let f = self.params.freq;
        let break_from = ((1.0 - self.break_tail) * n as f64).floor() as usize;
        // seasonal component shared by every pixel
        let season: Vec<f64> = (1..=n)
            .map(|t| self.amplitude * (2.0 * std::f64::consts::PI * t as f64 / f).sin())
            .collect();
        let mut stack = TimeStack::zeros(n, m);
        {
            let data = SyncSlice::new(stack.data_mut());
            let threads = threadpool::default_threads();
            threadpool::parallel_ranges(m, 4096, threads, |s, e| {
                for px in s..e {
                    let mut nrm =
                        Normal::new(Pcg32::with_stream(self.seed, px as u64));
                    let has_break = px % 2 == 0;
                    for (t, &sv) in season.iter().enumerate() {
                        let mut v = sv + self.noise_sd * nrm.sample();
                        if has_break && t >= break_from {
                            v += self.break_shift;
                        }
                        unsafe { data.write(t * m + px, v as f32) };
                    }
                }
            });
        }
        let truth = (0..m).map(|px| px % 2 == 0).collect();
        GeneratedData { stack, truth }
    }
}

impl ArtificialDataset {
    /// Stream the same dataset one acquisition layer at a time — the
    /// near-real-time shape a monitoring session consumes. Layer `t`
    /// of the stream is bit-identical to row `t` of
    /// [`ArtificialDataset::generate`]'s stack (each pixel draws from
    /// the same per-pixel PRNG stream, in the same order), so an
    /// ingest-driven analysis can be checked against the batch one.
    pub fn stream(&self) -> LayerStream {
        let n = self.params.n_total;
        let f = self.params.freq;
        let season: Vec<f64> = (1..=n)
            .map(|t| self.amplitude * (2.0 * std::f64::consts::PI * t as f64 / f).sin())
            .collect();
        LayerStream {
            rngs: (0..self.m)
                .map(|px| Normal::new(Pcg32::with_stream(self.seed, px as u64)))
                .collect(),
            season,
            break_from: ((1.0 - self.break_tail) * n as f64).floor() as usize,
            noise_sd: self.noise_sd,
            break_shift: self.break_shift,
            t: 0,
        }
    }
}

/// Iterator over `(time, layer)` pairs emitted by
/// [`ArtificialDataset::stream`]; times follow the regular 1..=N axis.
pub struct LayerStream {
    rngs: Vec<Normal>,
    season: Vec<f64>,
    break_from: usize,
    noise_sd: f64,
    break_shift: f64,
    t: usize,
}

impl Iterator for LayerStream {
    type Item = (f64, Vec<f32>);

    fn next(&mut self) -> Option<Self::Item> {
        if self.t >= self.season.len() {
            return None;
        }
        let t = self.t;
        let sv = self.season[t];
        let layer: Vec<f32> = self
            .rngs
            .iter_mut()
            .enumerate()
            .map(|(px, nrm)| {
                let mut v = sv + self.noise_sd * nrm.sample();
                if px % 2 == 0 && t >= self.break_from {
                    v += self.break_shift;
                }
                v as f32
            })
            .collect();
        self.t += 1;
        Some(((t + 1) as f64, layer))
    }
}

impl GeneratedData {
    /// Detection quality against the generator's ground truth.
    pub fn score(&self, breaks: &[i32]) -> (f64, f64) {
        assert_eq!(breaks.len(), self.truth.len());
        let mut tp = 0usize;
        let mut fp = 0usize;
        let (mut pos, mut neg) = (0usize, 0usize);
        for (&b, &t) in breaks.iter().zip(&self.truth) {
            if t {
                pos += 1;
                if b != 0 {
                    tp += 1;
                }
            } else {
                neg += 1;
                if b != 0 {
                    fp += 1;
                }
            }
        }
        let tpr = if pos > 0 { tp as f64 / pos as f64 } else { 1.0 };
        let fpr = if neg > 0 { fp as f64 / neg as f64 } else { 0.0 };
        (tpr, fpr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ArtificialDataset {
        let p = BfastParams::with_lambda(60, 40, 20, 2, 12.0, 0.05, 2.5).unwrap();
        ArtificialDataset::new(p, 64, 123)
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let d = small();
        std::env::set_var("BFAST_THREADS", "1");
        let a = d.generate();
        std::env::set_var("BFAST_THREADS", "7");
        let b = d.generate();
        std::env::remove_var("BFAST_THREADS");
        assert_eq!(a.stack.data(), b.stack.data());
    }

    #[test]
    fn break_pixels_shift_in_tail() {
        let d = small().with_noise(0.001, 0.5);
        let g = d.generate();
        let n = d.params.n_total;
        let break_from = (0.6 * n as f64).floor() as usize;
        // even pixel: tail mean >> head mean; odd pixel: comparable
        let s0 = g.stack.series(0);
        let s1 = g.stack.series(1);
        let mean = |xs: &[f32]| xs.iter().map(|&v| v as f64).sum::<f64>() / xs.len() as f64;
        assert!(mean(&s0[break_from..]) - mean(&s0[..break_from]) > 0.4);
        assert!((mean(&s1[break_from..]) - mean(&s1[..break_from])).abs() < 0.05);
        assert!(g.truth[0] && !g.truth[1]);
    }

    #[test]
    fn seasonal_amplitude_visible() {
        let d = small().with_noise(0.0001, 0.0);
        let g = d.generate();
        let s = g.stack.series(1);
        let max = s.iter().cloned().fold(f32::MIN, f32::max);
        let min = s.iter().cloned().fold(f32::MAX, f32::min);
        assert!((max as f64 - 0.05).abs() < 0.01, "max {max}");
        assert!((min as f64 + 0.05).abs() < 0.01, "min {min}");
    }

    #[test]
    fn stream_matches_batch_generation_bitwise() {
        let d = small();
        let g = d.generate();
        let mut n_layers = 0;
        for (ti, (t, layer)) in d.stream().enumerate() {
            assert_eq!(t, g.stack.time_axis[ti]);
            assert_eq!(layer.len(), d.m);
            for (px, &v) in layer.iter().enumerate() {
                let want = g.stack.layer(ti)[px];
                assert_eq!(
                    v.to_bits(),
                    want.to_bits(),
                    "layer {ti} px {px}: {v} vs {want}"
                );
            }
            n_layers += 1;
        }
        assert_eq!(n_layers, d.params.n_total);
    }

    #[test]
    fn score_computes_rates() {
        let d = small();
        let g = d.generate();
        // flag exactly the truth
        let breaks: Vec<i32> = g.truth.iter().map(|&t| t as i32).collect();
        assert_eq!(g.score(&breaks), (1.0, 0.0));
        let none = vec![0; g.truth.len()];
        assert_eq!(g.score(&none), (0.0, 0.0));
    }
}
