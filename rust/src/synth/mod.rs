//! Synthetic dataset generators.
//!
//! * [`artificial`] — the §4.2 runtime-benchmark generator (Eq. 12):
//!   sinus + noise, a constant added to the last 40 % of half of the
//!   series so they exhibit a break.
//! * [`chile`] — a procedural stand-in for the §4.3 USGS Landsat scene
//!   over the Atacama plantation forest (the real archive is not
//!   available offline; DESIGN.md §4 documents the substitution).

pub mod artificial;
pub mod chile;

pub use artificial::ArtificialDataset;
pub use chile::ChileScene;
