//! Procedural stand-in for the paper's §4.3 Chile dataset.
//!
//! The original is a USGS Landsat Collection-1 NDVI stack (scene
//! P01R74, Atacama desert, 288 acquisitions 2000-01-18 → 2017-08-20,
//! subset 2400×1851 px) — not available offline. This simulator
//! reproduces the statistical structure the paper's analysis depends
//! on:
//!
//! * **irregular acquisition dates** across three sensors (≈16-day
//!   cadence with jitter and dropped scenes), driving the day-of-year
//!   time-axis adaptation of Eq. (1);
//! * **desert background** — low NDVI, weak season, *small* mid-series
//!   level change (the paper observes >99 % of pixels break, deserts
//!   at small magnitude);
//! * **plantation blocks** — high-NDVI patches with strong seasonality
//!   where blocks are harvested (sharp NDVI drop) or planted (rise)
//!   partway through the monitor period — the spotty high-magnitude
//!   regions of Fig. 9;
//! * **cloud/gap noise** — optional NaN dropouts handled by
//!   [`crate::fill`].

use crate::params::BfastParams;
use crate::prng::{Normal, Pcg32};
use crate::raster::TimeStack;
use crate::threadpool::{self, SyncSlice};

/// Scene configuration. Defaults mirror the paper's parameters at a
/// scaled-down geometry (full size: 2400×1851).
#[derive(Clone, Debug)]
pub struct ChileScene {
    pub width: usize,
    pub height: usize,
    pub n_times: usize,
    pub seed: u64,
    /// Fraction of scene area covered by plantation blocks.
    pub forest_fraction: f64,
    /// Probability that an observation is cloud-masked (NaN).
    pub cloud_rate: f64,
}

/// Per-pixel ground truth of the simulated scene.
pub struct ChileTruth {
    /// true for plantation pixels.
    pub is_forest: Vec<bool>,
    /// time index of the injected event per pixel (usize::MAX = none;
    /// desert pixels get a shared low-magnitude event).
    pub event_at: Vec<usize>,
}

impl Default for ChileScene {
    fn default() -> Self {
        Self {
            width: 240,
            height: 186,
            n_times: 288,
            seed: 2017,
            forest_fraction: 0.25,
            cloud_rate: 0.0,
        }
    }
}

impl ChileScene {
    pub fn scaled(width: usize, height: usize, seed: u64) -> Self {
        Self { width, height, seed, ..Self::default() }
    }

    /// The §4.3 analysis parameters: n = 144, h = 72, k = 3, f = 365.
    pub fn params(&self) -> BfastParams {
        BfastParams::new(self.n_times, self.n_times / 2, self.n_times / 4, 3, 365.0, 0.05)
            .expect("chile params valid")
    }

    /// Irregular acquisition-day axis (days since 2000-01-18), three
    /// Landsat sensors with jitter + dropped scenes, spanning ≈17.6 y.
    pub fn time_axis(&self) -> Vec<f64> {
        let mut rng = Pcg32::with_stream(self.seed, 0xDA7E);
        let mut gaps = Vec::with_capacity(self.n_times);
        for _ in 0..self.n_times {
            // 16-day cadence, sometimes a scene is lost (32/48), plus
            // small sensor jitter.
            let base = *rng.choice(&[16.0, 16.0, 16.0, 16.0, 32.0, 48.0]);
            let jitter = rng.uniform_in(-2.0, 2.0);
            gaps.push((base + jitter).max(1.0));
        }
        // rescale so the span matches the real archive (6424 days)
        let total: f64 = gaps.iter().sum();
        let scale = 6424.0 / total;
        let mut t = Vec::with_capacity(self.n_times);
        let mut acc = 18.0; // first scene: 2000-01-18
        for g in gaps {
            t.push(acc);
            acc += g * scale;
        }
        t
    }

    /// Generate the scene stack + truth.
    pub fn generate(&self) -> (TimeStack, ChileTruth) {
        let m = self.width * self.height;
        let n = self.n_times;
        let taxis = self.time_axis();
        let monitor_from = n / 2;

        // --- plantation block layout -----------------------------------
        let mut rng = Pcg32::with_stream(self.seed, 0xB10C);
        let target_area = (self.forest_fraction * m as f64) as usize;
        let mut is_forest = vec![false; m];
        let mut block_of = vec![usize::MAX; m];
        let mut blocks: Vec<(usize, usize, bool)> = Vec::new(); // (event_t, block id, harvest?)
        let mut covered = 0usize;
        while covered < target_area {
            let bw = 4 + rng.below(24) as usize;
            let bh = 4 + rng.below(24) as usize;
            let x0 = rng.below(self.width.saturating_sub(bw).max(1) as u32) as usize;
            let y0 = rng.below(self.height.saturating_sub(bh).max(1) as u32) as usize;
            // each block is harvested or planted at a random monitor time
            let event_t = monitor_from
                + (n / 8)
                + rng.below(((n - monitor_from) / 2) as u32) as usize;
            let harvest = rng.below(2) == 0;
            let id = blocks.len();
            blocks.push((event_t, id, harvest));
            for y in y0..(y0 + bh).min(self.height) {
                for x in x0..(x0 + bw).min(self.width) {
                    let px = y * self.width + x;
                    if !is_forest[px] {
                        is_forest[px] = true;
                        covered += 1;
                    }
                    block_of[px] = id;
                }
            }
        }
        // desert-wide small event (the paper: "the desert areas also
        // experience change, but at a much smaller magnitude")
        let desert_event = monitor_from + n / 4;

        // --- per-pixel series -------------------------------------------
        let mut stack = TimeStack::zeros(n, m)
            .with_time_axis(taxis.clone())
            .expect("axis increasing");
        let mut event_at = vec![usize::MAX; m];
        for (px, ev) in event_at.iter_mut().enumerate() {
            *ev = if is_forest[px] { blocks[block_of[px]].0 } else { desert_event };
        }
        {
            let data = SyncSlice::new(stack.data_mut());
            let threads = threadpool::default_threads();
            let seed = self.seed;
            let cloud = self.cloud_rate;
            let is_forest = &is_forest;
            let block_of = &block_of;
            let blocks = &blocks;
            let taxis = &taxis;
            threadpool::parallel_ranges(m, 2048, threads, |s, e| {
                for px in s..e {
                    let mut nrm = Normal::new(Pcg32::with_stream(seed, 1 + px as u64));
                    let forest = is_forest[px];
                    // baseline NDVI + seasonal amplitude
                    let (base, amp, noise) = if forest {
                        (
                            0.45 + 0.1 * nrm.sample() * 0.3,
                            0.12 + 0.02 * nrm.sample().abs(),
                            0.02,
                        )
                    } else {
                        (0.08 + 0.01 * nrm.sample(), 0.015, 0.008)
                    };
                    let (event_t, harvest) = if forest {
                        let (t, _, hv) = blocks[block_of[px]];
                        (t, hv)
                    } else {
                        (desert_event, false)
                    };
                    for ti in 0..n {
                        let doy = taxis[ti];
                        let season =
                            amp * (2.0 * std::f64::consts::PI * doy / 365.0).sin();
                        let mut v = base + season + noise * nrm.sample();
                        if ti >= event_t {
                            if forest {
                                // harvest: NDVI collapses; plant: ramps up
                                v += if harvest { -0.35 } else { 0.3 };
                            } else {
                                v += 0.02; // small desert change
                            }
                        }
                        if cloud > 0.0 && nrm.rng().uniform() < cloud {
                            v = f64::NAN;
                        }
                        unsafe { data.write(ti * m + px, v as f32) };
                    }
                }
            });
        }
        let stack = stack
            .with_geometry(self.width, self.height)
            .expect("geometry consistent");
        (stack, ChileTruth { is_forest, event_at })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_axis_irregular_increasing_and_spanning() {
        let sc = ChileScene::default();
        let t = sc.time_axis();
        assert_eq!(t.len(), 288);
        assert!(t.windows(2).all(|w| w[1] > w[0]));
        assert!((t[0] - 18.0).abs() < 1e-9);
        let span = t.last().unwrap() - t[0];
        assert!((span - 6424.0).abs() < 100.0, "span {span}");
        // gaps must NOT be uniform
        let gaps: Vec<f64> = t.windows(2).map(|w| w[1] - w[0]).collect();
        let gmin = gaps.iter().cloned().fold(f64::MAX, f64::min);
        let gmax = gaps.iter().cloned().fold(f64::MIN, f64::max);
        assert!(gmax > 1.8 * gmin, "gaps {gmin}..{gmax}");
    }

    #[test]
    fn forest_coverage_and_events() {
        let sc = ChileScene::scaled(60, 50, 7);
        let (stack, truth) = sc.generate();
        assert_eq!(stack.n_pixels(), 3000);
        let ff = truth.is_forest.iter().filter(|&&f| f).count() as f64 / 3000.0;
        assert!(ff > 0.2 && ff < 0.45, "forest fraction {ff}");
        // every pixel has an event in the monitor period
        let mon = sc.n_times / 2;
        assert!(truth.event_at.iter().all(|&e| e >= mon && e < sc.n_times));
    }

    #[test]
    fn forest_pixels_ndvi_structure() {
        let sc = ChileScene::scaled(40, 40, 3);
        let (stack, truth) = sc.generate();
        let forest_px = truth.is_forest.iter().position(|&f| f).unwrap();
        let desert_px = truth.is_forest.iter().position(|&f| !f).unwrap();
        let mean_head = |px: usize| {
            let s = stack.series(px);
            s[..sc.n_times / 2].iter().map(|&v| v as f64).sum::<f64>()
                / (sc.n_times / 2) as f64
        };
        assert!(mean_head(forest_px) > 0.3, "forest NDVI {}", mean_head(forest_px));
        assert!(mean_head(desert_px) < 0.15, "desert NDVI {}", mean_head(desert_px));
    }

    #[test]
    fn cloud_rate_produces_nans() {
        let sc = ChileScene { cloud_rate: 0.1, ..ChileScene::scaled(20, 20, 5) };
        let (stack, _) = sc.generate();
        let nan_rate = stack.data().iter().filter(|v| v.is_nan()).count() as f64
            / stack.data().len() as f64;
        assert!((nan_rate - 0.1).abs() < 0.02, "nan rate {nan_rate}");
    }

    #[test]
    fn deterministic() {
        let a = ChileScene::scaled(16, 16, 9).generate().0;
        let b = ChileScene::scaled(16, 16, 9).generate().0;
        assert_eq!(a.data(), b.data());
    }
}
