//! BFAST(CPU) — the fused multi-core implementation of Section 3.
//!
//! All per-pixel model fits collapse into matrix operations shared
//! across the scene (Eqs. 8–11):
//!
//! 1. **create model** — `β_all = M · Y_hist` (one parallel GEMM; M is
//!    computed once in f64 and cast, exactly like the device path);
//! 2. **predictions** — `Ŷ = Xᵀ · β_all` (parallel GEMM);
//! 3. **residuals** — `R = Y − Ŷ` (parallel elementwise);
//! 4. **MOSUMs** — rolling-window sums per pixel, vectorised across
//!    pixel blocks row-by-row (the time-major layout makes the inner
//!    loop contiguous — the CPU analogue of warp coalescing);
//! 5. **detect breaks** — boundary scan per pixel.
//!
//! The five named phases match Fig. 3(a)/4(a)/5/6 of the paper; a
//! [`PhaseTimes`] is returned alongside the results so the benches can
//! print the same breakdowns.
//!
//! **Bit-identity contract:** this engine is the *single definition*
//! of the scene arithmetic. `monitor::MonitorSession` no longer
//! re-derives it: both its one-time history pass and its per-pixel
//! backfill rebuild call [`FusedCpuBfast::run_with_state`] and adopt
//! the engine's final rolling state ([`RollingState`]: β̂, σ̂√n, the
//! MOSUM accumulator and the last-`h` residual ring) verbatim, so the
//! numerics cannot drift between a fresh run and an incremental
//! session. `tests/monitor.rs` still pins the equivalence end to end.

use crate::design;
use crate::linalg;
use crate::metrics::PhaseTimes;
use crate::mosum;
use crate::params::BfastParams;
use crate::raster::{BreakMap, TimeStack};
use crate::threadpool::{self, SyncSlice};
use crate::error::{ensure, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Phase names (shared with the coordinator's tables).
pub const PHASE_MODEL: &str = "create model";
pub const PHASE_PREDICT: &str = "predictions";
pub const PHASE_RESID: &str = "residuals";
pub const PHASE_MOSUM: &str = "mosum";
pub const PHASE_DETECT: &str = "detect breaks";

/// Pixel-block width for the vectorised MOSUM/detect phases.
const BLOCK: usize = 512;

/// The engine's final rolling state after the monitor scan — exactly
/// the per-pixel quantities an incremental `monitor::MonitorSession`
/// needs to keep advancing layer by layer without a refit. Emitted by
/// [`FusedCpuBfast::run_with_state`]; `momax`/`first` live in the
/// returned [`BreakMap`].
#[derive(Clone, Debug, Default)]
pub struct RollingState {
    /// β̂ (p × m, f32) from the history fit.
    pub beta: Vec<f32>,
    /// σ̂√n per pixel (the Eq. 3 denominator).
    pub sigma_denom: Vec<f64>,
    /// Final MOSUM window sum per pixel (the rolling accumulator).
    pub acc: Vec<f64>,
    /// Last-`h` residual rows (h × m, f32); stack row `r` lives at
    /// slot `r % h` — the session's ring convention.
    pub ring: Vec<f32>,
}

/// Fused multi-core BFAST over whole scenes.
pub struct FusedCpuBfast {
    pub params: BfastParams,
    pub threads: usize,
    /// M = (X_h X_hᵀ)⁻¹ X_h, f32 (p × n), from the f64 computation.
    m_f32: Vec<f32>,
    /// Xᵀ, f32 (N × p).
    xt_f32: Vec<f32>,
    bound: Vec<f64>,
}

impl FusedCpuBfast {
    pub fn new(params: BfastParams, time_axis: &[f64]) -> Result<Self> {
        ensure!(
            time_axis.len() == params.n_total,
            "time axis length {} != N {}",
            time_axis.len(),
            params.n_total
        );
        let x = design::design_matrix(time_axis, params.freq, params.k);
        let m = design::history_pinv(&x, params.n_hist)?;
        let bound = mosum::boundary(&params);
        Ok(Self {
            threads: threadpool::default_threads(),
            m_f32: m.to_f32(),
            xt_f32: x.transpose().to_f32(),
            bound,
            params,
        })
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Full scene analysis; returns the break map and phase timings.
    pub fn run(&self, stack: &TimeStack) -> Result<(BreakMap, PhaseTimes)> {
        let (map, times, _) = self.run_inner(stack, false)?;
        Ok((map, times))
    }

    /// Full scene analysis that also emits the engine's final rolling
    /// state — what `monitor::MonitorSession` primes from (and rebuilds
    /// late-reporting pixels with), so the incremental path consumes
    /// this arithmetic instead of re-deriving it.
    pub fn run_with_state(
        &self,
        stack: &TimeStack,
    ) -> Result<(BreakMap, PhaseTimes, RollingState)> {
        let (map, times, state) = self.run_inner(stack, true)?;
        Ok((map, times, state.expect("state requested")))
    }

    fn run_inner(
        &self,
        stack: &TimeStack,
        want_state: bool,
    ) -> Result<(BreakMap, PhaseTimes, Option<RollingState>)> {
        let p = &self.params;
        ensure!(
            stack.n_times() == p.n_total,
            "stack has {} layers, params expect N={}",
            stack.n_times(),
            p.n_total
        );
        let (n_total, n_hist) = (p.n_total, p.n_hist);
        let m = stack.n_pixels();
        let mut times = PhaseTimes::new();
        if m == 0 {
            return Ok((BreakMap::zeros(0), times, want_state.then(RollingState::default)));
        }
        let y = stack.data();

        // 1–3. fit + predict + residuals (shared with the standalone
        // per-phase entry point, so the two can never drift)
        let (beta, resid) = self.fit_residuals_inner(y, m, &mut times, want_state);

        // 4+5. MOSUMs + detect, fused: every pixel block computes its
        // rolling statistics into a block-local strip (n_mon × w) and
        // scans that strip for breaks while it is still cache-hot — the
        // scene-wide (N − n) × m MOSUM matrix never materialises, which
        // removes one full write + read of n_mon·m floats through
        // memory. Arithmetic per element is unchanged (same expressions
        // in the same order), so results stay bit-identical to the
        // two-pass formulation. Wall time is split between the two
        // phases in proportion to per-thread kernel time so the
        // five-phase breakdown (Figs. 3–6) survives the fusion.
        let n_mon = p.n_monitor();
        let mut sigma_state = vec![0.0f64; if want_state { m } else { 0 }];
        let mut acc_state = vec![0.0f64; if want_state { m } else { 0 }];
        let mut map = BreakMap::zeros(m);
        let mosum_ns = AtomicU64::new(0);
        let detect_ns = AtomicU64::new(0);
        let pass = {
            let started = Instant::now();
            let sigma_view = SyncSlice::new(&mut sigma_state);
            let acc_view = SyncSlice::new(&mut acc_state);
            let vb = SyncSlice::new(&mut map.breaks);
            let vf = SyncSlice::new(&mut map.first);
            let vm = SyncSlice::new(&mut map.momax);
            let dof = p.dof() as f64;
            let h = p.h;
            threadpool::parallel_ranges(m, BLOCK, self.threads, |s, e| {
                let t0 = Instant::now();
                let w = e - s;
                let mut sigma = vec![0.0f64; w];
                let mut acc = vec![0.0f64; w];
                // sigma from history rows
                for t in 0..n_hist {
                    let row = &resid[t * m + s..t * m + e];
                    for (sg, &r) in sigma.iter_mut().zip(row) {
                        *sg += (r as f64) * (r as f64);
                    }
                }
                let sqrt_n = (n_hist as f64).sqrt();
                for sg in sigma.iter_mut() {
                    *sg = (*sg / dof).sqrt() * sqrt_n; // denominator σ̂√n
                }
                // initial window: rows n-h .. n-1 end at t = n+1 (row n)
                for t in n_hist + 1 - h..=n_hist {
                    let row = &resid[t * m + s..t * m + e];
                    for (a, &r) in acc.iter_mut().zip(row) {
                        *a += r as f64;
                    }
                }
                let mut strip = vec![0.0f32; n_mon * w];
                {
                    let (row0, _) = strip.split_at_mut(w);
                    for ((o, &a), &sg) in row0.iter_mut().zip(&acc).zip(&sigma) {
                        *o = (a / sg) as f32;
                    }
                }
                // rolling update: t = n+2..N (1-based) → row index t-1;
                // accumulator advance and normalised write fused into a
                // single pass over the block
                for ti in 1..n_mon {
                    let add = &resid[(n_hist + ti) * m + s..(n_hist + ti) * m + e];
                    let sub = &resid[(n_hist + ti - h) * m + s..(n_hist + ti - h) * m + e];
                    let out = &mut strip[ti * w..(ti + 1) * w];
                    for ((((o, a), &ad), &su), &sg) in
                        out.iter_mut().zip(acc.iter_mut()).zip(add).zip(sub).zip(&sigma)
                    {
                        *a += ad as f64 - su as f64;
                        *o = (*a / sg) as f32;
                    }
                }
                if want_state {
                    for j in 0..w {
                        unsafe {
                            sigma_view.write(s + j, sigma[j]);
                            acc_view.write(s + j, acc[j]);
                        }
                    }
                }
                let t1 = Instant::now();
                // detect: scan the still-hot strip
                let mut momax = vec![0.0f32; w];
                let mut first = vec![-1i32; w];
                for ti in 0..n_mon {
                    let b = self.bound[ti] as f32;
                    let row = &strip[ti * w..(ti + 1) * w];
                    for (j, &v) in row.iter().enumerate() {
                        let a = v.abs();
                        if a > momax[j] {
                            momax[j] = a;
                        }
                        if first[j] < 0 && a > b {
                            first[j] = ti as i32;
                        }
                    }
                }
                for j in 0..w {
                    unsafe {
                        vb.write(s + j, (first[j] >= 0) as i32);
                        vf.write(s + j, first[j]);
                        vm.write(s + j, momax[j]);
                    }
                }
                let t2 = Instant::now();
                mosum_ns.fetch_add((t1 - t0).as_nanos() as u64, Ordering::Relaxed);
                detect_ns.fetch_add((t2 - t1).as_nanos() as u64, Ordering::Relaxed);
            });
            started.elapsed()
        };
        let (mn, dn) = (mosum_ns.load(Ordering::Relaxed), detect_ns.load(Ordering::Relaxed));
        let detect_wall = if mn + dn > 0 {
            pass.mul_f64(dn as f64 / (mn + dn) as f64)
        } else {
            std::time::Duration::ZERO
        };
        times.add(PHASE_MOSUM, pass.saturating_sub(detect_wall));
        times.add(PHASE_DETECT, detect_wall);
        // the last-h residual rows, slotted the way the session's ring
        // expects (stack row r at slot r % h)
        let ring = want_state.then(|| {
            let h = p.h;
            let mut ring = vec![0.0f32; h * m];
            for row in n_total - h..n_total {
                let slot = row % h;
                ring[slot * m..(slot + 1) * m].copy_from_slice(&resid[row * m..(row + 1) * m]);
            }
            ring
        });
        drop(resid);
        let state = want_state.then(|| RollingState {
            beta: beta.expect("beta retained"),
            sigma_denom: sigma_state,
            acc: acc_state,
            ring: ring.expect("ring captured"),
        });
        Ok((map, times, state))
    }

    /// Phases 1–3, shared verbatim by [`FusedCpuBfast::run`] and the
    /// standalone [`FusedCpuBfast::fit_residuals`]: one code path, so
    /// the fused engine and the command-stream replayer cannot drift.
    fn fit_residuals_inner(
        &self,
        y: &[f32],
        m: usize,
        times: &mut PhaseTimes,
        keep_beta: bool,
    ) -> (Option<Vec<f32>>, Vec<f32>) {
        if m == 0 {
            return (keep_beta.then(Vec::new), Vec::new());
        }
        let p = &self.params;
        let (n_total, n_hist, preg) = (p.n_total, p.n_hist, p.p());

        // 1. create model: beta (p × m) = M (p × n) · Y[:n] (n × m)
        let mut beta = vec![0.0f32; preg * m];
        times.time(PHASE_MODEL, || {
            linalg::par_sgemm(
                self.threads,
                preg,
                n_hist,
                m,
                &self.m_f32,
                &y[..n_hist * m],
                &mut beta,
            );
        });

        // 2. predictions: yhat (N × m) = Xᵀ (N × p) · beta (p × m)
        let mut yhat = vec![0.0f32; n_total * m];
        times.time(PHASE_PREDICT, || {
            linalg::par_sgemm(self.threads, n_total, preg, m, &self.xt_f32, &beta, &mut yhat);
        });
        // past this point β̂ is only needed for the emitted state
        let beta = keep_beta.then_some(beta);

        // 3. residuals: R = Y − Ŷ (reuse the yhat buffer)
        let mut resid = yhat;
        times.time(PHASE_RESID, || {
            let view = SyncSlice::new(&mut resid);
            threadpool::parallel_ranges(n_total * m, 1 << 16, self.threads, |s, e| {
                let part = unsafe { view.slice_mut(s, e) };
                for (r, &yv) in part.iter_mut().zip(&y[s..e]) {
                    *r = yv - *r;
                }
            });
        });
        (beta, resid)
    }

    /// Phases 1–3 as one standalone call: history fit, predictions and
    /// the residual matrix `R = Y − Ŷ` (N × m, time-major like the
    /// stack). This is the `BatchedFit` dispatch target of the command
    /// stream replayer ([`crate::cmd`]); it runs the *same* code path
    /// as [`FusedCpuBfast::run`]'s first three phases, so the residuals
    /// are bit-identical by construction.
    pub fn fit_residuals(&self, stack: &TimeStack) -> Result<Vec<f32>> {
        let p = &self.params;
        ensure!(
            stack.n_times() == p.n_total,
            "stack has {} layers, params expect N={}",
            stack.n_times(),
            p.n_total
        );
        let mut times = PhaseTimes::new();
        let (_, resid) =
            self.fit_residuals_inner(stack.data(), stack.n_pixels(), &mut times, false);
        Ok(resid)
    }

    /// Phase 4 alone: the full normalised MOSUM strip (n_mon × m,
    /// time-major) over residuals from
    /// [`fit_residuals`](FusedCpuBfast::fit_residuals) — the `Mosum`
    /// dispatch target of the command stream replayer. The fused pass
    /// computes these values block-locally without materialising the
    /// scene-wide strip; per-element arithmetic here is the same
    /// expressions in the same order, so every strip value (and
    /// everything derived from it) is bit-identical to the fused run.
    pub fn mosum_strip(&self, resid: &[f32], m: usize) -> Result<Vec<f32>> {
        let p = &self.params;
        ensure!(
            resid.len() == p.n_total * m,
            "residual matrix has {} values, expected N*m = {}",
            resid.len(),
            p.n_total * m
        );
        let n_mon = p.n_monitor();
        let mut strip = vec![0.0f32; n_mon * m];
        if m == 0 {
            return Ok(strip);
        }
        let (n_hist, h, dof) = (p.n_hist, p.h, p.dof() as f64);
        let view = SyncSlice::new(&mut strip);
        threadpool::parallel_ranges(m, BLOCK, self.threads, |s, e| {
            let w = e - s;
            let mut sigma = vec![0.0f64; w];
            let mut acc = vec![0.0f64; w];
            // sigma from history rows
            for t in 0..n_hist {
                let row = &resid[t * m + s..t * m + e];
                for (sg, &r) in sigma.iter_mut().zip(row) {
                    *sg += (r as f64) * (r as f64);
                }
            }
            let sqrt_n = (n_hist as f64).sqrt();
            for sg in sigma.iter_mut() {
                *sg = (*sg / dof).sqrt() * sqrt_n; // denominator σ̂√n
            }
            // initial window: rows n-h .. n-1 end at t = n+1 (row n)
            for t in n_hist + 1 - h..=n_hist {
                let row = &resid[t * m + s..t * m + e];
                for (a, &r) in acc.iter_mut().zip(row) {
                    *a += r as f64;
                }
            }
            {
                let row0 = unsafe { view.slice_mut(s, e) };
                for ((o, &a), &sg) in row0.iter_mut().zip(&acc).zip(&sigma) {
                    *o = (a / sg) as f32;
                }
            }
            // rolling update, identical expressions to the fused pass
            for ti in 1..n_mon {
                let add = &resid[(n_hist + ti) * m + s..(n_hist + ti) * m + e];
                let sub = &resid[(n_hist + ti - h) * m + s..(n_hist + ti - h) * m + e];
                let out = unsafe { view.slice_mut(ti * m + s, ti * m + e) };
                for ((((o, a), &ad), &su), &sg) in
                    out.iter_mut().zip(acc.iter_mut()).zip(add).zip(sub).zip(&sigma)
                {
                    *a += ad as f64 - su as f64;
                    *o = (*a / sg) as f32;
                }
            }
        });
        Ok(strip)
    }

    /// Phase 5 alone: scan a [`mosum_strip`](FusedCpuBfast::mosum_strip)
    /// against the monitoring boundary — the `DetectBreaks` dispatch
    /// target of the command stream replayer. Same comparisons in the
    /// same order as the fused pass.
    pub fn detect_from_strip(&self, strip: &[f32], m: usize) -> Result<BreakMap> {
        let p = &self.params;
        let n_mon = p.n_monitor();
        ensure!(
            strip.len() == n_mon * m,
            "MOSUM strip has {} values, expected n_mon*m = {}",
            strip.len(),
            n_mon * m
        );
        let mut map = BreakMap::zeros(m);
        if m == 0 {
            return Ok(map);
        }
        let vb = SyncSlice::new(&mut map.breaks);
        let vf = SyncSlice::new(&mut map.first);
        let vm = SyncSlice::new(&mut map.momax);
        threadpool::parallel_ranges(m, BLOCK, self.threads, |s, e| {
            let w = e - s;
            let mut momax = vec![0.0f32; w];
            let mut first = vec![-1i32; w];
            for ti in 0..n_mon {
                let b = self.bound[ti] as f32;
                let row = &strip[ti * m + s..ti * m + e];
                for (j, &v) in row.iter().enumerate() {
                    let a = v.abs();
                    if a > momax[j] {
                        momax[j] = a;
                    }
                    if first[j] < 0 && a > b {
                        first[j] = ti as i32;
                    }
                }
            }
            for j in 0..w {
                unsafe {
                    vb.write(s + j, (first[j] >= 0) as i32);
                    vf.write(s + j, first[j]);
                    vm.write(s + j, momax[j]);
                }
            }
        });
        Ok(map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pixel::DirectBfast;
    use crate::synth::ArtificialDataset;

    fn params() -> BfastParams {
        BfastParams::with_lambda(60, 40, 20, 2, 12.0, 0.05, 2.5).unwrap()
    }

    #[test]
    fn matches_per_pixel_reference() {
        let p = params();
        let data = ArtificialDataset::new(p.clone(), 333, 5).generate();
        let fused = FusedCpuBfast::new(p.clone(), &data.stack.time_axis).unwrap();
        let (map, times) = fused.run(&data.stack).unwrap();
        let direct = DirectBfast::new(p, &data.stack.time_axis)
            .unwrap()
            .run(&data.stack)
            .unwrap();
        assert_eq!(map.breaks, direct.breaks);
        assert_eq!(map.first, direct.first);
        for (a, b) in map.momax.iter().zip(&direct.momax) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}");
        }
        // all five phases were recorded
        for ph in [PHASE_MODEL, PHASE_PREDICT, PHASE_RESID, PHASE_MOSUM, PHASE_DETECT] {
            assert!(times.get(ph).is_some(), "missing phase {ph}");
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let p = params();
        let data = ArtificialDataset::new(p.clone(), 97, 6).generate();
        let f1 = FusedCpuBfast::new(p.clone(), &data.stack.time_axis)
            .unwrap()
            .with_threads(1);
        let f8 = FusedCpuBfast::new(p, &data.stack.time_axis)
            .unwrap()
            .with_threads(8);
        let (m1, _) = f1.run(&data.stack).unwrap();
        let (m8, _) = f8.run(&data.stack).unwrap();
        assert_eq!(m1.breaks, m8.breaks);
        assert_eq!(m1.first, m8.first);
        assert_eq!(m1.momax, m8.momax);
    }

    #[test]
    fn run_with_state_matches_run_and_reports_consistent_state() {
        let p = params();
        let data = ArtificialDataset::new(p.clone(), 64, 9).generate();
        let eng = FusedCpuBfast::new(p.clone(), &data.stack.time_axis).unwrap();
        let (plain, _) = eng.run(&data.stack).unwrap();
        let (map, _, st) = eng.run_with_state(&data.stack).unwrap();
        assert_eq!(map.breaks, plain.breaks);
        assert_eq!(map.first, plain.first);
        assert_eq!(map.momax, plain.momax);
        let m = data.stack.n_pixels();
        assert_eq!(st.beta.len(), p.p() * m);
        assert_eq!(st.sigma_denom.len(), m);
        assert_eq!(st.acc.len(), m);
        assert_eq!(st.ring.len(), p.h * m);
        // the accumulator must equal the last window sum divided out in
        // the final MOSUM value: acc/σ̂√n truncated to f32 is the last
        // mo row, whose |.| can never exceed the reported momax
        for px in 0..m {
            let last_mo = ((st.acc[px] / st.sigma_denom[px]) as f32).abs();
            assert!(last_mo <= map.momax[px], "px {px}: {last_mo} > {}", map.momax[px]);
        }
    }

    #[test]
    fn per_phase_split_matches_the_fused_run_bitwise() {
        let p = params();
        let data = ArtificialDataset::new(p.clone(), 700, 12).generate();
        let mut stack = data.stack;
        // gaps and one all-NaN pixel: both paths see identical values
        stack.data_mut()[17] = f32::NAN;
        stack.data_mut()[700 + 3] = f32::NAN;
        let m = stack.n_pixels();
        for t in 0..p.n_total {
            stack.data_mut()[t * m + 5] = f32::NAN;
        }
        let eng = FusedCpuBfast::new(p.clone(), &stack.time_axis).unwrap();
        let (fused, _) = eng.run(&stack).unwrap();
        let resid = eng.fit_residuals(&stack).unwrap();
        let strip = eng.mosum_strip(&resid, m).unwrap();
        let map = eng.detect_from_strip(&strip, m).unwrap();
        assert_eq!(map.breaks, fused.breaks);
        assert_eq!(map.first, fused.first);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&map.momax), bits(&fused.momax));
        // shape errors are rejected, not padded
        assert!(eng.mosum_strip(&resid[1..], m).is_err());
        assert!(eng.detect_from_strip(&strip[1..], m).is_err());
    }

    #[test]
    fn empty_scene_ok() {
        let p = params();
        let stack = TimeStack::zeros(p.n_total, 0);
        let fused = FusedCpuBfast::new(p, &stack.time_axis).unwrap();
        let (map, _) = fused.run(&stack).unwrap();
        assert_eq!(map.len(), 0);
    }

    #[test]
    fn rejects_layer_mismatch() {
        let p = params();
        let stack = TimeStack::zeros(10, 4);
        let fused = FusedCpuBfast::new(p, &crate::design::regular_time_axis(60)).unwrap();
        assert!(fused.run(&stack).is_err());
    }
}
