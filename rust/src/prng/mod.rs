//! Deterministic pseudo-random number generation (substrate for the
//! offline build — replaces the `rand` crate).
//!
//! * [`SplitMix64`] — seeding / stream derivation.
//! * [`Pcg32`] — the workhorse generator (PCG-XSH-RR 64/32).
//! * Gaussian sampling via Box–Muller with a cached spare.
//!
//! Everything is reproducible from a `u64` seed; parallel workers
//! derive independent streams with [`Pcg32::stream`].

/// SplitMix64 — tiny, solid 64-bit generator used to seed PCG.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 — small-state, statistically strong, fast.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub const DEFAULT_STREAM: u64 = 0xDA3E_39CB_94B9_5BDB;

    /// Seed via SplitMix so that nearby seeds give unrelated states.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, Self::DEFAULT_STREAM)
    }

    /// Independent generator for (seed, stream id) — used to give each
    /// worker thread / pixel block its own sequence.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let init_state = sm.next_u64();
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(init_state);
        rng.next_u32();
        rng
    }

    /// Derive a child stream (e.g. per chunk index).
    pub fn stream(&self, id: u64) -> Self {
        Self::with_stream(self.state ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15), id)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (bound as u64);
            let lo = m as u32;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

/// Gaussian sampler: Box–Muller with a cached second deviate.
#[derive(Clone, Debug)]
pub struct Normal {
    rng: Pcg32,
    spare: Option<f64>,
}

impl Normal {
    pub fn new(rng: Pcg32) -> Self {
        Self { rng, spare: None }
    }

    pub fn from_seed(seed: u64) -> Self {
        Self::new(Pcg32::new(seed))
    }

    /// Standard normal deviate.
    pub fn sample(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        // Box–Muller on (0,1] uniforms (avoid ln(0)).
        let u1 = 1.0 - self.rng.uniform();
        let u2 = self.rng.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// N(mu, sigma^2) deviate.
    #[inline]
    pub fn sample_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.sample()
    }

    /// Fill a slice with iid standard normals (f32).
    pub fn fill_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.sample() as f32;
        }
    }

    /// Access the underlying uniform generator.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values from the canonical splitmix64.c with seed 0.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn pcg_is_deterministic_and_stream_dependent() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        let seq_a: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let seq_b: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_eq!(seq_a, seq_b);
        let mut c = Pcg32::with_stream(42, 7);
        let seq_c: Vec<u32> = (0..8).map(|_| c.next_u32()).collect();
        assert_ne!(seq_a, seq_c);
    }

    #[test]
    fn uniform_bounds_and_moments() {
        let mut rng = Pcg32::new(1);
        let nsamp = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..nsamp {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
            sumsq += u * u;
        }
        let mean = sum / nsamp as f64;
        let var = sumsq / nsamp as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 3e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 3e-3, "var={var}");
    }

    #[test]
    fn below_is_unbiased_over_small_bound() {
        let mut rng = Pcg32::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..100_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 / 20_000.0 - 1.0).abs() < 0.05, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut n = Normal::from_seed(3);
        let nsamp = 200_000;
        let (mut sum, mut sumsq, mut sumcub) = (0.0, 0.0, 0.0);
        for _ in 0..nsamp {
            let x = n.sample();
            sum += x;
            sumsq += x * x;
            sumcub += x * x * x;
        }
        let mean = sum / nsamp as f64;
        let var = sumsq / nsamp as f64 - mean * mean;
        let skew = sumcub / nsamp as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
        assert!(skew.abs() < 0.05, "skew={skew}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg32::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn child_streams_are_distinct() {
        let base = Pcg32::new(11);
        let mut s1 = base.stream(1);
        let mut s2 = base.stream(2);
        let a: Vec<u32> = (0..4).map(|_| s1.next_u32()).collect();
        let b: Vec<u32> = (0..4).map(|_| s2.next_u32()).collect();
        assert_ne!(a, b);
    }
}
