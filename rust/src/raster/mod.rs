//! Scene / time-series raster data model.
//!
//! A [`TimeStack`] holds every pixel's series for one scene in a
//! single time-major buffer `Y ∈ R^{N×m}` (row = one acquisition, as
//! in Eq. 7 of the paper). Time-major layout is what the device
//! pipeline wants (the history rows `Y[:n]` form a contiguous prefix,
//! and a pixel-range chunk is one memcpy per row).
//!
//! Submodules: [`io`] — the `.bsq` on-disk format; [`pgm`] — grayscale
//! heatmap export (Fig. 7/9 analogues); [`chunks`] — pixel-range
//! chunking used by the coordinator.

pub mod chunks;
pub mod io;
pub mod pgm;

pub use chunks::{ChunkPlan, PixelChunk};

use crate::error::{ensure, Result};

/// A scene's worth of time series: `n_times × n_pixels`, time-major.
#[derive(Clone, Debug, PartialEq)]
pub struct TimeStack {
    n_times: usize,
    n_pixels: usize,
    /// Optional scene geometry (pixels = width × height when present).
    pub width: Option<usize>,
    pub height: Option<usize>,
    /// Time axis: acquisition time of each layer (index or fractional
    /// day-of-year — see `design::design_matrix`).
    pub time_axis: Vec<f64>,
    data: Vec<f32>,
}

impl TimeStack {
    /// New zero-filled stack with a regular 1..=N time axis.
    pub fn zeros(n_times: usize, n_pixels: usize) -> Self {
        Self {
            n_times,
            n_pixels,
            width: None,
            height: None,
            time_axis: crate::design::regular_time_axis(n_times),
            data: vec![0.0; n_times * n_pixels],
        }
    }

    pub fn from_vec(n_times: usize, n_pixels: usize, data: Vec<f32>) -> Result<Self> {
        ensure!(
            data.len() == n_times * n_pixels,
            "TimeStack: {}x{} needs {} values, got {}",
            n_times,
            n_pixels,
            n_times * n_pixels,
            data.len()
        );
        Ok(Self {
            n_times,
            n_pixels,
            width: None,
            height: None,
            time_axis: crate::design::regular_time_axis(n_times),
            data,
        })
    }

    pub fn with_geometry(mut self, width: usize, height: usize) -> Result<Self> {
        ensure!(
            width * height == self.n_pixels,
            "geometry {}x{} != {} pixels",
            width,
            height,
            self.n_pixels
        );
        self.width = Some(width);
        self.height = Some(height);
        Ok(self)
    }

    pub fn with_time_axis(mut self, t: Vec<f64>) -> Result<Self> {
        ensure!(
            t.len() == self.n_times,
            "time axis length {} != {} layers",
            t.len(),
            self.n_times
        );
        ensure!(
            t.windows(2).all(|w| w[1] > w[0]),
            "time axis must be strictly increasing"
        );
        self.time_axis = t;
        Ok(self)
    }

    pub fn n_times(&self) -> usize {
        self.n_times
    }

    pub fn n_pixels(&self) -> usize {
        self.n_pixels
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// One acquisition layer (all pixels at time index `t`).
    pub fn layer(&self, t: usize) -> &[f32] {
        &self.data[t * self.n_pixels..(t + 1) * self.n_pixels]
    }

    pub fn layer_mut(&mut self, t: usize) -> &mut [f32] {
        &mut self.data[t * self.n_pixels..(t + 1) * self.n_pixels]
    }

    /// Gather one pixel's series (strided copy).
    pub fn series(&self, pixel: usize) -> Vec<f32> {
        (0..self.n_times)
            .map(|t| self.data[t * self.n_pixels + pixel])
            .collect()
    }

    /// Gather one pixel's series as f64 (for the per-pixel baselines).
    pub fn series_f64(&self, pixel: usize) -> Vec<f64> {
        (0..self.n_times)
            .map(|t| self.data[t * self.n_pixels + pixel] as f64)
            .collect()
    }

    /// Set one pixel's series (strided scatter).
    pub fn set_series(&mut self, pixel: usize, series: &[f32]) {
        assert_eq!(series.len(), self.n_times);
        for (t, &v) in series.iter().enumerate() {
            self.data[t * self.n_pixels + pixel] = v;
        }
    }

    /// Copy the pixel range `[start, end)` into `dst`, which must hold
    /// `n_times × (end-start + pad)` values; pixels beyond `end-start`
    /// columns are filled with `pad_value` (chunk padding for the
    /// shape-specialised device executables). One memcpy per row.
    pub fn copy_chunk_padded(
        &self,
        start: usize,
        end: usize,
        padded_width: usize,
        pad_value: f32,
        dst: &mut [f32],
    ) {
        let w = end - start;
        assert!(end <= self.n_pixels && w <= padded_width);
        assert_eq!(dst.len(), self.n_times * padded_width);
        for t in 0..self.n_times {
            let src = &self.data[t * self.n_pixels + start..t * self.n_pixels + end];
            let drow = &mut dst[t * padded_width..t * padded_width + w];
            drow.copy_from_slice(src);
            dst[t * padded_width + w..(t + 1) * padded_width].fill(pad_value);
        }
    }

    /// Append one acquisition layer (all pixels at a new time `t`).
    /// `t` must extend the time axis strictly; `layer` holds one value
    /// per pixel. This is the monitoring-session growth path: only the
    /// monitor period grows, one layer per satellite revisit.
    pub fn push_layer(&mut self, t: f64, layer: &[f32]) -> Result<()> {
        ensure!(
            layer.len() == self.n_pixels,
            "layer has {} values, stack has {} pixels",
            layer.len(),
            self.n_pixels
        );
        if let Some(&last) = self.time_axis.last() {
            ensure!(t > last, "layer time {t} does not extend the axis (last = {last})");
        }
        self.data.extend_from_slice(layer);
        self.time_axis.push(t);
        self.n_times += 1;
        Ok(())
    }

    /// The first `n_times` layers as a new stack (copies) — the
    /// "archive as of layer k" view used to compare incremental
    /// monitoring against fresh full runs.
    pub fn prefix(&self, n_times: usize) -> Result<TimeStack> {
        ensure!(
            n_times >= 1 && n_times <= self.n_times,
            "prefix of {} layers from a {}-layer stack",
            n_times,
            self.n_times
        );
        Ok(Self {
            n_times,
            n_pixels: self.n_pixels,
            width: self.width,
            height: self.height,
            time_axis: self.time_axis[..n_times].to_vec(),
            data: self.data[..n_times * self.n_pixels].to_vec(),
        })
    }

    /// Drop the first `from` layers (copies) — ROC-trimmed history:
    /// when the stable-history scan finds a break inside the candidate
    /// history, the layers before it are discarded entirely.
    pub fn slice_layers(&self, from: usize) -> Result<TimeStack> {
        ensure!(
            from < self.n_times,
            "cannot drop {} of {} layers",
            from,
            self.n_times
        );
        Ok(Self {
            n_times: self.n_times - from,
            n_pixels: self.n_pixels,
            width: self.width,
            height: self.height,
            time_axis: self.time_axis[from..].to_vec(),
            data: self.data[from * self.n_pixels..].to_vec(),
        })
    }

    /// View of a pixel range as a new stack (copies).
    pub fn slice_pixels(&self, start: usize, end: usize) -> TimeStack {
        let w = end - start;
        let mut out = TimeStack::zeros(self.n_times, w);
        out.time_axis = self.time_axis.clone();
        for t in 0..self.n_times {
            out.data[t * w..(t + 1) * w].copy_from_slice(
                &self.data[t * self.n_pixels + start..t * self.n_pixels + end],
            );
        }
        out
    }
}

/// Per-pixel outputs of one analysis, assembled scene-wide.
#[derive(Clone, Debug, Default)]
pub struct BreakMap {
    /// 1 where a break was detected.
    pub breaks: Vec<i32>,
    /// 0-based monitor index of the first crossing, -1 when none.
    pub first: Vec<i32>,
    /// max_t |MO_t| per pixel (Fig. 9 statistic).
    pub momax: Vec<f32>,
}

impl BreakMap {
    pub fn with_capacity(m: usize) -> Self {
        Self {
            breaks: Vec::with_capacity(m),
            first: Vec::with_capacity(m),
            momax: Vec::with_capacity(m),
        }
    }

    pub fn zeros(m: usize) -> Self {
        Self { breaks: vec![0; m], first: vec![-1; m], momax: vec![0.0; m] }
    }

    pub fn len(&self) -> usize {
        self.breaks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.breaks.is_empty()
    }

    pub fn break_count(&self) -> usize {
        self.breaks.iter().filter(|&&b| b != 0).count()
    }

    pub fn break_fraction(&self) -> f64 {
        if self.breaks.is_empty() {
            0.0
        } else {
            self.break_count() as f64 / self.breaks.len() as f64
        }
    }

    /// Write a chunk's results at pixel offset `at` (used by the
    /// coordinator when chunks complete out of order).
    pub fn write_at(&mut self, at: usize, breaks: &[i32], first: &[i32], momax: &[f32]) {
        self.breaks[at..at + breaks.len()].copy_from_slice(breaks);
        self.first[at..at + first.len()].copy_from_slice(first);
        self.momax[at..at + momax.len()].copy_from_slice(momax);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_and_layer_are_consistent() {
        let mut s = TimeStack::zeros(3, 4);
        for t in 0..3 {
            for p in 0..4 {
                s.data_mut()[t * 4 + p] = (t * 10 + p) as f32;
            }
        }
        assert_eq!(s.layer(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(s.series(2), vec![2.0, 12.0, 22.0]);
        s.set_series(0, &[9.0, 9.0, 9.0]);
        assert_eq!(s.series(0), vec![9.0, 9.0, 9.0]);
    }

    #[test]
    fn chunk_copy_pads() {
        let mut s = TimeStack::zeros(2, 5);
        for (i, v) in s.data_mut().iter_mut().enumerate() {
            *v = i as f32;
        }
        let mut dst = vec![-1.0f32; 2 * 4];
        s.copy_chunk_padded(1, 3, 4, 0.5, &mut dst);
        assert_eq!(dst, vec![1.0, 2.0, 0.5, 0.5, 6.0, 7.0, 0.5, 0.5]);
    }

    #[test]
    fn slice_pixels_roundtrip() {
        let mut s = TimeStack::zeros(3, 6);
        for (i, v) in s.data_mut().iter_mut().enumerate() {
            *v = i as f32;
        }
        let sub = s.slice_pixels(2, 5);
        assert_eq!(sub.n_pixels(), 3);
        for p in 0..3 {
            assert_eq!(sub.series(p), s.series(2 + p));
        }
    }

    #[test]
    fn geometry_and_time_axis_validation() {
        let s = TimeStack::zeros(4, 6);
        assert!(s.clone().with_geometry(2, 3).is_ok());
        assert!(s.clone().with_geometry(2, 2).is_err());
        assert!(s.clone().with_time_axis(vec![1.0, 2.0, 3.0, 4.0]).is_ok());
        assert!(s.clone().with_time_axis(vec![1.0, 2.0]).is_err());
        assert!(s.with_time_axis(vec![1.0, 3.0, 2.0, 4.0]).is_err());
    }

    #[test]
    fn push_layer_grows_stack() {
        let mut s = TimeStack::zeros(2, 3);
        s.push_layer(3.0, &[7.0, 8.0, 9.0]).unwrap();
        assert_eq!(s.n_times(), 3);
        assert_eq!(s.layer(2), &[7.0, 8.0, 9.0]);
        assert_eq!(s.time_axis, vec![1.0, 2.0, 3.0]);
        assert_eq!(s.series(1), vec![0.0, 0.0, 8.0]);
        // wrong arity and non-increasing time rejected
        assert!(s.push_layer(4.0, &[1.0]).is_err());
        assert!(s.push_layer(3.0, &[1.0, 2.0, 3.0]).is_err());
        assert!(s.push_layer(2.5, &[1.0, 2.0, 3.0]).is_err());
    }

    #[test]
    fn prefix_and_slice_layers() {
        let mut s = TimeStack::zeros(4, 2).with_geometry(2, 1).unwrap();
        for (i, v) in s.data_mut().iter_mut().enumerate() {
            *v = i as f32;
        }
        let p = s.prefix(2).unwrap();
        assert_eq!(p.n_times(), 2);
        assert_eq!(p.time_axis, vec![1.0, 2.0]);
        assert_eq!(p.data(), &s.data()[..4]);
        assert_eq!((p.width, p.height), (Some(2), Some(1)));
        let tail = s.slice_layers(3).unwrap();
        assert_eq!(tail.n_times(), 1);
        assert_eq!(tail.time_axis, vec![4.0]);
        assert_eq!(tail.data(), &s.data()[6..]);
        assert!(s.prefix(0).is_err());
        assert!(s.prefix(5).is_err());
        assert!(s.slice_layers(4).is_err());
    }

    #[test]
    fn break_map_assembly() {
        let mut bm = BreakMap::zeros(6);
        bm.write_at(2, &[1, 0], &[3, -1], &[2.5, 0.1]);
        assert_eq!(bm.breaks, vec![0, 0, 1, 0, 0, 0]);
        assert_eq!(bm.first, vec![-1, -1, 3, -1, -1, -1]);
        assert_eq!(bm.break_count(), 1);
        assert!((bm.break_fraction() - 1.0 / 6.0).abs() < 1e-12);
    }
}
