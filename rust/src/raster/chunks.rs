//! Pixel-range chunking for the streaming coordinator.
//!
//! The device executables are shape-specialised on `m_chunk` pixels,
//! so a scene of `m` pixels becomes `⌈m / m_chunk⌉` chunks; the last
//! one is padded. [`ChunkPlan`] is the pure planning half (easy to
//! property-test); the coordinator owns the buffers.

/// One planned chunk: pixels `[start, end)` of the scene, executed in
/// a buffer of `padded` columns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PixelChunk {
    pub index: usize,
    pub start: usize,
    pub end: usize,
    pub padded: usize,
}

impl PixelChunk {
    pub fn width(&self) -> usize {
        self.end - self.start
    }

    pub fn pad(&self) -> usize {
        self.padded - self.width()
    }
}

/// Deterministic chunk plan over `m` pixels with chunk width `m_chunk`.
#[derive(Clone, Debug)]
pub struct ChunkPlan {
    pub m: usize,
    pub m_chunk: usize,
    chunks: Vec<PixelChunk>,
}

impl ChunkPlan {
    pub fn new(m: usize, m_chunk: usize) -> Self {
        assert!(m_chunk >= 1, "m_chunk must be >= 1");
        let mut chunks = Vec::with_capacity(m.div_ceil(m_chunk));
        let mut start = 0;
        let mut index = 0;
        while start < m {
            let end = (start + m_chunk).min(m);
            chunks.push(PixelChunk { index, start, end, padded: m_chunk });
            start = end;
            index += 1;
        }
        Self { m, m_chunk, chunks }
    }

    pub fn len(&self) -> usize {
        self.chunks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = PixelChunk> + '_ {
        self.chunks.iter().copied()
    }

    pub fn get(&self, i: usize) -> PixelChunk {
        self.chunks[i]
    }

    /// Total padding overhead (wasted columns) of the plan.
    pub fn padding_overhead(&self) -> usize {
        self.chunks.iter().map(|c| c.pad()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::propcheck::property;

    #[test]
    fn exact_division() {
        let p = ChunkPlan::new(100, 25);
        assert_eq!(p.len(), 4);
        assert!(p.iter().all(|c| c.width() == 25 && c.pad() == 0));
    }

    #[test]
    fn remainder_chunk_padded() {
        let p = ChunkPlan::new(10, 4);
        let cs: Vec<_> = p.iter().collect();
        assert_eq!(cs.len(), 3);
        assert_eq!((cs[2].start, cs[2].end, cs[2].padded), (8, 10, 4));
        assert_eq!(cs[2].pad(), 2);
        assert_eq!(p.padding_overhead(), 2);
    }

    #[test]
    fn empty_scene() {
        let p = ChunkPlan::new(0, 16);
        assert!(p.is_empty());
    }

    #[test]
    fn prop_chunks_partition_the_scene() {
        property("chunks partition [0, m)", 200, |g| {
            let m = g.usize(0..=10_000);
            let mc = g.usize(1..=512);
            let plan = ChunkPlan::new(m, mc);
            let mut covered = 0usize;
            let mut prev_end = 0usize;
            for (i, c) in plan.iter().enumerate() {
                if c.index != i {
                    return Err(format!("index mismatch at {i}"));
                }
                if c.start != prev_end {
                    return Err(format!("gap before chunk {i}: {} != {}", c.start, prev_end));
                }
                if c.end <= c.start && m > 0 {
                    return Err(format!("empty chunk {i}"));
                }
                if c.padded != mc || c.width() > mc {
                    return Err(format!("bad padding at {i}: {c:?}"));
                }
                covered += c.width();
                prev_end = c.end;
            }
            if covered != m {
                return Err(format!("covered {covered} != m {m}"));
            }
            if m > 0 && plan.len() != m.div_ceil(mc) {
                return Err(format!("chunk count {} for m={m} mc={mc}", plan.len()));
            }
            Ok(())
        });
    }
}
