//! On-disk stack format (`.bsq` — band-sequential f32 + JSON header).
//!
//! Layout:
//! ```text
//! magic  "BSQ1"            4 bytes
//! hlen   u32 LE            header length
//! header JSON              n_times, n_pixels, width?, height?, time_axis
//! data   f32 LE            n_times × n_pixels values, time-major
//! ```
//! NaN encodes missing observations (see [`crate::fill`]).

use super::TimeStack;
use crate::json::{self, Value};
use crate::error::{bail, ensure, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"BSQ1";

/// Write a stack to a `.bsq` file.
pub fn write_stack(path: impl AsRef<Path>, stack: &TimeStack) -> Result<()> {
    let path = path.as_ref();
    let mut header = vec![
        ("n_times", Value::Num(stack.n_times() as f64)),
        ("n_pixels", Value::Num(stack.n_pixels() as f64)),
        ("time_axis", Value::arr_num(&stack.time_axis)),
    ];
    if let (Some(w), Some(h)) = (stack.width, stack.height) {
        header.push(("width", Value::Num(w as f64)));
        header.push(("height", Value::Num(h as f64)));
    }
    let htext = Value::obj(header).to_string_compact();
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&(htext.len() as u32).to_le_bytes())?;
    w.write_all(htext.as_bytes())?;
    // bulk f32 LE write
    let data = stack.data();
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    #[cfg(target_endian = "big")]
    compile_error!("bsq writer assumes little-endian host");
    w.write_all(bytes)?;
    w.flush()?;
    Ok(())
}

/// Read a stack from a `.bsq` file.
pub fn read_stack(path: impl AsRef<Path>) -> Result<TimeStack> {
    let path = path.as_ref();
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not a BSQ1 file", path.display());
    }
    let mut hlen = [0u8; 4];
    r.read_exact(&mut hlen)?;
    let hlen = u32::from_le_bytes(hlen) as usize;
    ensure!(hlen < 64 << 20, "unreasonable header length {hlen}");
    let mut htext = vec![0u8; hlen];
    r.read_exact(&mut htext)?;
    let header = json::parse(std::str::from_utf8(&htext)?)
        .with_context(|| format!("{}: bad header", path.display()))?;
    let n_times = header.get("n_times")?.as_usize()?;
    let n_pixels = header.get("n_pixels")?.as_usize()?;
    let taxis: Vec<f64> = header
        .get("time_axis")?
        .as_arr()?
        .iter()
        .map(|v| v.as_f64())
        .collect::<Result<_>>()?;
    let mut bytes = Vec::new();
    r.read_to_end(&mut bytes)?;
    ensure!(
        bytes.len() == n_times * n_pixels * 4,
        "{}: expected {} data bytes, found {}",
        path.display(),
        n_times * n_pixels * 4,
        bytes.len()
    );
    let mut data = vec![0.0f32; n_times * n_pixels];
    for (i, ch) in bytes.chunks_exact(4).enumerate() {
        data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
    }
    let mut stack = TimeStack::from_vec(n_times, n_pixels, data)?.with_time_axis(taxis)?;
    if let (Some(w), Some(h)) = (header.try_get("width"), header.try_get("height")) {
        stack = stack.with_geometry(w.as_usize()?, h.as_usize()?)?;
    }
    Ok(stack)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("bfast_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip() {
        let mut s = TimeStack::zeros(5, 7).with_geometry(7, 1).unwrap();
        for (i, v) in s.data_mut().iter_mut().enumerate() {
            *v = i as f32 * 0.5;
        }
        s.data_mut()[3] = f32::NAN;
        let path = tmpfile("roundtrip.bsq");
        write_stack(&path, &s).unwrap();
        let back = read_stack(&path).unwrap();
        assert_eq!(back.n_times(), 5);
        assert_eq!(back.n_pixels(), 7);
        assert_eq!((back.width, back.height), (Some(7), Some(1)));
        assert_eq!(back.time_axis, s.time_axis);
        for (a, b) in back.data().iter().zip(s.data()) {
            assert!(a == b || (a.is_nan() && b.is_nan()));
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn irregular_time_axis_roundtrip() {
        let s = TimeStack::zeros(3, 2)
            .with_time_axis(vec![18.0, 50.5, 99.25])
            .unwrap();
        let path = tmpfile("axis.bsq");
        write_stack(&path, &s).unwrap();
        assert_eq!(read_stack(&path).unwrap().time_axis, vec![18.0, 50.5, 99.25]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_wrong_magic_and_truncation() {
        let path = tmpfile("bad.bsq");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read_stack(&path).is_err());
        let s = TimeStack::zeros(4, 4);
        write_stack(&path, &s).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        assert!(read_stack(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
