//! On-disk stack format (`.bsq` — band-sequential f32 + JSON header).
//!
//! Layout:
//! ```text
//! magic  "BSQ1"            4 bytes
//! hlen   u32 LE            header length
//! header JSON              n_times, n_pixels, width?, height?, time_axis
//! data   f32 LE            n_times × n_pixels values, time-major
//! ```
//! NaN encodes missing observations (see [`crate::fill`]).

use super::TimeStack;
use crate::json::{self, Value};
use crate::error::{ensure, Context, Result};
use crate::store::hash::Sha256;
use std::path::Path;

const MAGIC: &[u8; 4] = b"BSQ1";

fn header_text(stack: &TimeStack) -> String {
    let mut header = vec![
        ("n_times", Value::Num(stack.n_times() as f64)),
        ("n_pixels", Value::Num(stack.n_pixels() as f64)),
        ("time_axis", Value::arr_num(&stack.time_axis)),
    ];
    if let (Some(w), Some(h)) = (stack.width, stack.height) {
        header.push(("width", Value::Num(w as f64)));
        header.push(("height", Value::Num(h as f64)));
    }
    Value::obj(header).to_string_compact()
}

/// Serialise a stack into the `.bsq` byte layout (the serving API
/// ships stacks as request bodies; files are just these bytes).
pub fn stack_to_bytes(stack: &TimeStack) -> Vec<u8> {
    let htext = header_text(stack);
    let data = stack.data();
    let mut out = Vec::with_capacity(8 + htext.len() + data.len() * 4);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(htext.len() as u32).to_le_bytes());
    out.extend_from_slice(htext.as_bytes());
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// SHA-256 (lowercase hex) of the canonical `.bsq` byte stream of
/// `stack` — identical to hashing [`stack_to_bytes`], but streamed in
/// bounded chunks so no full byte copy of the scene is materialised.
/// This is the scene's content digest (`scene_digest`): the same hex
/// whether the scene arrived as a file, raw octets, or inline JSON.
pub fn stack_digest_hex(stack: &TimeStack) -> String {
    let mut h = Sha256::new();
    let htext = header_text(stack);
    h.update(MAGIC);
    h.update(&(htext.len() as u32).to_le_bytes());
    h.update(htext.as_bytes());
    let mut buf = Vec::with_capacity(4 << 16);
    for chunk in stack.data().chunks(1 << 16) {
        buf.clear();
        for v in chunk {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        h.update(&buf);
    }
    h.finalize_hex()
}

/// The `.bsq` bytes of the pixel slice `[start, end)` of `stack` —
/// byte-identical to `stack_to_bytes(&stack.slice_pixels(start, end))`
/// without materialising the intermediate sliced stack. The sharded
/// fan-out encodes one of these per worker, so skipping the copy
/// matters at scene scale.
pub fn slice_to_bytes(stack: &TimeStack, start: usize, end: usize) -> Vec<u8> {
    assert!(start <= end && end <= stack.n_pixels());
    let w = end - start;
    // slice_pixels drops geometry, so the slice header carries none
    let header = Value::obj(vec![
        ("n_times", Value::Num(stack.n_times() as f64)),
        ("n_pixels", Value::Num(w as f64)),
        ("time_axis", Value::arr_num(&stack.time_axis)),
    ])
    .to_string_compact();
    let data = stack.data();
    let n_pixels = stack.n_pixels();
    let mut out = Vec::with_capacity(8 + header.len() + stack.n_times() * w * 4);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for t in 0..stack.n_times() {
        for v in &data[t * n_pixels + start..t * n_pixels + end] {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Parse a stack from `.bsq` bytes. `label` names the source in
/// errors (a path, a request, …).
pub fn stack_from_bytes(bytes: &[u8], label: &str) -> Result<TimeStack> {
    ensure!(bytes.len() >= 8 && &bytes[..4] == MAGIC, "{label}: not a BSQ1 stream");
    let hlen = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    ensure!(hlen < 64 << 20, "unreasonable header length {hlen}");
    ensure!(bytes.len() >= 8 + hlen, "{label}: truncated header");
    let header = json::parse(std::str::from_utf8(&bytes[8..8 + hlen])?)
        .with_context(|| format!("{label}: bad header"))?;
    let n_times = header.get("n_times")?.as_usize()?;
    let n_pixels = header.get("n_pixels")?.as_usize()?;
    let taxis: Vec<f64> = header
        .get("time_axis")?
        .as_arr()?
        .iter()
        .map(|v| v.as_f64())
        .collect::<Result<_>>()?;
    let payload = &bytes[8 + hlen..];
    ensure!(
        payload.len() == n_times * n_pixels * 4,
        "{label}: expected {} data bytes, found {}",
        n_times * n_pixels * 4,
        payload.len()
    );
    let mut data = vec![0.0f32; n_times * n_pixels];
    for (i, ch) in payload.chunks_exact(4).enumerate() {
        data[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
    }
    let mut stack = TimeStack::from_vec(n_times, n_pixels, data)?.with_time_axis(taxis)?;
    if let (Some(w), Some(h)) = (header.try_get("width"), header.try_get("height")) {
        stack = stack.with_geometry(w.as_usize()?, h.as_usize()?)?;
    }
    Ok(stack)
}

/// Write a stack to a `.bsq` file. Streams the payload in bounded
/// chunks — unlike [`stack_to_bytes`], peak memory stays O(chunk)
/// above the stack itself, so scene-scale exports don't double RSS.
pub fn write_stack(path: impl AsRef<Path>, stack: &TimeStack) -> Result<()> {
    use std::io::Write as _;
    let path = path.as_ref();
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = std::io::BufWriter::new(file);
    let htext = header_text(stack);
    w.write_all(MAGIC)?;
    w.write_all(&(htext.len() as u32).to_le_bytes())?;
    w.write_all(htext.as_bytes())?;
    let mut buf = Vec::with_capacity(4 << 16);
    for chunk in stack.data().chunks(1 << 16) {
        buf.clear();
        for v in chunk {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    w.flush()?;
    Ok(())
}

/// Read a stack from a `.bsq` file.
pub fn read_stack(path: impl AsRef<Path>) -> Result<TimeStack> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
    stack_from_bytes(&bytes, &path.display().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("bfast_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip() {
        let mut s = TimeStack::zeros(5, 7).with_geometry(7, 1).unwrap();
        for (i, v) in s.data_mut().iter_mut().enumerate() {
            *v = i as f32 * 0.5;
        }
        s.data_mut()[3] = f32::NAN;
        let path = tmpfile("roundtrip.bsq");
        write_stack(&path, &s).unwrap();
        let back = read_stack(&path).unwrap();
        assert_eq!(back.n_times(), 5);
        assert_eq!(back.n_pixels(), 7);
        assert_eq!((back.width, back.height), (Some(7), Some(1)));
        assert_eq!(back.time_axis, s.time_axis);
        for (a, b) in back.data().iter().zip(s.data()) {
            assert!(a == b || (a.is_nan() && b.is_nan()));
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn irregular_time_axis_roundtrip() {
        let s = TimeStack::zeros(3, 2)
            .with_time_axis(vec![18.0, 50.5, 99.25])
            .unwrap();
        let path = tmpfile("axis.bsq");
        write_stack(&path, &s).unwrap();
        assert_eq!(read_stack(&path).unwrap().time_axis, vec![18.0, 50.5, 99.25]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bytes_roundtrip_without_touching_disk() {
        let mut s = TimeStack::zeros(3, 4);
        s.data_mut()[5] = f32::NAN;
        s.data_mut()[7] = -2.5;
        let bytes = stack_to_bytes(&s);
        let back = stack_from_bytes(&bytes, "test").unwrap();
        assert_eq!(back.n_times(), 3);
        assert_eq!(back.n_pixels(), 4);
        for (a, b) in back.data().iter().zip(s.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(stack_from_bytes(&bytes[..bytes.len() - 1], "test").is_err());
        assert!(stack_from_bytes(b"BS", "test").is_err());
    }

    #[test]
    fn digest_and_slice_bytes_match_the_materialised_forms() {
        let mut s = TimeStack::zeros(4, 6).with_geometry(6, 1).unwrap();
        for (i, v) in s.data_mut().iter_mut().enumerate() {
            *v = i as f32 * 0.25;
        }
        s.data_mut()[5] = f32::NAN;
        assert_eq!(
            stack_digest_hex(&s),
            crate::store::hash::sha256_hex(&stack_to_bytes(&s)),
            "streamed digest must equal hashing the materialised bytes"
        );
        let direct = stack_to_bytes(&s.slice_pixels(1, 4));
        assert_eq!(slice_to_bytes(&s, 1, 4), direct);
        // full-width slice still drops geometry, like slice_pixels
        assert_eq!(slice_to_bytes(&s, 0, 6), stack_to_bytes(&s.slice_pixels(0, 6)));
    }

    #[test]
    fn rejects_wrong_magic_and_truncation() {
        let path = tmpfile("bad.bsq");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read_stack(&path).is_err());
        let s = TimeStack::zeros(4, 4);
        write_stack(&path, &s).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        assert!(read_stack(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
