//! Grayscale heatmap export (binary PGM, P5).
//!
//! Used to render the paper's image figures from our outputs: Fig. 7
//! (scene snapshots) and Fig. 9 (max |MOSUM| heatmap). PGM needs no
//! codec dependencies and opens everywhere.

use crate::error::{Context, Result};
use std::io::{BufWriter, Write};
use std::path::Path;

/// Write `values` (row-major, `width × height`) as an 8-bit PGM,
/// linearly mapping `[lo, hi]` → [0, 255]. NaN renders as 0.
pub fn write_pgm(
    path: impl AsRef<Path>,
    values: &[f32],
    width: usize,
    height: usize,
    lo: f32,
    hi: f32,
) -> Result<()> {
    assert_eq!(values.len(), width * height, "pgm: size mismatch");
    let path = path.as_ref();
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    write!(w, "P5\n{width} {height}\n255\n")?;
    let span = if hi > lo { hi - lo } else { 1.0 };
    let mut row = Vec::with_capacity(width);
    for y in 0..height {
        row.clear();
        for x in 0..width {
            let v = values[y * width + x];
            let b = if v.is_nan() {
                0u8
            } else {
                (((v - lo) / span).clamp(0.0, 1.0) * 255.0).round() as u8
            };
            row.push(b);
        }
        w.write_all(&row)?;
    }
    w.flush()?;
    Ok(())
}

/// Convenience: auto-scale to the finite min/max of the data.
pub fn write_pgm_autoscale(
    path: impl AsRef<Path>,
    values: &[f32],
    width: usize,
    height: usize,
) -> Result<(f32, f32)> {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in values {
        if v.is_finite() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    if !lo.is_finite() {
        lo = 0.0;
        hi = 1.0;
    }
    write_pgm(path, values, width, height, lo, hi)?;
    Ok((lo, hi))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_and_payload() {
        let path = std::env::temp_dir().join(format!("bfast_pgm_{}.pgm", std::process::id()));
        let vals = vec![0.0f32, 0.5, 1.0, f32::NAN];
        write_pgm(&path, &vals, 2, 2, 0.0, 1.0).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let text = String::from_utf8_lossy(&bytes[..9]);
        assert!(text.starts_with("P5\n2 2\n"));
        let pixels = &bytes[bytes.len() - 4..];
        assert_eq!(pixels, &[0, 128, 255, 0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn autoscale_finds_range() {
        let path = std::env::temp_dir().join(format!("bfast_pgm2_{}.pgm", std::process::id()));
        let (lo, hi) = write_pgm_autoscale(&path, &[2.0, 4.0, 3.0, 2.5], 2, 2).unwrap();
        assert_eq!((lo, hi), (2.0, 4.0));
        std::fs::remove_file(path).ok();
    }
}
