//! Grayscale heatmap import/export (binary PGM, P5).
//!
//! Used to render the paper's image figures from our outputs — Fig. 7
//! (scene snapshots) and Fig. 9 (max |MOSUM| heatmap) — and, on the
//! read side, to ingest single acquisition layers into a monitoring
//! session (`bfast monitor`). PGM needs no codec dependencies and
//! opens everywhere.

use crate::error::{bail, ensure, Context, Result};
use std::path::Path;

/// Encode `values` (row-major, `width × height`) as 8-bit PGM bytes,
/// linearly mapping `[lo, hi]` → [0, 255]. NaN renders as 0. The
/// serving API returns these bytes directly; files are just them.
pub fn encode_pgm(values: &[f32], width: usize, height: usize, lo: f32, hi: f32) -> Vec<u8> {
    assert_eq!(values.len(), width * height, "pgm: size mismatch");
    let header = format!("P5\n{width} {height}\n255\n");
    let mut out = Vec::with_capacity(header.len() + values.len());
    out.extend_from_slice(header.as_bytes());
    let span = if hi > lo { hi - lo } else { 1.0 };
    for &v in values {
        let b = if v.is_nan() {
            0u8
        } else {
            (((v - lo) / span).clamp(0.0, 1.0) * 255.0).round() as u8
        };
        out.push(b);
    }
    out
}

/// The finite min/max of the data (0..1 when nothing is finite) —
/// the auto-scale range used by [`write_pgm_autoscale`].
pub fn autoscale_range(values: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in values {
        if v.is_finite() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    if !lo.is_finite() {
        (0.0, 1.0)
    } else {
        (lo, hi)
    }
}

/// Write `values` (row-major, `width × height`) as an 8-bit PGM,
/// linearly mapping `[lo, hi]` → [0, 255]. NaN renders as 0.
pub fn write_pgm(
    path: impl AsRef<Path>,
    values: &[f32],
    width: usize,
    height: usize,
    lo: f32,
    hi: f32,
) -> Result<()> {
    let path = path.as_ref();
    std::fs::write(path, encode_pgm(values, width, height, lo, hi))
        .with_context(|| format!("writing {}", path.display()))
}

/// Convenience: auto-scale to the finite min/max of the data.
pub fn write_pgm_autoscale(
    path: impl AsRef<Path>,
    values: &[f32],
    width: usize,
    height: usize,
) -> Result<(f32, f32)> {
    let (lo, hi) = autoscale_range(values);
    write_pgm(path, values, width, height, lo, hi)?;
    Ok((lo, hi))
}

/// Read a binary PGM (P5, 8-bit) as one raster layer, mapping pixel
/// values linearly `[0, maxval] → [0, 1]`. Returns
/// `(width, height, values)` row-major. This is the inverse of
/// [`write_pgm`] up to the 8-bit quantisation (NaN is not
/// representable in PGM; gaps must come in via `.bsq`).
pub fn read_pgm(path: impl AsRef<Path>) -> Result<(usize, usize, Vec<f32>)> {
    let path = path.as_ref();
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    ensure!(bytes.starts_with(b"P5"), "{}: not a binary PGM (P5)", path.display());
    // Header: "P5" <ws> width <ws> height <ws> maxval <single ws> data.
    // Comments (# …) may appear between tokens.
    let mut pos = 2usize;
    let mut fields = [0usize; 3];
    for field in fields.iter_mut() {
        // skip whitespace and comment lines
        loop {
            while pos < bytes.len() && bytes[pos].is_ascii_whitespace() {
                pos += 1;
            }
            if pos < bytes.len() && bytes[pos] == b'#' {
                while pos < bytes.len() && bytes[pos] != b'\n' {
                    pos += 1;
                }
            } else {
                break;
            }
        }
        let start = pos;
        while pos < bytes.len() && bytes[pos].is_ascii_digit() {
            pos += 1;
        }
        ensure!(pos > start, "{}: malformed PGM header", path.display());
        *field = std::str::from_utf8(&bytes[start..pos])
            .expect("ascii digits")
            .parse()
            .map_err(|_| crate::err!("{}: bad PGM header number", path.display()))?;
    }
    let [width, height, maxval] = fields;
    ensure!(width >= 1 && height >= 1, "{}: empty PGM", path.display());
    if maxval == 0 || maxval > 255 {
        bail!("{}: unsupported maxval {maxval} (8-bit only)", path.display());
    }
    // exactly one whitespace byte separates maxval from the payload
    ensure!(
        pos < bytes.len() && bytes[pos].is_ascii_whitespace(),
        "{}: truncated PGM",
        path.display()
    );
    pos += 1;
    let payload = &bytes[pos..];
    ensure!(
        payload.len() == width * height,
        "{}: expected {} pixels, found {} bytes",
        path.display(),
        width * height,
        payload.len()
    );
    let scale = 1.0f32 / maxval as f32;
    Ok((width, height, payload.iter().map(|&b| b as f32 * scale).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_and_payload() {
        let path = std::env::temp_dir().join(format!("bfast_pgm_{}.pgm", std::process::id()));
        let vals = vec![0.0f32, 0.5, 1.0, f32::NAN];
        write_pgm(&path, &vals, 2, 2, 0.0, 1.0).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let text = String::from_utf8_lossy(&bytes[..9]);
        assert!(text.starts_with("P5\n2 2\n"));
        let pixels = &bytes[bytes.len() - 4..];
        assert_eq!(pixels, &[0, 128, 255, 0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn read_roundtrips_written_pgm() {
        let path = std::env::temp_dir().join(format!("bfast_pgm_rt_{}.pgm", std::process::id()));
        let vals = vec![0.0f32, 0.25, 0.5, 0.75, 1.0, 0.1];
        write_pgm(&path, &vals, 3, 2, 0.0, 1.0).unwrap();
        let (w, h, back) = read_pgm(&path).unwrap();
        assert_eq!((w, h), (3, 2));
        assert_eq!(back.len(), 6);
        for (a, b) in back.iter().zip(&vals) {
            // 8-bit quantisation: within half a grey level
            assert!((a - b).abs() <= 0.5 / 255.0 + 1e-6, "{a} vs {b}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn read_rejects_garbage() {
        let path = std::env::temp_dir().join(format!("bfast_pgm_bad_{}.pgm", std::process::id()));
        std::fs::write(&path, b"P6\n1 1\n255\n.").unwrap();
        assert!(read_pgm(&path).is_err());
        std::fs::write(&path, b"P5\n2 2\n255\n..").unwrap(); // short payload
        assert!(read_pgm(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn autoscale_finds_range() {
        let path = std::env::temp_dir().join(format!("bfast_pgm2_{}.pgm", std::process::id()));
        let (lo, hi) = write_pgm_autoscale(&path, &[2.0, 4.0, 3.0, 2.5], 2, 2).unwrap();
        assert_eq!((lo, hi), (2.0, 4.0));
        std::fs::remove_file(path).ok();
    }
}
