//! `bfast::cmd` — the recorded command stream: the chunk contract as
//! **data**.
//!
//! Every backend executes the same per-chunk sequence (gather → fill →
//! batched fit → MOSUM → detect → readback), but until this module the
//! sequence only existed as direct Rust calls — nothing to inspect,
//! reorder, or hand to a device. A [`CmdStream`] reifies it: a
//! versioned IR of typed [`Op`]s over a fixed tensor slot table, with
//! a canonical binary encoding (`.bcmd`, see [`CmdStream::encode`])
//! and a JSON dump for inspection ([`CmdStream::to_json`],
//! `bfast replay --dump`).
//!
//! * [`Recorder`] captures a stream. The coordinator drives it over
//!   its resolved chunk plan ([`record_stream`] /
//!   `BfastRunner::record`) instead of calling a `ChunkExecutor` —
//!   the recorded stream carries the **raw, unfilled** staged chunks,
//!   so gap-filling is itself a replayable [`Op::FillColumns`] op.
//! * [`replay::ReplayExecutor`] parses a stream and dispatches each op
//!   to the fused CPU kernels through a translation cache (prepared
//!   engine keyed on the f32 chunk-contract bits), producing break
//!   maps **bit-identical** to a direct run — the op kernels are the
//!   same code path as `FusedCpuBfast::run` (pinned by
//!   `tests/cmdstream.rs`).
//! * [`replay::CmdBackend`] wires record-then-replay in as a
//!   first-class `ExecutorBackend` (`--engine cmd`), and
//!   [`record_stream`] accepts **many jobs** sharing one chunk
//!   contract — the serve scheduler's batching seam: queued compatible
//!   requests execute through a single stream on one prepared engine
//!   (see [`batch_compatible`]).
//!
//! ## `.bcmd` format version policy
//!
//! The binary form opens with the magic `BCMD` and a little-endian
//! `u32` version ([`BCMD_VERSION`], currently 1). The rules:
//!
//! * A reader accepts exactly the versions it knows and **fails
//!   closed** on anything else (`unsupported .bcmd version`): ops must
//!   never be silently skipped, because a skipped op changes the
//!   arithmetic.
//! * Any change to the op set, the slot table, or a header field is a
//!   version bump — there are no in-version extension points.
//! * The encoder always writes the newest version, and encoding is
//!   canonical: `encode(decode(bytes)) == bytes` for any accepted
//!   stream (the fixed-point property pinned by the codec tests).
//!
//! Header values are stored twice on purpose: the resolved `f64`
//! analysis parameters (for result envelopes) and the `f32`
//! chunk-contract values actually fed to the kernels (time axis,
//! frequency, λ) — replay upcasts the f32 bits exactly like the
//! emulated device, which is what makes replay bit-identical.

pub mod codec;
pub mod replay;

pub use replay::{replay_to_results, CmdBackend, ReplayExecutor, REPLAY_ENGINE};

use crate::api::{AnalysisRequest, SceneSource};
use crate::error::{ensure, Result};
use crate::params::BfastParams;
use crate::raster::{ChunkPlan, TimeStack};
use crate::runtime::{Dtype, TensorSpec};

/// Magic bytes opening every `.bcmd` stream.
pub const BCMD_MAGIC: [u8; 4] = *b"BCMD";

/// The stream format version this build reads and writes.
pub const BCMD_VERSION: u32 = 1;

/// Stream-wide execution contract: the resolved analysis parameters
/// plus the f32 values the chunk boundary actually ships (see the
/// module docs on why both live here).
#[derive(Clone, Debug)]
pub struct StreamHeader {
    pub n_total: usize,
    pub n_hist: usize,
    pub h: usize,
    pub k: usize,
    /// Resolved f64 parameters, kept for result envelopes.
    pub freq: f64,
    pub alpha: f64,
    pub lambda: f64,
    /// Pixels per executed chunk (every slot is shaped for this).
    pub m_chunk: usize,
    /// Whether chunks were recorded raw with a gap-fill op following
    /// each gather (`false` = the producer staged pre-filled data).
    pub fill_missing: bool,
    /// The f32 chunk-contract values fed to the kernels.
    pub t_axis: Vec<f32>,
    pub freq32: f32,
    pub lambda32: f32,
}

impl StreamHeader {
    /// Build the header the coordinator's chunk boundary implies:
    /// f32-rounded time axis, frequency and λ next to the resolved
    /// f64 parameters.
    pub fn from_params(
        params: &BfastParams,
        time_axis: &[f64],
        m_chunk: usize,
        fill_missing: bool,
    ) -> Self {
        Self {
            n_total: params.n_total,
            n_hist: params.n_hist,
            h: params.h,
            k: params.k,
            freq: params.freq,
            alpha: params.alpha,
            lambda: params.lambda,
            m_chunk,
            fill_missing,
            t_axis: time_axis.iter().map(|&v| v as f32).collect(),
            freq32: params.freq as f32,
            lambda32: params.lambda as f32,
        }
    }

    /// The resolved f64 parameters (envelope side — replay builds its
    /// engine from the f32 values instead, see [`replay`]).
    pub fn params(&self) -> Result<BfastParams> {
        BfastParams::with_lambda(
            self.n_total,
            self.n_hist,
            self.h,
            self.k,
            self.freq,
            self.alpha,
            self.lambda,
        )
    }

    /// Monitoring-window length `N - n`.
    pub fn n_monitor(&self) -> usize {
        self.n_total - self.n_hist
    }
}

/// One analysis riding in a stream: several jobs may share one stream
/// (and one prepared engine) when their chunk contracts agree.
#[derive(Clone, Debug, PartialEq)]
pub struct JobDesc {
    /// Caller label (request id on serve; `"job 0"` from the CLI).
    pub tag: String,
    /// Pixels in this job's scene.
    pub m: usize,
    /// Optional scene geometry, carried into the result envelope.
    pub width: Option<usize>,
    pub height: Option<usize>,
}

/// One typed command. `job`/`chunk` address the work; slot traffic is
/// implicit in the v1 contract: `StageGather` writes slot `y`,
/// `FillColumns` rewrites it in place, `BatchedFit` produces `resid`,
/// `Mosum` produces `strip`, `DetectBreaks` produces
/// `breaks`/`first`/`momax`, and `Readback` copies the first `width`
/// columns of those into the job's map at `start`.
#[derive(Clone, Debug)]
pub enum Op {
    /// Stage a raw padded chunk (`n_total × m_chunk`, time-major) into
    /// slot `y`. `data` is **unfilled**: NaN observations travel as
    /// recorded.
    StageGather { job: u32, chunk: u32, start: u32, width: u32, data: Vec<f32> },
    /// Gap-fill slot `y` column-wise (the staging-side interpolation).
    FillColumns { job: u32, chunk: u32 },
    /// History OLS fit + predictions + residuals: `y` → `resid`.
    BatchedFit { job: u32, chunk: u32 },
    /// Rolling normalised MOSUM strip: `resid` → `strip`.
    Mosum { job: u32, chunk: u32 },
    /// Scan the strip against the monitoring boundary: `strip` →
    /// `breaks`/`first`/`momax`.
    DetectBreaks { job: u32, chunk: u32 },
    /// Copy columns `[0, width)` of the detection outputs into job
    /// `job`'s break map at pixel `start`.
    Readback { job: u32, chunk: u32, start: u32, width: u32 },
}

impl Op {
    /// Stable op name (JSON tag, trace span name, phase label).
    pub fn name(&self) -> &'static str {
        match self {
            Op::StageGather { .. } => "stage_gather",
            Op::FillColumns { .. } => "fill_columns",
            Op::BatchedFit { .. } => "batched_fit",
            Op::Mosum { .. } => "mosum",
            Op::DetectBreaks { .. } => "detect_breaks",
            Op::Readback { .. } => "readback",
        }
    }

    /// The job this op belongs to.
    pub fn job(&self) -> u32 {
        match self {
            Op::StageGather { job, .. }
            | Op::FillColumns { job, .. }
            | Op::BatchedFit { job, .. }
            | Op::Mosum { job, .. }
            | Op::DetectBreaks { job, .. }
            | Op::Readback { job, .. } => *job,
        }
    }

    /// The job-relative chunk index this op works on.
    pub fn chunk(&self) -> u32 {
        match self {
            Op::StageGather { chunk, .. }
            | Op::FillColumns { chunk, .. }
            | Op::BatchedFit { chunk, .. }
            | Op::Mosum { chunk, .. }
            | Op::DetectBreaks { chunk, .. }
            | Op::Readback { chunk, .. } => *chunk,
        }
    }
}

/// A recorded command stream: header + job table + op sequence.
#[derive(Clone, Debug)]
pub struct CmdStream {
    pub header: StreamHeader,
    pub jobs: Vec<JobDesc>,
    pub ops: Vec<Op>,
}

impl CmdStream {
    /// The v1 tensor slot table this stream's shapes imply. Slots are
    /// fixed by the format version; the table is carried in the binary
    /// form and checked on decode so a corrupted or foreign stream is
    /// rejected before any op executes.
    pub fn slot_table(&self) -> Vec<TensorSpec> {
        slot_table(&self.header)
    }

    /// Number of executed chunks a job contributes (its readbacks).
    pub fn chunks_of(&self, job: u32) -> usize {
        self.ops
            .iter()
            .filter(|op| matches!(op, Op::Readback { .. }) && op.job() == job)
            .count()
    }

    /// Structural validation: every op must address a real job, stay
    /// inside its pixel range, and ship full-slot payloads. Run by
    /// [`CmdStream::decode`] and again by the replayer before
    /// execution.
    pub fn validate(&self) -> Result<()> {
        let h = &self.header;
        ensure!(h.m_chunk >= 1, "m_chunk must be >= 1");
        ensure!(
            h.t_axis.len() == h.n_total,
            "t axis length {} != N {}",
            h.t_axis.len(),
            h.n_total
        );
        h.params()?;
        let chunk_len = h.n_total * h.m_chunk;
        for (i, op) in self.ops.iter().enumerate() {
            let job = op.job() as usize;
            ensure!(
                job < self.jobs.len(),
                "op {i} ({}) addresses job {job}, stream has {}",
                op.name(),
                self.jobs.len()
            );
            let m = self.jobs[job].m;
            match op {
                Op::StageGather { start, width, data, .. } => {
                    ensure!(
                        data.len() == chunk_len,
                        "op {i} (stage_gather) payload has {} values, slot y holds {chunk_len}",
                        data.len()
                    );
                    check_range(i, op.name(), *start, *width, m, h.m_chunk)?;
                }
                Op::Readback { start, width, .. } => {
                    check_range(i, op.name(), *start, *width, m, h.m_chunk)?;
                }
                _ => {}
            }
        }
        Ok(())
    }
}

fn check_range(
    i: usize,
    name: &str,
    start: u32,
    width: u32,
    m: usize,
    m_chunk: usize,
) -> Result<()> {
    let (start, width) = (start as usize, width as usize);
    ensure!(width >= 1 && width <= m_chunk, "op {i} ({name}) width {width} not in [1, {m_chunk}]");
    ensure!(
        start + width <= m,
        "op {i} ({name}) pixels [{start}, {}) exceed the job's {m}",
        start + width
    );
    Ok(())
}

/// The v1 slot table for a header's shapes (see
/// [`CmdStream::slot_table`]).
pub fn slot_table(h: &StreamHeader) -> Vec<TensorSpec> {
    let (n, mc, n_mon) = (h.n_total, h.m_chunk, h.n_monitor());
    let f32s = |name: &str, shape: Vec<usize>| TensorSpec {
        name: name.to_string(),
        shape,
        dtype: Dtype::F32,
    };
    vec![
        f32s("y", vec![n, mc]),
        f32s("resid", vec![n, mc]),
        f32s("strip", vec![n_mon, mc]),
        TensorSpec { name: "breaks".into(), shape: vec![mc], dtype: Dtype::I32 },
        TensorSpec { name: "first".into(), shape: vec![mc], dtype: Dtype::I32 },
        f32s("momax", vec![mc]),
    ]
}

/// Captures a [`CmdStream`]: declare jobs, then record each staged
/// chunk; [`Recorder::record_chunk`] emits the canonical op sequence
/// for it (gather, optional fill, fit, mosum, detect, readback).
pub struct Recorder {
    header: StreamHeader,
    jobs: Vec<JobDesc>,
    ops: Vec<Op>,
}

impl Recorder {
    pub fn new(header: StreamHeader) -> Result<Self> {
        ensure!(header.m_chunk >= 1, "m_chunk must be >= 1");
        ensure!(
            header.t_axis.len() == header.n_total,
            "t axis length {} != N {}",
            header.t_axis.len(),
            header.n_total
        );
        Ok(Self { header, jobs: Vec::new(), ops: Vec::new() })
    }

    /// Declare a job; returns its id for [`Recorder::record_chunk`].
    pub fn begin_job(
        &mut self,
        tag: impl Into<String>,
        m: usize,
        width: Option<usize>,
        height: Option<usize>,
    ) -> u32 {
        self.jobs.push(JobDesc { tag: tag.into(), m, width, height });
        (self.jobs.len() - 1) as u32
    }

    /// Record one staged chunk of `job`: raw padded data (NaNs intact)
    /// covering pixels `[start, start + width)`.
    pub fn record_chunk(
        &mut self,
        job: u32,
        chunk: u32,
        start: usize,
        width: usize,
        data: Vec<f32>,
    ) -> Result<()> {
        let h = &self.header;
        ensure!((job as usize) < self.jobs.len(), "unknown job {job}");
        ensure!(
            data.len() == h.n_total * h.m_chunk,
            "chunk payload has {} values, slot y holds {}",
            data.len(),
            h.n_total * h.m_chunk
        );
        let m = self.jobs[job as usize].m;
        ensure!(
            width >= 1 && width <= h.m_chunk && start + width <= m,
            "chunk pixels [{start}, {}) invalid for m={m}, m_chunk={}",
            start + width,
            h.m_chunk
        );
        let (start, width) = (start as u32, width as u32);
        self.ops.push(Op::StageGather { job, chunk, start, width, data });
        if self.header.fill_missing {
            self.ops.push(Op::FillColumns { job, chunk });
        }
        self.ops.push(Op::BatchedFit { job, chunk });
        self.ops.push(Op::Mosum { job, chunk });
        self.ops.push(Op::DetectBreaks { job, chunk });
        self.ops.push(Op::Readback { job, chunk, start, width });
        Ok(())
    }

    pub fn finish(self) -> CmdStream {
        CmdStream { header: self.header, jobs: self.jobs, ops: self.ops }
    }
}

/// One analysis to record into a (possibly multi-job) stream.
pub struct RecordJob<'a> {
    pub tag: String,
    pub stack: &'a TimeStack,
    pub params: &'a BfastParams,
}

/// Do two resolved parameter sets describe the same chunk contract?
/// (Float fields compare by bits — replay equality is bitwise.)
pub fn params_bits_eq(a: &BfastParams, b: &BfastParams) -> bool {
    a.n_total == b.n_total
        && a.n_hist == b.n_hist
        && a.h == b.h
        && a.k == b.k
        && a.freq.to_bits() == b.freq.to_bits()
        && a.alpha.to_bits() == b.alpha.to_bits()
        && a.lambda.to_bits() == b.lambda.to_bits()
}

/// Record a command stream executing `jobs` through chunk width
/// `m_chunk`. All jobs must share the chunk contract — identical
/// resolved parameters (bitwise) and time axis — because the stream
/// carries exactly one header; [`replay::ReplayExecutor::execute`]
/// then runs them all on one prepared engine and returns one break
/// map per job, in order.
pub fn record_stream(
    jobs: &[RecordJob<'_>],
    m_chunk: usize,
    fill_missing: bool,
) -> Result<CmdStream> {
    ensure!(!jobs.is_empty(), "record_stream: no jobs");
    let first = &jobs[0];
    ensure!(
        first.stack.n_times() == first.params.n_total,
        "stack has {} layers, params expect N={}",
        first.stack.n_times(),
        first.params.n_total
    );
    let header =
        StreamHeader::from_params(first.params, &first.stack.time_axis, m_chunk, fill_missing);
    let n_total = first.params.n_total;
    let mut rec = Recorder::new(header)?;
    for job in jobs {
        ensure!(
            params_bits_eq(job.params, first.params),
            "job {:?} breaks the shared chunk contract (parameters differ)",
            job.tag
        );
        let same_axis = job.stack.time_axis.len() == first.stack.time_axis.len()
            && job
                .stack
                .time_axis
                .iter()
                .zip(&first.stack.time_axis)
                .all(|(a, b)| a.to_bits() == b.to_bits());
        ensure!(
            same_axis,
            "job {:?} breaks the shared chunk contract (time axis differs)",
            job.tag
        );
        let m = job.stack.n_pixels();
        let jid = rec.begin_job(job.tag.clone(), m, job.stack.width, job.stack.height);
        if m == 0 {
            continue;
        }
        let plan = ChunkPlan::new(m, m_chunk);
        for chunk in plan.iter() {
            let mut buf = vec![0.0f32; n_total * m_chunk];
            job.stack.copy_chunk_padded(chunk.start, chunk.end, chunk.padded, 0.0, &mut buf);
            rec.record_chunk(jid, chunk.index as u32, chunk.start, chunk.width(), buf)?;
        }
    }
    Ok(rec.finish())
}

/// Can two queued requests execute through one batched stream? True
/// when both carry inline scenes over the identical time axis, no
/// pixel-range restriction, the same gap-fill setting, and resolve to
/// bitwise-equal parameters — i.e. they differ only in pixel values,
/// which is exactly what the job table expresses. The serve scheduler
/// uses this to drain several small jobs per prepared engine.
pub fn batch_compatible(a: &AnalysisRequest, b: &AnalysisRequest) -> bool {
    let (SceneSource::Inline(sa), SceneSource::Inline(sb)) = (&a.source, &b.source) else {
        return false;
    };
    if a.chunking.pixel_range.is_some() || b.chunking.pixel_range.is_some() {
        return false;
    }
    if a.chunking.fill_missing != b.chunking.fill_missing {
        return false;
    }
    if sa.n_times() != sb.n_times()
        || sa
            .time_axis
            .iter()
            .zip(&sb.time_axis)
            .any(|(x, y)| x.to_bits() != y.to_bits())
    {
        return false;
    }
    let (Ok(pa), Ok(pb)) = (a.params.resolve(sa.n_times()), b.params.resolve(sb.n_times())) else {
        return false;
    };
    params_bits_eq(&pa, &pb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::ArtificialDataset;

    fn params() -> BfastParams {
        BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 3.0).unwrap()
    }

    fn scene(m: usize, seed: u64) -> TimeStack {
        ArtificialDataset::new(params(), m, seed).generate().stack
    }

    #[test]
    fn recorder_emits_the_canonical_op_sequence() {
        let p = params();
        let stack = scene(25, 1);
        let stream = record_stream(
            &[RecordJob { tag: "a".into(), stack: &stack, params: &p }],
            10,
            true,
        )
        .unwrap();
        assert_eq!(stream.jobs.len(), 1);
        assert_eq!(stream.jobs[0].m, 25);
        assert_eq!(stream.chunks_of(0), 3); // ceil(25 / 10)
        // 6 ops per chunk with fill, in a fixed order
        assert_eq!(stream.ops.len(), 3 * 6);
        let names: Vec<&str> = stream.ops[..6].iter().map(|o| o.name()).collect();
        assert_eq!(
            names,
            ["stage_gather", "fill_columns", "batched_fit", "mosum", "detect_breaks", "readback"]
        );
        // the last chunk is partial: width 5, padded payload
        match &stream.ops[2 * 6] {
            Op::StageGather { start, width, data, .. } => {
                assert_eq!((*start, *width), (20, 5));
                assert_eq!(data.len(), p.n_total * 10);
            }
            other => panic!("expected a gather, got {other:?}"),
        }
        assert!(stream.validate().is_ok());

        // no fill op when the producer staged pre-filled data
        let raw = record_stream(
            &[RecordJob { tag: "a".into(), stack: &stack, params: &p }],
            10,
            false,
        )
        .unwrap();
        assert_eq!(raw.ops.len(), 3 * 5);
        assert!(!raw.ops.iter().any(|o| matches!(o, Op::FillColumns { .. })));
    }

    #[test]
    fn slot_table_matches_the_contract_shapes() {
        let p = params();
        let stack = scene(8, 2);
        let stream = record_stream(
            &[RecordJob { tag: "a".into(), stack: &stack, params: &p }],
            4,
            true,
        )
        .unwrap();
        let slots = stream.slot_table();
        let names: Vec<&str> = slots.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["y", "resid", "strip", "breaks", "first", "momax"]);
        assert_eq!(slots[0].shape, vec![40, 4]);
        assert_eq!(slots[2].shape, vec![16, 4]); // n_mon = 40 - 24
        assert_eq!(slots[3].dtype, Dtype::I32);
    }

    #[test]
    fn validation_rejects_malformed_streams() {
        let p = params();
        let stack = scene(12, 3);
        let ok = record_stream(
            &[RecordJob { tag: "a".into(), stack: &stack, params: &p }],
            8,
            true,
        )
        .unwrap();

        // op addressing a job the table does not have
        let mut bad = ok.clone();
        bad.ops.push(Op::BatchedFit { job: 7, chunk: 0 });
        assert!(bad.validate().unwrap_err().to_string().contains("job 7"));

        // readback past the job's pixel count
        let mut bad = ok.clone();
        bad.ops.push(Op::Readback { job: 0, chunk: 0, start: 8, width: 8 });
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("exceed"), "{err}");

        // short gather payload
        let mut bad = ok.clone();
        bad.ops.push(Op::StageGather { job: 0, chunk: 0, start: 0, width: 1, data: vec![0.0] });
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("payload"), "{err}");

        // truncated time axis
        let mut bad = ok;
        bad.header.t_axis.pop();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn multi_job_streams_share_one_contract() {
        let p = params();
        let (a, b) = (scene(9, 4), scene(5, 5));
        let stream = record_stream(
            &[
                RecordJob { tag: "a".into(), stack: &a, params: &p },
                RecordJob { tag: "b".into(), stack: &b, params: &p },
            ],
            8,
            true,
        )
        .unwrap();
        assert_eq!(stream.jobs.len(), 2);
        assert_eq!((stream.chunks_of(0), stream.chunks_of(1)), (2, 1));

        // a job with different parameters is refused
        let p2 = BfastParams::with_lambda(40, 24, 8, 1, 12.0, 0.05, 2.5).unwrap();
        let err = record_stream(
            &[
                RecordJob { tag: "a".into(), stack: &a, params: &p },
                RecordJob { tag: "b".into(), stack: &b, params: &p2 },
            ],
            8,
            true,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("chunk contract"), "{err}");
    }

    #[test]
    fn batch_compatibility_requires_an_identical_contract() {
        use crate::api::ParamSpec;
        let make = |m: usize, seed: u64| {
            let mut req = AnalysisRequest::new(SceneSource::Inline(scene(m, seed)));
            req.params = ParamSpec { n_hist: 24, h: 8, k: 1, freq: 12.0, ..Default::default() };
            req
        };
        let a = make(6, 1);
        assert!(batch_compatible(&a, &make(9, 2)), "pixel values may differ");
        let mut other = make(6, 3);
        other.params.h = 9;
        assert!(!batch_compatible(&a, &other), "parameters must match");
        let mut ranged = make(6, 4);
        ranged.chunking.pixel_range = Some((0, 3));
        assert!(!batch_compatible(&a, &ranged), "pixel ranges opt out");
        let mut nofill = make(6, 5);
        nofill.chunking.fill_missing = false;
        assert!(!batch_compatible(&a, &nofill), "gap-fill setting must match");
        let path = AnalysisRequest::new(SceneSource::Path("x.bsq".into()));
        assert!(!batch_compatible(&a, &path), "path sources opt out");
    }
}
